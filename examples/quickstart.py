"""Quickstart: the paper in 60 seconds, through the one front door.

A hypergraph partition IS an SpGEMM algorithm — and ``repro.plan`` is the
whole pipeline: model the instance, partition it, lower the cut to routing
tables, and (when devices allow) run the partition as a compiled program.

  PYTHONPATH=src python examples/quickstart.py                  # plan + costs
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
  PYTHONPATH=src python examples/quickstart.py                  # + execution

The old five-layer spelling (SpGEMMInstance -> build_model -> partition ->
build_executable_plan -> compile_spgemm) still works for stage-by-stage
exploration; this example is the supported surface.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro

A_FIG1 = np.array([[1, 0, 1, 0], [1, 0, 0, 1], [0, 1, 0, 0]])
B_FIG1 = np.array([[0, 1], [1, 0], [1, 1], [0, 1]])


def main():
    print("== Fig. 1 instance, fine-grained model (Def. 3.1) ==")
    fig1 = repro.plan(A_FIG1, B_FIG1, p=2, model="fine", name="fig1", include_nz=True)
    inst = fig1.instance
    print(f"S_A nnz={inst.a.nnz}, S_B nnz={inst.b.nnz}, S_C nnz={inst.c.nnz}, "
          f"|V^m|={inst.n_mult}")
    print(f"hypergraph: {fig1.hypergraph}")

    print("\n== one real instance, every model, p=4 ==")
    from repro.core.matrices import mcl_instance

    # one symbolic inspection, seven plans: pass the instance itself
    inst = mcl_instance("dip", scale=0.2)
    print(f"{'model':12s} {'family':>6s} {'exec':>5s} {'predicted':>9s} "
          f"{'planned':>9s} {'maxpart':>8s}  imb")
    for model in repro.MODELS:
        handle = repro.plan(inst, p=4, model=model)
        r = handle.cost_report()
        print(
            f"{model:12s} {handle.spec.family:>6s} {str(r['executable']):>5s} "
            f"{r['predicted_words']:9d} {r['planned_words']:9d} "
            f"{r['predicted_max_part']:8d}  {r['comp_imbalance']:.2f}"
        )

    print("\n== auto-selection + execution (values in, dense C out) ==")
    rng = np.random.default_rng(0)
    a_s = inst.a
    b_s = inst.b
    spgemm = repro.plan(inst, p=4, model="auto")
    print(f"selected model: {spgemm.model} "
          f"(predicted {spgemm.cost_report()['predicted_words']} words)")
    if repro.device_count() >= spgemm.p:
        a_vals = rng.standard_normal(a_s.nnz).astype(np.float32)
        b_vals = rng.standard_normal(b_s.nnz).astype(np.float32)
        dense_a = np.zeros(a_s.shape, np.float32)
        dense_a[a_s.coo()] = a_vals
        dense_b = np.zeros(b_s.shape, np.float32)
        dense_b[b_s.coo()] = b_vals
        c = spgemm(a_vals, b_vals)
        err = float(np.abs(c - dense_a @ dense_b).max())
        print(f"executed on {spgemm.p} devices: max |C - A@B| = {err:.2e}")
    else:
        print(f"(execution skipped: {repro.device_count()} device(s) < "
              f"p={spgemm.p}; rerun with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=4)")


if __name__ == "__main__":
    main()
