"""Quickstart: the paper in 60 seconds.

Builds the Fig. 1 SpGEMM instance, constructs the fine-grained hypergraph
(Def. 3.1) and the coarsened 1D/2D models (Sec. 5), partitions each for p=4,
and prints the Lemma 4.2 communication costs — then runs the row-wise
distributed executor to show the partition actually computing A@B.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import SpGEMMInstance, build_model, evaluate, partition, MODELS
from repro.core.matrices import mcl_instance
from repro.sparse import from_dense

A_FIG1 = np.array([[1, 0, 1, 0], [1, 0, 0, 1], [0, 1, 0, 0]])
B_FIG1 = np.array([[0, 1], [1, 0], [1, 1], [0, 1]])


def main():
    print("== Fig. 1 instance ==")
    inst = SpGEMMInstance(from_dense(A_FIG1), from_dense(B_FIG1), name="fig1")
    print(f"S_A nnz={inst.a.nnz}, S_B nnz={inst.b.nnz}, S_C nnz={inst.c.nnz}, "
          f"|V^m|={inst.n_mult}")
    hg = build_model(inst, "fine", include_nz=True)
    print(f"fine-grained hypergraph: {hg}")

    print("\n== partitioning a real instance (MCL 'dip'-like, p=4) ==")
    inst = mcl_instance("dip", scale=0.2)
    for model in MODELS:
        hg = build_model(inst, model)
        res = partition(hg, 4, eps=0.10, seed=0)
        c = evaluate(hg, res.parts, 4)
        print(
            f"{model:11s} V={hg.n_vertices:7d} "
            f"max-part-cost={c.max_part_cost:8d} "
            f"(expand {c.expand}, fold {c.fold}) imb={c.comp_imbalance:.2f}"
        )

    print("\n== executing the row-wise partition (4 host devices) ==")
    print("(run tests/multidev_runner.py for the shard_map executors, or:")
    print("  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\")
    print("  PYTHONPATH=src python tests/multidev_runner.py rowwise)")


if __name__ == "__main__":
    main()
