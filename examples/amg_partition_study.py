"""AMG case study (paper Sec. 6.1 / Fig. 7, reduced scale).

Compares the seven parallelization classes for both Galerkin-product
SpGEMMs (A@P, P^T@(AP)) against geometric baselines, and prints the
paper's headline conclusions from OUR measured numbers.

  PYTHONPATH=src python examples/amg_partition_study.py [--n 9] [--p 8]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import build_model, evaluate, partition
from repro.core.matrices import amg_instances, geometric_row_partition
from repro.core.spgemm_models import MODELS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=9, help="grid side (N^3 points)")
    ap.add_argument("--p", type=int, default=8)
    args = ap.parse_args(argv)

    ap_inst, ptap_inst = amg_instances(args.n)
    geo = geometric_row_partition(args.n, args.p)
    results = {}
    for inst, kind in ((ap_inst, "AP"), (ptap_inst, "PTAP")):
        print(f"\n== {inst.name} ==")
        for model in MODELS:
            hg = build_model(inst, model)
            if hg.n_pins > 4_000_000:
                print(f"{model:11s} skipped ({hg.n_pins} pins)")
                continue
            res = partition(hg, args.p, eps=0.10, seed=0)
            c = evaluate(hg, res.parts, args.p)
            results[(kind, model)] = c.max_part_cost
            print(f"{model:11s} max-part-cost={c.max_part_cost:8d} imb={c.comp_imbalance:.2f}")
        # geometric baseline
        model = "rowwise" if kind == "AP" else "outer"
        hg = build_model(inst, model)
        c = evaluate(hg, geo, args.p)
        results[(kind, "geometric")] = c.max_part_cost
        print(f"{'geo-' + model:11s} max-part-cost={c.max_part_cost:8d}")

    print("\n== paper-claim check (Sec. 6.1) ==")
    rw, out = results[("AP", "rowwise")], results[("AP", "outer")]
    print(f"A@P: row-wise {rw} vs outer {out} -> row-wise sufficient: {rw <= 2 * out}")
    rw, out = results[("PTAP", "rowwise")], results[("PTAP", "outer")]
    print(f"PTAP: outer {out} vs row-wise {rw} -> outer wins by {rw / max(out,1):.1f}x")


if __name__ == "__main__":
    main()
