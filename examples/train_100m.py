"""End-to-end driver (deliverable b): train a ~100M-parameter decoder for a
few hundred steps on the synthetic pipeline, with checkpointing + restart.

  PYTHONPATH=src python examples/train_100m.py --steps 300

The model is the internlm2 family scaled to ~100M params (d=768, 12 layers,
16k vocab).  Loss should drop well below the uniform baseline ln(16384)=9.70
within the first tens of steps (the synthetic stream has Zipf unigrams +
repeated motifs worth >4 nats).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.elastic import run_loop
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, param_count
from repro.models.config import ModelConfig
from repro.training.optimizer import adamw_init
from repro.training.step import make_train_step


def model_100m() -> ModelConfig:
    base = get_config("internlm2-1.8b")
    import dataclasses

    return dataclasses.replace(
        base,
        name="repro-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_head=64,
        d_ff=3072,
        vocab=16384,
        dtype="float32",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--log", default="experiments/train_100m.jsonl")
    args = ap.parse_args(argv)

    cfg = model_100m()
    n = param_count(cfg)
    print(f"model {cfg.name}: {n/1e6:.1f}M params, uniform nll={math.log(cfg.vocab):.3f}")
    mesh = make_host_mesh()
    compat.set_mesh(mesh)

    step = jax.jit(make_train_step(cfg, lr=args.lr), donate_argnums=(0, 1))
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    data = SyntheticTokens(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch, seed=0
    )
    os.makedirs(os.path.dirname(args.log) or ".", exist_ok=True)
    logf = open(args.log, "a")

    t_start = time.time()

    def step_fn(state, idx):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(idx).items()}
        p, o, m = step(p, o, batch)
        loss = float(m["loss"])
        if idx % 10 == 0 or idx == args.steps - 1:
            rec = {
                "step": idx,
                "loss": round(loss, 4),
                "grad_norm": round(float(m["grad_norm"]), 3),
                "wall_s": round(time.time() - t_start, 1),
            }
            print(rec, flush=True)
            logf.write(json.dumps(rec) + "\n")
            logf.flush()
        return p, o

    (params, opt), stats = run_loop(
        (params, opt),
        step_fn,
        args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        state_to_tree=lambda s: {"p": s[0], "o": s[1]},
        tree_to_state=lambda t, s: (
            jax.tree.map(jnp.asarray, t["p"]),
            jax.tree.map(jnp.asarray, t["o"]),
        ),
    )
    print(f"finished {stats.steps_run} steps ({stats.restarts} restarts)")


if __name__ == "__main__":
    main()
