"""Example: batched transformer decode over the training substrate.

Formerly ``repro.launch.serve``; moved here because the library's serving
story is SpGEMM (``python -m repro.launch.serve``), while this driver
exercises the transformer stack (prefill a prompt batch, then decode).

Usage (in-container, reduced config):
  PYTHONPATH=src python examples/transformer_decode.py \
      --arch internlm2-1.8b --smoke --batch 4 --prompt-len 64 --decode-tokens 32
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.sharding import param_shardings
from repro.training.step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=all_arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_parallel)
    compat.set_mesh(mesh)
    params_sh = param_shardings(cfg, mesh)
    params = jax.jit(partial(init_params, cfg), out_shardings=params_sh)(
        jax.random.key(args.seed)
    )
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.key(args.seed)
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.decode_tokens - 1):
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = logits.argmax(-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    total = args.batch * (args.decode_tokens - 1)
    print(
        f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s | "
        f"decode {total} tokens in {t_decode:.2f}s "
        f"({total/max(t_decode,1e-9):.1f} tok/s)"
    )
    toks = jnp.concatenate(out_tokens, axis=1)
    print("first sequence:", np.asarray(toks[0])[:16].tolist())
    return toks


if __name__ == "__main__":
    main()
