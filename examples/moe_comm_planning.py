"""MoE dispatch planning (the paper's technique inside the LM framework).

Profiles routing on a smoke MoE model, builds the dispatch-SpGEMM hypergraph,
partitions it into expert columns, and compares the planned placement's
communication/load metrics against the naive contiguous placement — then
re-runs the model with the placement installed.

  PYTHONPATH=src python examples/moe_comm_planning.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.moe_planner import plan_expert_placement, routing_counts
from repro.models import init_params, train_loss
from repro.models.config import MoEConfig


def main():
    # a 16-expert smoke MoE with *correlated* routing (see planner tests):
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=64)
    )
    params = init_params(cfg, jax.random.key(0))

    # profile routing: correlated synthetic gate decisions
    rng = np.random.default_rng(0)
    T, E, K = 8192, 16, 2
    scattered = rng.permutation(E).reshape(4, 4)
    gate = np.empty((T, K), dtype=np.int64)
    for t in range(T):
        gate[t] = rng.choice(scattered[(t * 4) // T], size=K, replace=False)

    counts = routing_counts(gate, E, n_groups=64)
    plan = plan_expert_placement(counts, n_columns=4)
    print("dispatch-SpGEMM hypergraph planning (4 expert columns):")
    print(f"  cut cost  : contiguous={plan.comm_contiguous}  planned={plan.comm_planned}")
    print(f"  load imbal: contiguous={plan.load_imbalance_contiguous:.3f}  "
          f"planned={plan.load_imbalance_planned:.3f}")
    print(f"  placement : {plan.placement.tolist()}")

    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, expert_placement=tuple(plan.placement))
    )
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32),
    }
    loss, _ = jax.jit(lambda p, b: train_loss(p, cfg2, b))(params, batch)
    print(f"model runs with planned placement: loss={float(loss):.4f}")


if __name__ == "__main__":
    main()
