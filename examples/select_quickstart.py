"""Model selection in 30 seconds: pick the cheapest SpGEMM algorithm.

Partitions every hypergraph model of a small AMG instance (the 27-point
stencil Galerkin product A·P), reports each model's predicted communication
next to the words its lowered execution plan actually schedules, and — when
the process owns >= p devices — runs the executors against the dense oracle
so predicted == measured is checked on live traffic.  Everything goes
through the ``repro.api`` front door; the sweep table comes from
``sweep_instance`` (the same selection ``model="auto"`` runs).

Single device (plans + prediction only):

    PYTHONPATH=src python examples/select_quickstart.py

With executors live:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/select_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro


def main():
    from repro.core.matrices import amg_instances
    from repro.distributed.select import sweep_instance

    p = 4
    inst = amg_instances(6)[0]  # 27-pt stencil A·P at n=6 (216 rows)
    print(f"instance: {inst.name}  shape={inst.shape}  |V^m|={inst.n_mult}")

    # random values on the fixed structures, for the executor oracle check
    rng = np.random.default_rng(0)
    def valued(struct):
        d = np.zeros(struct.shape, np.float32)
        r, c = struct.coo()
        d[r, c] = rng.standard_normal(len(r)).astype(np.float32)
        return d

    recs = sweep_instance(
        inst, p, a_dense=valued(inst.a), b_dense=valued(inst.b), execute=True
    )
    print(f"\n{'model':12s} {'predicted':>9s} {'measured':>9s} {'padded':>8s}  notes")
    for r in recs:
        if r["status"] != "ok":
            print(f"{r['model']:12s}  skipped: {r['reason']}")
            continue
        measured = str(r.get("measured_words", "-"))
        padded = str(r.get("padded_words", "-"))
        notes = []
        if r.get("measured_words") == r["predicted_words"]:
            notes.append("measured == predicted")
        if "exec_max_err" in r:
            notes.append(f"executor err {r['exec_max_err']:.1e}")
        if r["selected"]:
            notes.append("<== selected")
        print(
            f"{r['model']:12s} {r['predicted_words']:9d} {measured:>9s} "
            f"{padded:>8s}  {', '.join(notes)}"
        )

    iterated_multiply_demo(inst, p, rng)


def iterated_multiply_demo(inst, p, rng):
    """Amortization in action: one ``repro.plan`` handle, compiled once,
    then many same-structure multiplies as value-only updates (the AMG/MCL
    pattern — one partition, many products).  Needs >= p devices."""
    import time

    if repro.device_count() < p:
        print(f"\n(iterated-multiply demo skipped: {repro.device_count()} "
              f"device(s) < p={p})")
        return
    from repro.distributed.runtime import trace_count

    # plan + compile ONCE, from the structures alone (no dense operands)
    spgemm = repro.plan(inst.a, inst.b, p=p, model="fine", name=inst.name)
    t0 = time.perf_counter()
    exe = spgemm.compile()
    cold = time.perf_counter() - t0
    traces = trace_count()
    # many multiplies on the fixed structure: values only, no retracing
    t0 = time.perf_counter()
    iters = 10
    for _ in range(iters):
        a_vals = rng.standard_normal(inst.a.nnz).astype(np.float32)
        b_vals = rng.standard_normal(inst.b.nnz).astype(np.float32)
        c = exe(a_vals, b_vals)  # dense C, synced
    per_call = (time.perf_counter() - t0) / iters
    print(
        f"\ncompile-once runtime (fine, p={p}): compile {cold * 1e3:.0f} ms once, "
        f"then {per_call * 1e6:.0f} us/multiply over {iters} same-structure calls "
        f"({trace_count() - traces} retraces); C is dense, trimmed, ready"
    )


if __name__ == "__main__":
    main()
