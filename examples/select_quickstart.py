"""Model selection in 30 seconds: pick the cheapest SpGEMM algorithm.

Partitions every hypergraph model of a small AMG instance (the 27-point
stencil Galerkin product A·P), reports each model's predicted communication
next to the words its lowered execution plan actually schedules, and — when
the process owns >= p devices — runs the fine-grained executor against the
dense oracle so predicted == measured is checked on live traffic.

Single device (plans + prediction only):

    PYTHONPATH=src python examples/select_quickstart.py

With executors live:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/select_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    from repro.core.matrices import amg_instances
    from repro.distributed.select import sweep_instance

    p = 4
    inst = amg_instances(6)[0]  # 27-pt stencil A·P at n=6 (216 rows)
    print(f"instance: {inst.name}  shape={inst.shape}  |V^m|={inst.n_mult}")

    # random values on the fixed structures, for the executor oracle check
    rng = np.random.default_rng(0)
    def valued(struct):
        d = np.zeros(struct.shape, np.float32)
        r, c = struct.coo()
        d[r, c] = rng.standard_normal(len(r)).astype(np.float32)
        return d

    recs = sweep_instance(
        inst, p, a_dense=valued(inst.a), b_dense=valued(inst.b), execute=True
    )
    print(f"\n{'model':12s} {'predicted':>9s} {'measured':>9s} {'padded':>8s}  notes")
    for r in recs:
        if r["status"] != "ok":
            print(f"{r['model']:12s}  skipped: {r['reason']}")
            continue
        measured = str(r.get("measured_words", "-"))
        padded = str(r.get("padded_words", "-"))
        notes = []
        if r.get("measured_words") == r["predicted_words"]:
            notes.append("measured == predicted")
        if "exec_max_err" in r:
            notes.append(f"executor err {r['exec_max_err']:.1e}")
        if r["selected"]:
            notes.append("<== selected")
        print(
            f"{r['model']:12s} {r['predicted_words']:9d} {measured:>9s} "
            f"{padded:>8s}  {', '.join(notes)}"
        )


if __name__ == "__main__":
    main()
