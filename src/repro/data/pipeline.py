"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step, host_slice): restart at step k
reproduces the exact stream (fault-tolerance requirement — no cursor files to
lose).  At multi-host scale each host materializes only its slice of the
global batch; in-container there is one host and the slice is everything.

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, which gives a learnable (loss goes below uniform) yet
tokenizer-free workload for the end-to-end examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        B, S = self.host_batch, self.seq_len
        # Zipf unigrams, clipped to vocab (rejection-free)
        base = rng.zipf(self.zipf_a, size=(B, S + 1)) % self.vocab
        # inject repeated motifs: positions copy a motif drawn per row
        motif = rng.integers(0, self.vocab, size=(B, self.motif_len))
        for b in range(B):
            n_spans = int(S * self.motif_prob / self.motif_len)
            starts = rng.integers(0, S - self.motif_len, size=n_spans)
            for s in starts:
                base[b, s : s + self.motif_len] = motif[b]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_batch(cfg, shape_spec, step: int, seed: int = 0) -> dict[str, np.ndarray]:
    """Batch for a ModelConfig x ShapeSpec cell (training kinds only)."""
    ds = SyntheticTokens(
        vocab=cfg.vocab,
        seq_len=shape_spec.seq_len,
        global_batch=shape_spec.global_batch,
        seed=seed,
    )
    return ds.batch(step)
