from repro.data.pipeline import SyntheticTokens, make_batch

__all__ = ["SyntheticTokens", "make_batch"]
