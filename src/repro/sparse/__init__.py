"""Sparse-matrix substrate: structure containers, symbolic SpGEMM, BSR tiling.

Structure-only matrices are represented as ``scipy.sparse.csr_matrix`` with
boolean data; this module wraps the handful of structural operations the
hypergraph layer needs so that `core/` never touches scipy directly.
"""
from repro.sparse.structure import (
    SparseStructure,
    as_structure,
    from_coo,
    from_dense,
    random_structure,
    spgemm_symbolic,
    structure_and_values,
    nontrivial_multiplications,
)
from repro.sparse.bsr import BlockSparse, to_bsr, bsr_to_dense

__all__ = [
    "SparseStructure",
    "as_structure",
    "from_coo",
    "from_dense",
    "random_structure",
    "structure_and_values",
    "spgemm_symbolic",
    "nontrivial_multiplications",
    "BlockSparse",
    "to_bsr",
    "bsr_to_dense",
]
