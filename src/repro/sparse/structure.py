"""Nonzero-structure containers and symbolic SpGEMM.

The paper (Sec. 3.1) works purely with nonzero structures S_A, S_B and the
induced S_C (no numerical cancellation).  ``SparseStructure`` is a thin,
immutable wrapper around a deduplicated, sorted boolean CSR matrix.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass(frozen=True)
class SparseStructure:
    """Immutable nonzero structure of a sparse matrix."""

    csr: sp.csr_matrix  # bool data, canonical (sorted indices, no dups)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def wrap(mat: sp.spmatrix) -> "SparseStructure":
        m = sp.csr_matrix(mat, copy=True)
        m.data = np.ones_like(m.data, dtype=bool)
        m.sum_duplicates()
        m.sort_indices()
        m.eliminate_zeros()
        return SparseStructure(m)

    # -- basic properties --------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return self.csr.shape

    @property
    def nnz(self) -> int:
        return int(self.csr.nnz)

    @property
    def indptr(self) -> np.ndarray:
        return self.csr.indptr

    @property
    def indices(self) -> np.ndarray:
        return self.csr.indices

    def row_counts(self) -> np.ndarray:
        return np.diff(self.csr.indptr)

    def col_counts(self) -> np.ndarray:
        return np.asarray(
            self.csr.astype(np.int64).sum(axis=0)
        ).ravel()

    def transpose(self) -> "SparseStructure":
        return SparseStructure.wrap(self.csr.T)

    def tocsc(self) -> sp.csc_matrix:
        return self.csr.tocsc()

    def coo(self) -> tuple[np.ndarray, np.ndarray]:
        c = self.csr.tocoo()
        return c.row.astype(np.int64), c.col.astype(np.int64)

    # nnz are identified by their CSR position: nz_id(i, k) = position of
    # (i, k) within the CSR data array.  This is the canonical net/vertex
    # numbering used by the hypergraph builders.
    def nz_ids(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """Map (row, col) coordinate arrays to CSR nonzero positions."""
        out = np.empty(len(rows), dtype=np.int64)
        indptr, indices = self.csr.indptr, self.csr.indices
        for n, (i, k) in enumerate(zip(rows, cols)):
            lo, hi = indptr[i], indptr[i + 1]
            pos = lo + np.searchsorted(indices[lo:hi], k)
            if pos >= hi or indices[pos] != k:
                raise KeyError(f"({i},{k}) not a nonzero")
            out[n] = pos
        return out

    def has_empty_rows_or_cols(self) -> bool:
        return bool((self.row_counts() == 0).any() or (self.col_counts() == 0).any())

    def __eq__(self, other: object) -> bool:  # structural equality
        if not isinstance(other, SparseStructure):
            return NotImplemented
        return (
            self.shape == other.shape
            and self.nnz == other.nnz
            and np.array_equal(self.indptr, other.indptr)
            and np.array_equal(self.indices, other.indices)
        )


def structure_fingerprint(s: SparseStructure) -> str:
    """Content hash of a nonzero structure, memoized on the object.

    Lives here (not in the jax-side runtime, which re-exports it) so the
    session's drift detection stays importable without a device stack.
    """
    fp = s.__dict__.get("_fingerprint")
    if fp is None:
        h = hashlib.sha1(f"{s.shape}".encode())
        h.update(np.ascontiguousarray(s.indptr))
        h.update(np.ascontiguousarray(s.indices))
        fp = h.hexdigest()
        object.__setattr__(s, "_fingerprint", fp)  # frozen dataclass
    return fp


def from_coo(rows, cols, shape) -> SparseStructure:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    m = sp.coo_matrix((np.ones(len(rows), dtype=bool), (rows, cols)), shape=shape)
    return SparseStructure.wrap(m)


def from_dense(arr) -> SparseStructure:
    return SparseStructure.wrap(sp.csr_matrix(np.asarray(arr) != 0))


def as_structure(x) -> SparseStructure:
    """Normalize to a ``SparseStructure``: accepts a structure (returned
    as-is), any scipy sparse matrix, or a dense array."""
    if isinstance(x, SparseStructure):
        return x
    if sp.issparse(x):
        return SparseStructure.wrap(sp.csr_matrix(x))
    return from_dense(x)


def structure_and_values(x) -> tuple[SparseStructure, np.ndarray]:
    """Normalize an operand to (structure, values-in-canonical-CSR-order).

    Accepts a dense ndarray, any scipy sparse matrix, or an
    ``(SparseStructure, values)`` pair whose values already follow the
    structure's CSR order — sparse callers never round-trip through dense.
    """
    if isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], SparseStructure):
        s, vals = x
        vals = np.asarray(vals)
        if vals.shape != (s.nnz,):
            raise ValueError(
                f"values shape {vals.shape} does not match structure nnz {s.nnz}"
            )
        return s, vals
    if sp.issparse(x):
        m = sp.csr_matrix(x, copy=True)
        m.sum_duplicates()
        m.sort_indices()
        return SparseStructure.wrap(m), np.asarray(m.data)
    m = sp.csr_matrix(np.asarray(x))
    return SparseStructure.wrap(m), np.asarray(m.data)


def random_structure(
    n_rows: int,
    n_cols: int,
    density: float,
    rng: np.random.Generator,
    ensure_nonempty: bool = True,
) -> SparseStructure:
    """Erdős–Rényi structure; optionally patch empty rows/cols (Sec. 3.1
    assumes no zero rows/columns in A or B)."""
    mask = rng.random((n_rows, n_cols)) < density
    if ensure_nonempty:
        for i in np.flatnonzero(~mask.any(axis=1)):
            mask[i, rng.integers(n_cols)] = True
        for j in np.flatnonzero(~mask.any(axis=0)):
            mask[rng.integers(n_rows), j] = True
    return from_dense(mask)


def spgemm_symbolic(a: SparseStructure, b: SparseStructure) -> SparseStructure:
    """S_C induced by S_A, S_B (no cancellation)."""
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    c = (a.csr.astype(np.int8) @ b.csr.astype(np.int8))
    return SparseStructure.wrap(c)


def nontrivial_multiplications(
    a: SparseStructure, b: SparseStructure
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All (i, k, j) with a_ik != 0 and b_kj != 0, ordered by k then by the
    CSR order within A's column k and B's row k.

    Returns (i, k, j) int64 arrays of length |V^m|.  This is the iteration
    space of Fig. 2 and the multiplication-vertex set of Def. 3.1.
    """
    acsc = a.tocsc()
    bcsr = b.csr
    K = a.shape[1]
    a_cnt = np.diff(acsc.indptr)  # nnz per column of A
    b_cnt = np.diff(bcsr.indptr)  # nnz per row of B
    per_k = a_cnt * b_cnt
    total = int(per_k.sum())
    ii = np.empty(total, dtype=np.int64)
    kk = np.empty(total, dtype=np.int64)
    jj = np.empty(total, dtype=np.int64)
    pos = 0
    for k in range(K):
        na, nb = int(a_cnt[k]), int(b_cnt[k])
        if na == 0 or nb == 0:
            continue
        rows = acsc.indices[acsc.indptr[k] : acsc.indptr[k + 1]]
        cols = bcsr.indices[bcsr.indptr[k] : bcsr.indptr[k + 1]]
        n = na * nb
        ii[pos : pos + n] = np.repeat(rows, nb)
        kk[pos : pos + n] = k
        jj[pos : pos + n] = np.tile(cols, na)
        pos += n
    return ii[:pos], kk[:pos], jj[:pos]


def flops(a: SparseStructure, b: SparseStructure) -> int:
    """|V^m| = number of nontrivial multiplications."""
    return int((a.col_counts() * b.row_counts()).sum())
