"""Block-sparse (BSR) tiling.

TPU adaptation layer: a b_r x b_c blocking of a sparse matrix is a vertex
coarsening of the SpGEMM hypergraph (DESIGN.md Sec. 3) and simultaneously the
storage format consumed by the Pallas kernels.  Blocks are stored dense; the
block index set is the coarsened nonzero structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.structure import SparseStructure, from_coo


@dataclasses.dataclass(frozen=True)
class BlockSparse:
    """BSR matrix: dense blocks at sparse block coordinates.

    blocks:   (n_blocks, b_r, b_c) float array
    brows:    (n_blocks,) block-row index
    bcols:    (n_blocks,) block-col index
    shape:    logical (padded) shape, multiples of (b_r, b_c)
    """

    blocks: np.ndarray
    brows: np.ndarray
    bcols: np.ndarray
    shape: tuple[int, int]

    @property
    def block_shape(self) -> tuple[int, int]:
        return self.blocks.shape[1], self.blocks.shape[2]

    @property
    def n_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def grid(self) -> tuple[int, int]:
        b_r, b_c = self.block_shape
        return self.shape[0] // b_r, self.shape[1] // b_c

    def block_structure(self) -> SparseStructure:
        """Coarsened nonzero structure over the block grid."""
        return from_coo(self.brows, self.bcols, self.grid)


def to_bsr(dense: np.ndarray, b_r: int, b_c: int) -> BlockSparse:
    """Tile a dense array, keeping only blocks with any nonzero."""
    m, n = dense.shape
    pm = (m + b_r - 1) // b_r * b_r
    pn = (n + b_c - 1) // b_c * b_c
    padded = np.zeros((pm, pn), dtype=dense.dtype)
    padded[:m, :n] = dense
    g_r, g_c = pm // b_r, pn // b_c
    tiles = padded.reshape(g_r, b_r, g_c, b_c).transpose(0, 2, 1, 3)
    nz = np.argwhere(np.abs(tiles).sum(axis=(2, 3)) != 0)
    if len(nz) == 0:
        nz = np.zeros((1, 2), dtype=np.int64)  # keep one block: static shapes
    brows, bcols = nz[:, 0], nz[:, 1]
    blocks = tiles[brows, bcols]
    return BlockSparse(blocks, brows.astype(np.int64), bcols.astype(np.int64), (pm, pn))


def bsr_to_dense(bsr: BlockSparse) -> np.ndarray:
    b_r, b_c = bsr.block_shape
    out = np.zeros(bsr.shape, dtype=bsr.blocks.dtype)
    for blk, i, j in zip(bsr.blocks, bsr.brows, bsr.bcols):
        out[i * b_r : (i + 1) * b_r, j * b_c : (j + 1) * b_c] += blk
    return out


def pad_blocks(bsr: BlockSparse, n_blocks: int) -> BlockSparse:
    """Pad the block list to a static count (inspector-executor: XLA sees a
    fixed shape; padding blocks are all-zero at block-coord (0, 0))."""
    if n_blocks < bsr.n_blocks:
        raise ValueError(f"cannot shrink {bsr.n_blocks} -> {n_blocks}")
    extra = n_blocks - bsr.n_blocks
    if extra == 0:
        return bsr
    b_r, b_c = bsr.block_shape
    blocks = np.concatenate(
        [bsr.blocks, np.zeros((extra, b_r, b_c), dtype=bsr.blocks.dtype)]
    )
    brows = np.concatenate([bsr.brows, np.zeros(extra, dtype=np.int64)])
    bcols = np.concatenate([bsr.bcols, np.zeros(extra, dtype=np.int64)])
    return BlockSparse(blocks, brows, bcols, bsr.shape)
