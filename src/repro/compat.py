"""jax version-compatibility shims.

The repo is written against the modern jax API surface (``jax.shard_map``,
``jax.set_mesh``, ``AxisType`` meshes).  Containers pin older jax (0.4.x)
where those either live under ``jax.experimental`` or do not exist; every
call site routes through this module so the rest of the codebase sees one
API regardless of the installed version.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (0.4.x).

    Replication checking is disabled in both spellings (``check_vma`` /
    ``check_rep``): the SpGEMM executors return per-device shards on purpose.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Install ``mesh`` as the ambient mesh.

    New jax has ``jax.set_mesh``; on 0.4.x the equivalent process-scoped
    state is the legacy ``Mesh`` context manager, entered and deliberately
    never exited (callers treat the ambient mesh as process-global).
    """
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
        return mesh
    mesh.__enter__()
    return mesh


def axis_size(axis_name):
    """``jax.lax.axis_size`` (new); a counting psum on 0.4.x."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def get_abstract_mesh():
    """The ambient mesh, or ``None`` when none is installed.

    Returns the abstract mesh on new jax and the physical mesh from the
    legacy context on 0.4.x — both expose ``axis_names`` and a name-keyed
    ``shape`` mapping, which is all the call sites use.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh
