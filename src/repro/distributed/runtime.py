"""Compile-once executor runtime: cached AOT executors, value-only updates.

The paper's amortization premise is that the partition — and therefore the
plan — is computed once and reused across many multiplications with the same
sparsity structure (AMG applies one partition across repeated Galerkin
products; MCL squares a same-structure matrix every iteration).  The
executors in ``spgemm_exec`` realize the plans correctly but, called naively
on dense operands, pay the full inspector bill on every invocation: dense ->
sparse round trips, per-call route-table uploads, and a fresh shard_map
trace + XLA compile per call (the executor closures are rebuilt each time,
so nothing caches).

``CompiledSpGEMM`` does all structure-time work exactly once per
(plan, operand structure, mesh, dtype, backend):

- host packing collapses to one vectorized owner/slot scatter-spec (the
  ``np.nonzero(local_ids >= 0)`` idiom), computed at construction;
- route tables, pair lists and scatter indices are uploaded once and baked
  into the program as compile-time constants;
- the whole executor (value scatter -> expand -> local compute -> reduce)
  is AOT-compiled via ``jax.jit(...).lower().compile()`` with the value
  buffers donated, so ``__call__(a_values, b_values)`` does zero host
  structure work and zero retracing — the steady-state cost is exactly the
  collectives plus local compute the plan prescribes.

Which packing closure, step builder and unpacker a plan gets is no longer
decided here: ``registry.ModelSpec.make_runner`` / ``.unpack`` are the single
declarative source — this module only owns the model-agnostic machinery
(fingerprints, AOT compile, donation, the bounded LRU).

Value conventions (``__call__`` inputs):

- rowwise / outer / fine: 1-D nonzero value vectors in the operands'
  canonical CSR order (``SparseStructure`` order — what
  ``structure_and_values`` returns);
- monoC: (nnz, b, b) block-value arrays in the *block* structure's CSR
  order (``to_bsr(...).blocks`` order).  The ``repro.api`` front door hides
  this behind ``ModelSpec.pack_values``.

``compile_spgemm`` memoizes executors in a bounded LRU keyed on
(plan fingerprint, structure fingerprints, mesh, dtype, backend, block,
axis names); the dense entry points in ``spgemm_exec`` are thin wrappers
that hit this cache on every same-structure call.  ``trace_count()`` exposes
a retrace counter so tests can pin "zero recompiles after warmup".
"""
from __future__ import annotations

import hashlib
import os
import warnings
from collections import OrderedDict

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.registry import get_spec
from repro.sparse.structure import (
    SparseStructure,
    structure_and_values,
    structure_fingerprint,
)
from repro.testing import faults

__all__ = [
    "CompiledSpGEMM",
    "batch_bucket",
    "compile_spgemm",
    "cache_clear",
    "cache_info",
    "plan_fingerprint",
    "structure_and_values",
    "structure_fingerprint",
    "trace_count",
]

# -- batch-size bucketing ----------------------------------------------------
#: geometric batch-capacity buckets (x2 from 1).  A batched executor is
#: compiled for a bucket CAPACITY, not a request count: ragged request
#: batches pad up to the same capacity and hit the same AOT executable —
#: the serving loop never retraces on batch-size jitter (the same idea as
#: the device partitioner's x1.5 shape buckets, PR 6).
BATCH_GROWTH = 2


def batch_bucket(n: int) -> int:
    """Smallest batch-capacity bucket holding ``n`` items (1, 2, 4, 8, ...)."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = 1
    while b < n:
        b *= BATCH_GROWTH
    return b

# -- retrace accounting ------------------------------------------------------
_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times any runtime executor body has been traced (== number
    of AOT compiles).  Stable across ``CompiledSpGEMM.__call__`` — the test
    hook for the zero-retrace claim."""
    return _TRACE_COUNT


def _mark_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


# -- fingerprints ------------------------------------------------------------
def plan_fingerprint(plan) -> str:
    """Content hash of a plan's executor-visible state, computed once and
    memoized on the plan object (id-stable: repeat lookups are O(1))."""
    fp = getattr(plan, "_fingerprint", None)
    if fp is None:
        h = hashlib.sha1(f"{plan.model}/{plan.p}".encode())
        for tag, group in (
            ("own", plan.ownership),
            ("loc", plan.local_ids),
            ("cmp", plan.compute),
        ):
            for k in sorted(group):
                h.update(f"{tag}:{k}".encode())
                h.update(np.ascontiguousarray(group[k]))
        for k in sorted(plan.routes):
            r = plan.routes[k]
            h.update(f"route:{k}:{r.word_size}".encode())
            h.update(np.ascontiguousarray(r.send_idx))
            h.update(np.ascontiguousarray(r.recv_key))
        fp = h.hexdigest()
        plan._fingerprint = fp
    return fp


def _mesh_key(mesh: Mesh) -> tuple:
    return (
        tuple(mesh.axis_names),
        tuple(int(mesh.shape[a]) for a in mesh.axis_names),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


# -- the compiled executor ---------------------------------------------------
class CompiledSpGEMM:
    """One AOT-compiled SpGEMM executor: structure work done, values only.

    Construction performs every structure-dependent step (scatter-spec
    build, constant upload, trace, lowering, XLA compile) by handing the
    plan's ``ModelSpec.make_runner`` the operand structures; ``__call__``
    takes nonzero value vectors and returns the executor's device-major
    C shards with no host structure work and no retracing.
    """

    def __init__(
        self,
        plan,
        a_structure: SparseStructure,
        b_structure: SparseStructure,
        mesh: Mesh,
        *,
        dtype=np.float32,
        backend: str | None = None,
        block: int = 1,
        axis: str = "x",
        axes: tuple[str, str] = ("x", "y"),
        c_structure: SparseStructure | None = None,
        batch: int | None = None,
    ):
        faults.fire("compile")
        if mesh.devices.size != plan.p:
            raise ValueError(
                f"plan is for p={plan.p} but mesh has {mesh.devices.size} devices"
            )
        if a_structure.shape[1] != b_structure.shape[0]:
            raise ValueError(
                f"inner dimensions disagree: {a_structure.shape} @ {b_structure.shape}"
            )
        if batch is not None and batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.plan = plan
        self.model = plan.model
        self.mesh = mesh
        self.dtype = np.dtype(dtype)
        self.block = block
        self.backend = backend
        self.c_structure = c_structure
        self.batch = batch
        dt = self.dtype

        spec = get_spec(plan.model)
        if spec.make_runner is None:
            raise ValueError(f"no runtime lowering for model {plan.model!r}")
        self.spec = spec
        setup = spec.make_setup(
            plan,
            a_structure,
            b_structure,
            mesh,
            dtype=dt,
            block=block,
            backend=backend,
            axis=axis,
            axes=axes,
            batch=batch,
        )
        self._I, self._J = setup.out_shape
        self._a_shape, self._b_shape = setup.a_shape, setup.b_shape
        run = setup.run

        def traced(a_values, b_values):
            _mark_trace()
            return run(a_values, b_values)

        with warnings.catch_warnings():
            # donation is best-effort: backends without it (CPU) warn per
            # compile, which would spam every cache miss
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            self._compiled = (
                jax.jit(traced, donate_argnums=(0, 1))
                .lower(
                    jax.ShapeDtypeStruct(setup.a_shape, dt),
                    jax.ShapeDtypeStruct(setup.b_shape, dt),
                )
                .compile()
            )

    def _coerce(self, x, shape, name: str):
        if isinstance(x, jax.Array):
            if x.dtype != self.dtype:
                x = x.astype(self.dtype)
        else:
            # host values stay numpy: the executable uploads a fresh buffer,
            # so donation never invalidates a caller-held array
            x = np.asarray(x, dtype=self.dtype)
        if x.shape != shape:
            raise ValueError(
                f"{name} values have shape {x.shape}, but this executor was "
                f"compiled for {shape} — same-structure updates only"
            )
        return x

    def __call__(self, a_values, b_values) -> jax.Array:
        """Value-only update: returns device-major C shards (the same layout
        the underlying ``*_spgemm`` executor returns; a leading batch axis
        when compiled with ``batch=n``).  Passing a jax.Array transfers
        ownership of its buffer (donation)."""
        faults.fire("execute")
        a = self._coerce(a_values, self._a_shape, "A")
        b = self._coerce(b_values, self._b_shape, "B")
        return self._compiled(a, b)

    def unpack(self, c_local) -> np.ndarray:
        """Scatter device-major C shards back to a dense (I, J) array (padded
        block-grid shape for monoC) via the model's registered unpacker.  A
        batched executor's shards carry a leading batch axis and unpack to
        (batch, I, J)."""
        if self.spec.needs_c_structure and self.c_structure is None:
            raise ValueError(f"unpacking a {self.model} result needs c_structure")
        shape = (self._I, self._J)
        if self.batch is None:
            return self.spec.unpack(c_local, self.plan, self.c_structure, shape)
        c_local = np.asarray(c_local)
        return np.stack(
            [
                self.spec.unpack(c_local[i], self.plan, self.c_structure, shape)
                for i in range(c_local.shape[0])
            ]
        )

    @property
    def cost_model_words(self) -> tuple[int, int]:
        """(ideal, padded) words per call — what the partition promised and
        what the padded routes actually move."""
        return self.plan.comm_words_ideal, self.plan.comm_words_padded


# -- bounded LRU cache -------------------------------------------------------
CACHE_SIZE = int(os.environ.get("REPRO_EXEC_CACHE_SIZE", "16"))
_CACHE: OrderedDict[tuple, CompiledSpGEMM] = OrderedDict()
_STATS = {"hits": 0, "misses": 0}


def _cache_key(
    plan, a_structure, b_structure, mesh, dtype, backend, block, axis, axes, batch
):
    return (
        plan_fingerprint(plan),
        structure_fingerprint(a_structure),
        structure_fingerprint(b_structure),
        _mesh_key(mesh),
        np.dtype(dtype).str,
        backend,
        block,
        axis,
        tuple(axes),
        batch,
    )


def compile_spgemm(
    plan,
    a_structure: SparseStructure,
    b_structure: SparseStructure,
    mesh: Mesh,
    *,
    dtype=np.float32,
    backend: str | None = None,
    block: int = 1,
    axis: str = "x",
    axes: tuple[str, str] = ("x", "y"),
    c_structure: SparseStructure | None = None,
    batch: int | None = None,
    cache: bool = True,
) -> CompiledSpGEMM:
    """Get (or build) the AOT executor for a plan + structure + mesh + dtype.

    Cache hits return the *same* ``CompiledSpGEMM`` object — same XLA
    executable, zero retracing.  ``batch=n`` compiles the vmapped executor
    for a fixed batch capacity (one more key dimension — callers should
    bucket ``n`` through ``batch_bucket`` so ragged request batches share an
    executable).  ``cache=False`` bypasses the LRU entirely (a fresh trace +
    compile: the rebuild-everything reference path the benchmarks compare
    against).
    """
    if not cache:
        return CompiledSpGEMM(
            plan, a_structure, b_structure, mesh, dtype=dtype, backend=backend,
            block=block, axis=axis, axes=axes, c_structure=c_structure,
            batch=batch,
        )
    key = _cache_key(
        plan, a_structure, b_structure, mesh, dtype, backend, block, axis, axes, batch
    )
    exe = _CACHE.get(key)
    if exe is not None:
        _CACHE.move_to_end(key)
        _STATS["hits"] += 1
        if exe.c_structure is None and c_structure is not None:
            exe.c_structure = c_structure
        return exe
    _STATS["misses"] += 1
    exe = CompiledSpGEMM(
        plan, a_structure, b_structure, mesh, dtype=dtype, backend=backend,
        block=block, axis=axis, axes=axes, c_structure=c_structure, batch=batch,
    )
    _CACHE[key] = exe
    while len(_CACHE) > CACHE_SIZE:
        _CACHE.popitem(last=False)
    return exe


def cache_info() -> dict:
    return {"size": len(_CACHE), "max_size": CACHE_SIZE, **_STATS}


def cache_clear() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0
