"""Inspector phase: lower a hypergraph partition to a static execution plan.

The partition of the row-wise (or outer-product) model decides ownership; the
plan materializes, with static padded shapes, exactly the data movement the
hypergraph cut prescribes:

- row-wise: device d owns row set R_d of A and C, and row set S_d of B (the
  partition of V^B, or round-robin when V^nz was omitted).  The expand phase
  sends B row k from its owner to every device whose A-columns touch k — one
  transfer per (cut net, touched part) pair, i.e. volume = sum_n c(n) *
  (lambda(n) - 1) plus padding.  Realized as a single padded all_to_all.
- outer-product: device d owns column set K_d of A and B-row set K_d; the
  fold phase routes partial C rows to C's owner.

All index arrays are padded to per-pair maxima so XLA sees static shapes; the
padding fraction is reported so benchmarks can quantify executor overhead vs
the combinatorial volume.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.spgemm_models import SpGEMMInstance


@dataclasses.dataclass
class RowwisePlan:
    p: int
    row_part: np.ndarray  # (I,) owner of each A/C row
    b_part: np.ndarray  # (K,) owner of each B row
    # per-device padded local row ids (I_max,) with -1 padding
    local_rows: np.ndarray  # (p, I_max)
    # expand-phase routing: send_idx[s, d, t] = local index (into s's B rows)
    # of the t-th B row device s ships to device d; -1 = padding
    send_idx: np.ndarray  # (p, p, T_max)
    # after the all_to_all, device d holds recv[s, t] slots; gather_idx maps
    # global B row k -> position in d's receive buffer (K,) per device
    recv_key: np.ndarray  # (p, p, T_max) global B-row id or -1
    local_b_rows: np.ndarray  # (p, K_max) B rows owned per device, -1 pad
    padding_fraction: float
    comm_words_ideal: int  # hypergraph connectivity volume (rows)
    comm_words_padded: int  # p*p*T_max actually shipped


def build_rowwise_plan(
    inst: SpGEMMInstance,
    row_part: np.ndarray,
    p: int,
    b_part: np.ndarray | None = None,
) -> RowwisePlan:
    I, K, J = inst.shape
    row_part = np.asarray(row_part, dtype=np.int64)
    if b_part is None:
        # default B distribution: round-robin rows (paper Sec. 6: V^nz omitted)
        b_part = np.arange(K, dtype=np.int64) % p
    # which devices need B row k: parts of A-column-k's rows
    acsc = inst.a.tocsc()
    need = [[] for _ in range(K)]  # destinations per B row
    for k in range(K):
        rows = acsc.indices[acsc.indptr[k] : acsc.indptr[k + 1]]
        devs = np.unique(row_part[rows])
        need[k] = [int(d) for d in devs]

    send_lists: dict[tuple[int, int], list[int]] = {}
    ideal = 0
    for k in range(K):
        src = int(b_part[k])
        for d in need[k]:
            if d == src:
                continue
            send_lists.setdefault((src, d), []).append(k)
            ideal += 1

    T_max = max((len(v) for v in send_lists.values()), default=0)
    T_max = max(T_max, 1)
    send_idx = np.full((p, p, T_max), -1, dtype=np.int64)
    recv_key = np.full((p, p, T_max), -1, dtype=np.int64)

    # local B-row numbering per device
    owned = [np.flatnonzero(b_part == d) for d in range(p)]
    K_max = max((len(o) for o in owned), default=1)
    K_max = max(K_max, 1)
    local_b_rows = np.full((p, K_max), -1, dtype=np.int64)
    local_of = np.full(K, -1, dtype=np.int64)
    for d in range(p):
        local_b_rows[d, : len(owned[d])] = owned[d]
        local_of[owned[d]] = np.arange(len(owned[d]))

    for (s, d), ks in send_lists.items():
        send_idx[s, d, : len(ks)] = local_of[np.array(ks)]
        recv_key[s, d, : len(ks)] = ks

    rows_by_dev = [np.flatnonzero(row_part == d) for d in range(p)]
    I_max = max((len(r) for r in rows_by_dev), default=1)
    I_max = max(I_max, 1)
    local_rows = np.full((p, I_max), -1, dtype=np.int64)
    for d in range(p):
        local_rows[d, : len(rows_by_dev[d])] = rows_by_dev[d]

    padded = p * p * T_max if ideal else 0
    return RowwisePlan(
        p=p,
        row_part=row_part,
        b_part=b_part,
        local_rows=local_rows,
        send_idx=send_idx,
        recv_key=recv_key,
        local_b_rows=local_b_rows,
        padding_fraction=(padded - ideal) / max(padded, 1),
        comm_words_ideal=ideal,
        comm_words_padded=padded,
    )


@dataclasses.dataclass
class OuterPlan:
    p: int
    k_part: np.ndarray  # (K,) owner of each A column / B row
    c_part: np.ndarray  # (I,) owner of each C row (fold destinations)
    local_ks: np.ndarray  # (p, K_max) columns owned per device, -1 pad
    comm_words_ideal: int  # fold volume in C entries (connectivity metric)


def build_outer_plan(
    inst: SpGEMMInstance,
    k_part: np.ndarray,
    p: int,
    c_part: np.ndarray | None = None,
) -> OuterPlan:
    I, K, J = inst.shape
    k_part = np.asarray(k_part, dtype=np.int64)
    if c_part is None:
        c_part = np.arange(I, dtype=np.int64) % p
    ks_by_dev = [np.flatnonzero(k_part == d) for d in range(p)]
    K_max = max(max((len(x) for x in ks_by_dev), default=1), 1)
    local_ks = np.full((p, K_max), -1, dtype=np.int64)
    for d in range(p):
        local_ks[d, : len(ks_by_dev[d])] = ks_by_dev[d]
    # ideal fold volume: per C nonzero, (#distinct contributing k-parts - 1)
    cpos = inst.mult_i * J + inst.mult_j
    pair = np.unique(cpos * p + k_part[inst.mult_k])
    lam = np.bincount(pair // p)
    ideal = int(np.maximum(lam[lam > 0] - 1, 0).sum())
    return OuterPlan(
        p=p,
        k_part=k_part,
        c_part=c_part,
        local_ks=local_ks,
        comm_words_ideal=ideal,
    )
