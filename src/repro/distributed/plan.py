"""Inspector phase: lower a hypergraph partition to a static execution plan.

The partition of a model decides ownership; the plan materializes, with
static padded shapes, exactly the data movement the hypergraph cut
prescribes.  The plan containers and the vectorized builders live in
``plan_ir`` (one ``ExecutionPlan`` IR for every model); this module
re-exports them and keeps the original loop-based row-wise inspector as an
executable specification — ``tests/test_plan_ir.py`` pins the vectorized
builder to it byte for byte, and ``benchmarks/bench_plan_build.py`` measures
the speedup.  ``build_rowwise_plan_loop`` is importable from here only: it
left the ``repro.distributed`` public surface in the api_redesign PR (a
once-warning shim covers old package-level imports).

- row-wise: device d owns row set R_d of A and C, and row set S_d of B (the
  partition of V^B, or round-robin when V^nz was omitted).  The expand phase
  sends B row k from its owner to every device whose A-columns touch k — one
  transfer per (cut net, touched part) pair, i.e. volume = sum_n c(n) *
  (lambda(n) - 1) plus padding.  Realized as a single padded all_to_all.
- outer-product: device d owns column set K_d of A and B-row set K_d; the
  fold phase routes partial C rows to C's owner.
- monochrome-C: device d owns a C-nonzero set; two expand phases ship the
  cut A- and B-nets, local compute streams BSR pair lists (see ``plan_ir``).

All index arrays are padded to per-pair maxima so XLA sees static shapes; the
padding fraction is reported so benchmarks can quantify executor overhead vs
the combinatorial volume.
"""
from __future__ import annotations

import numpy as np

from repro.core.spgemm_models import SpGEMMInstance
from repro.distributed.plan_ir import (  # noqa: F401  (re-exports)
    ExecutionPlan,
    MonoCPlan,
    OuterPlan,
    Route,
    RowwisePlan,
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
)

__all__ = [
    "ExecutionPlan",
    "Route",
    "RowwisePlan",
    "OuterPlan",
    "MonoCPlan",
    "build_rowwise_plan",
    "build_outer_plan",
    "build_monoC_plan",
    "build_rowwise_plan_loop",
]


def build_rowwise_plan_loop(
    inst: SpGEMMInstance,
    row_part: np.ndarray,
    p: int,
    b_part: np.ndarray | None = None,
) -> RowwisePlan:
    """Original per-k Python-loop inspector, kept as the executable
    specification of ``plan_ir.build_rowwise_plan`` (which must reproduce
    its routing tables byte for byte)."""
    I, K, J = inst.shape
    row_part = np.asarray(row_part, dtype=np.int64)
    if b_part is None:
        # default B distribution: round-robin rows (paper Sec. 6: V^nz omitted)
        b_part = np.arange(K, dtype=np.int64) % p
    # which devices need B row k: parts of A-column-k's rows
    acsc = inst.a.tocsc()
    need = [[] for _ in range(K)]  # destinations per B row
    for k in range(K):
        rows = acsc.indices[acsc.indptr[k] : acsc.indptr[k + 1]]
        devs = np.unique(row_part[rows])
        need[k] = [int(d) for d in devs]

    send_lists: dict[tuple[int, int], list[int]] = {}
    ideal = 0
    for k in range(K):
        src = int(b_part[k])
        for d in need[k]:
            if d == src:
                continue
            send_lists.setdefault((src, d), []).append(k)
            ideal += 1

    T_max = max((len(v) for v in send_lists.values()), default=0)
    T_max = max(T_max, 1)
    send_idx = np.full((p, p, T_max), -1, dtype=np.int64)
    recv_key = np.full((p, p, T_max), -1, dtype=np.int64)

    # local B-row numbering per device
    owned = [np.flatnonzero(b_part == d) for d in range(p)]
    K_max = max((len(o) for o in owned), default=1)
    K_max = max(K_max, 1)
    local_b_rows = np.full((p, K_max), -1, dtype=np.int64)
    local_of = np.full(K, -1, dtype=np.int64)
    for d in range(p):
        local_b_rows[d, : len(owned[d])] = owned[d]
        local_of[owned[d]] = np.arange(len(owned[d]))

    for (s, d), ks in send_lists.items():
        send_idx[s, d, : len(ks)] = local_of[np.array(ks)]
        recv_key[s, d, : len(ks)] = ks

    rows_by_dev = [np.flatnonzero(row_part == d) for d in range(p)]
    I_max = max((len(r) for r in rows_by_dev), default=1)
    I_max = max(I_max, 1)
    local_rows = np.full((p, I_max), -1, dtype=np.int64)
    for d in range(p):
        local_rows[d, : len(rows_by_dev[d])] = rows_by_dev[d]

    padded = p * p * T_max if ideal else 0
    return RowwisePlan(
        model="rowwise",
        p=p,
        ownership={"a_row": row_part, "b_row": np.asarray(b_part, dtype=np.int64)},
        local_ids={"a_row": local_rows, "b_row": local_b_rows},
        routes={
            "expand": Route(
                payload="B",
                send_idx=send_idx,
                recv_key=recv_key,
                items_ideal=ideal,
                items_padded=padded,
            )
        },
    )
