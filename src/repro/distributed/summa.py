"""Sparse SUMMA: the sparsity-*oblivious* 2D baseline the paper beats.

The seven hypergraph models ship exactly the cut-net traffic of a partition
tuned to the instance's sparsity.  The classic competitor — Sparse SUMMA
(Buluc & Gilbert, arXiv 1109.3739 / 1006.2183) — fixes the data
distribution up front and broadcasts whole sparse panels regardless of who
actually needs them:

- devices form a ``(pr, pc)`` grid, flattened row-major
  (``d = r * pc + c`` — the same flattening the monoC executor's
  two-axis ``all_to_all`` uses);
- A, B and C are distributed element-cyclically: ``A(i, k)`` lives on
  ``(i % pr, k % pc)``, ``B(k, j)`` on ``(k % pr, j % pc)``, ``C(i, j)``
  stays put on ``(i % pr, j % pc)`` (stationary C);
- the multiply runs in ``n_stages = lcm(pr, pc)`` pipelined stages: stage
  ``t`` broadcasts every A nonzero with ``k % n_stages == t`` along its
  mesh *row* (``pc - 1`` copies) and every such B nonzero along its mesh
  *column* (``pr - 1`` copies), then each device multiplies the panel pair
  into its owned C slots through the BSR kernel path.

Because the broadcast is oblivious, the analytic communication volume is
closed-form — ``nnz(A) * (pc - 1) + nnz(B) * (pr - 1)`` words — and the
per-stage ``Route`` tables enumerate exactly those transfers, so
``measured_route_words(plan) == summa_words_ideal(...)`` is the same
measured == predicted check the hypergraph models pass, with the
connectivity metric replaced by the closed form.  ``benchmarks/
bench_versus.py`` compares ``model="auto"`` against this baseline on the
application instances — the paper's headline claim as a live gate.

Planning here is pure numpy (jax only enters inside the runner/step
factories), matching the lazy-import contract of the rest of the
planning stack.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.spgemm_models import SpGEMMInstance
from repro.distributed.plan_ir import (
    ExecutionPlan,
    _table_slots,
    build_route,
    padded_id_lists,
)


class SummaPlan(ExecutionPlan):
    """Stationary-C Sparse SUMMA plan over a ``(pr, pc)`` device grid.

    Routes ``bcast_a_s{t}`` / ``bcast_b_s{t}`` hold the stage-``t`` panel
    broadcasts; ``pair_*_s{t}`` are the stage-``t`` BSR pair lists in the
    monoC slot-table convention (``[owned | received | zero]`` operand
    tables, owned-C slots plus one trailing garbage slot).
    """

    @property
    def pr(self) -> int:
        return int(self.stats["pr"])

    @property
    def pc(self) -> int:
        return int(self.stats["pc"])

    @property
    def n_stages(self) -> int:
        return int(self.stats["n_stages"])

    @property
    def a_part(self) -> np.ndarray:
        return self.ownership["a_nz"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_nz"]

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_nz"]

    @property
    def n_c_slots(self) -> int:
        """Local C slots incl. the trailing garbage slot padding pairs hit."""
        return self.local_ids["c_nz"].shape[1] + 1


def summa_words_ideal(
    inst: SpGEMMInstance, pr: int, pc: int, word_size: int = 1
) -> int:
    """Closed-form SUMMA volume: every A nonzero is broadcast to the other
    ``pc - 1`` columns of its mesh row, every B nonzero to the other
    ``pr - 1`` rows of its mesh column — sparsity of the *other* operand
    never enters (that obliviousness is the whole point of the baseline)."""
    return int((inst.a.nnz * (pc - 1) + inst.b.nnz * (pr - 1)) * word_size)


def summa_mesh_shape(p: int, inst: SpGEMMInstance | None = None) -> tuple[int, int]:
    """Pick the ``(pr, pc)`` factorization of ``p`` for an instance.

    With an instance in hand the aspect is chosen to minimize the analytic
    volume ``nnz(A) * (pc - 1) + nnz(B) * (pr - 1)`` (an A-heavy instance
    wants few columns, a B-heavy one few rows); without one, nearest-square.
    Ties break toward square, then toward more rows.
    """
    best = None
    for pr in range(1, p + 1):
        if p % pr:
            continue
        pc = p // pr
        vol = 0 if inst is None else summa_words_ideal(inst, pr, pc)
        key = (vol, abs(pr - pc), pc)
        if best is None or key < best[0]:
            best = (key, (pr, pc))
    return best[1]


def build_summa_plan(
    inst: SpGEMMInstance,
    p: int,
    pr: int | None = None,
    pc: int | None = None,
    word_size: int = 1,
) -> SummaPlan:
    """Lower an instance straight to a Sparse SUMMA plan (no partition).

    ``pr``/``pc`` default to ``summa_mesh_shape(p, inst)``.  The stage count
    is ``lcm(pr, pc)`` so the element-cyclic owner maps stay pure 2D cyclic
    (``t(k) % pc == k % pc`` and ``t(k) % pr == k % pr``).
    """
    if pr is None or pc is None:
        pr, pc = summa_mesh_shape(p, inst)
    if pr * pc != p:
        raise ValueError(f"(pr, pc) = ({pr}, {pc}) does not factor p = {p}")
    S = math.lcm(pr, pc)
    nA, nB, nC = inst.a.nnz, inst.b.nnz, inst.c.nnz
    ar, ak = inst.a.coo()
    bk, bj = inst.b.coo()
    cr, cj = inst.c.coo()

    a_part = (ar % pr) * pc + ak % pc
    b_part = (bk % pr) * pc + bj % pc
    c_part = (cr % pr) * pc + cj % pc
    local_a, local_of_a = padded_id_lists(a_part, p)
    local_b, local_of_b = padded_id_lists(b_part, p)
    local_c, local_of_c = padded_id_lists(c_part, p)
    A_max, B_max, C_max = local_a.shape[1], local_b.shape[1], local_c.shape[1]

    def _broadcast_route(ids, owner_rc, along_cols, payload):
        """Oblivious broadcast of the stage panel: each item goes from its
        owner to the other ``w - 1`` positions of its mesh row (A) or
        column (B).  Item-major by construction (ids ascend)."""
        rr, cc = owner_rc
        w = pc if along_cols else pr
        lane = np.broadcast_to(np.arange(w, dtype=np.int64), (len(ids), w))
        keep = lane != (cc if along_cols else rr)[:, None]
        if along_cols:
            dst = ((rr[:, None] * pc) + lane)[keep]
        else:
            dst = ((lane * pc) + cc[:, None])[keep]
        src = np.repeat(rr * pc + cc, w - 1)
        item = np.repeat(ids, w - 1)
        local_of = local_of_a if payload == "A" else local_of_b
        return build_route(src, dst, item, local_of, p, payload, word_size)

    a_stage = ak % S
    b_stage = bk % S
    mult_stage = inst.mult_k % S
    mult_dev = (inst.mult_i % pr) * pc + inst.mult_j % pc
    a_pos, b_pos, c_pos = inst.mult_a_pos, inst.mult_b_pos, inst.mult_c_pos

    routes, compute = {}, {}
    n_pairs = 0
    for t in range(S):
        ids_a = np.nonzero(a_stage == t)[0]
        route_a = _broadcast_route(ids_a, (ar[ids_a] % pr, ak[ids_a] % pc), True, "A")
        ids_b = np.nonzero(b_stage == t)[0]
        route_b = _broadcast_route(ids_b, (bk[ids_b] % pr, bj[ids_b] % pc), False, "B")
        routes[f"bcast_a_s{t}"] = route_a
        routes[f"bcast_b_s{t}"] = route_b

        # stage-t pair lists: every multiplication whose k falls in this
        # panel runs on the (stationary) owner of its C nonzero, reading the
        # [owned | received | zero] tables the stage broadcasts filled
        a_slots = _table_slots(a_part, local_of_a, route_a, nA, p)
        b_slots = _table_slots(b_part, local_of_b, route_b, nB, p)
        sel = np.nonzero(mult_stage == t)[0]
        dev = mult_dev[sel]
        pa = a_slots[dev, a_pos[sel]]
        pb = b_slots[dev, b_pos[sel]]
        pcs = local_of_c[c_pos[sel]]
        assert (pa >= 0).all() and (pb >= 0).all(), (
            "SUMMA broadcast missed a needed nonzero"
        )
        order = np.lexsort((pb, pa, pcs, dev))
        pa, pb, pcs, dev = pa[order], pb[order], pcs[order], dev[order]
        counts = np.bincount(dev, minlength=p)
        P_max = max(int(counts.max(initial=0)), 1)
        starts = np.cumsum(counts) - counts
        rank = np.arange(len(dev), dtype=np.int64) - np.repeat(starts, counts)
        pair_a = np.full((p, P_max), A_max + p * route_a.T, dtype=np.int64)
        pair_b = np.full((p, P_max), B_max + p * route_b.T, dtype=np.int64)
        pair_c = np.full((p, P_max), C_max, dtype=np.int64)
        pair_a[dev, rank] = pa
        pair_b[dev, rank] = pb
        pair_c[dev, rank] = pcs
        compute[f"pair_a_s{t}"] = pair_a
        compute[f"pair_b_s{t}"] = pair_b
        compute[f"pair_c_s{t}"] = pair_c
        n_pairs += int(len(dev))

    plan = SummaPlan(
        model="summa2d",
        p=p,
        ownership={"a_nz": a_part, "b_nz": b_part, "c_nz": c_part},
        local_ids={"a_nz": local_a, "b_nz": local_b, "c_nz": local_c},
        routes=routes,
        compute=compute,
        stats={
            "pr": int(pr),
            "pc": int(pc),
            "n_stages": int(S),
            "n_pairs": n_pairs,
            "words_analytic": summa_words_ideal(inst, pr, pc, word_size),
        },
    )
    assert plan.comm_words_ideal == plan.stats["words_analytic"], (
        "stage routes diverged from the closed-form SUMMA volume"
    )
    assert n_pairs == inst.n_mult, "stage pair lists dropped a multiplication"
    return plan


def _lower_summa(inst: SpGEMMInstance, parts, p: int) -> SummaPlan:
    """Registry lowerer: SUMMA is partition-free, ``parts`` is ignored
    (``None`` from the front door — there is no hypergraph to partition)."""
    return build_summa_plan(inst, p)


def make_summa_step(
    plan: SummaPlan,
    mesh,
    block: int = 1,
    backend: str | None = None,
    axes: tuple[str, str] = ("x", "y"),
):
    """Jit-compatible SUMMA executor core.

    Returns ``fn(a_own, b_own) -> c_local`` over device-major packed block
    tables ``(p, N_max, b, b)``.  The stage loop is unrolled in Python —
    ``n_stages`` is a small compile-time constant (``lcm(pr, pc)``), so the
    whole pipeline AOT-compiles to one executable and each stage is the
    monoC expand (gather -> flattened two-axis ``all_to_all`` -> concat)
    followed by a BSR pair-list multiply accumulated into the owned C slots.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.distributed.spgemm_exec import _take0
    from repro.kernels.bsr_spgemm import bsr_spgemm_local

    p = plan.p
    S = plan.n_stages
    n_c_slots = plan.n_c_slots
    stage_T = []
    consts = []
    for t in range(S):
        route_a = plan.routes[f"bcast_a_s{t}"]
        route_b = plan.routes[f"bcast_b_s{t}"]
        stage_T.append((route_a.T, route_b.T))
        consts += [
            jnp.asarray(route_a.send_idx),
            jnp.asarray(route_b.send_idx),
            jnp.asarray(plan.compute[f"pair_a_s{t}"], jnp.int32),
            jnp.asarray(plan.compute[f"pair_b_s{t}"], jnp.int32),
            jnp.asarray(plan.compute[f"pair_c_s{t}"], jnp.int32),
        ]

    def expand(own, send_idx_blk, T):
        buf = _take0(own, send_idx_blk.reshape(-1)).reshape(p, T, block, block)
        recv = jax.lax.all_to_all(
            buf[None], axes, split_axis=1, concat_axis=1, tiled=False
        )[0]
        zero = jnp.zeros((1, block, block), own.dtype)
        return jnp.concatenate([own, recv.reshape(p * T, block, block), zero], 0)

    def step(a_blk, b_blk, *tabs):
        a_own, b_own = a_blk[0], b_blk[0]
        c = jnp.zeros((n_c_slots, block, block), a_own.dtype)
        for t in range(S):
            sa_, sb_, pa_, pb_, pc_ = tabs[5 * t : 5 * t + 5]
            T_a, T_b = stage_T[t]
            a_tab = expand(a_own, sa_[0], T_a)
            b_tab = expand(b_own, sb_[0], T_b)
            c = c + bsr_spgemm_local(
                a_tab, b_tab, pa_[0], pb_[0], pc_[0],
                n_c_blocks=n_c_slots, backend=backend,
            )
        return c[None]

    spec = P(axes)
    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(spec,) * (2 + 5 * S),
        out_specs=spec,
    )

    def fn(a_own, b_own):
        return shard(a_own, b_own, *consts)

    return fn


def _summa_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    """Registry runner factory (monoC value layout: ``(nnz, b, b)`` blocks
    scattered into device-major owned tables)."""
    import jax.numpy as jnp

    from repro.distributed.registry import RunnerSetup, owner_slot

    p = plan.p
    I, _ = a_structure.shape
    _, J = b_structure.shape
    nA, nB = a_structure.nnz, b_structure.nnz
    if nA != len(plan.a_part) or nB != len(plan.b_part):
        raise ValueError("plan was built for a different nonzero structure")
    adev, aslot = owner_slot(plan.local_ids["a_nz"], nA)
    bdev, bslot = owner_slot(plan.local_ids["b_nz"], nB)
    N_a = plan.local_ids["a_nz"].shape[1]
    N_b = plan.local_ids["b_nz"].shape[1]
    a_idx = (jnp.asarray(adev), jnp.asarray(aslot))
    b_idx = (jnp.asarray(bdev), jnp.asarray(bslot))
    step = make_summa_step(plan, mesh, block=block, backend=backend, axes=axes)

    def run(a_values, b_values):
        a_own = jnp.zeros((p, N_a, block, block), dtype).at[a_idx].set(a_values)
        b_own = jnp.zeros((p, N_b, block, block), dtype).at[b_idx].set(b_values)
        return step(a_own, b_own)

    return RunnerSetup(
        run, (nA, block, block), (nB, block, block), (I * block, J * block)
    )
