"""Distributed SpGEMM executors (shard_map) + inspector-executor planning."""
from repro.distributed.plan import RowwisePlan, build_rowwise_plan, OuterPlan, build_outer_plan
from repro.distributed.spgemm_exec import (
    rowwise_spgemm,
    outer_product_spgemm,
    spsumma,
)

__all__ = [
    "RowwisePlan",
    "build_rowwise_plan",
    "OuterPlan",
    "build_outer_plan",
    "rowwise_spgemm",
    "outer_product_spgemm",
    "spsumma",
]
