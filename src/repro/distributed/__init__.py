"""Distributed SpGEMM executors (shard_map) + inspector-executor planning.

The supported public surface is listed in ``__all__``; the declarative
``ModelSpec`` registry (``repro.distributed.registry``) is the single
source for which models lower to executors and how.  Attributes resolve
lazily (PEP 562) so importing the planning-side modules (``registry``,
``plan_ir``, ``select`` — pure numpy/scipy) never drags jax in; only
touching an executor or the runtime does.

The loop-based reference builder ``build_rowwise_plan_loop`` is
deliberately *not* part of the public surface anymore — it remains
importable from ``repro.distributed.plan`` for the byte-identical pin
test, and accessing it through this package emits a one-time
DeprecationWarning.
"""
from __future__ import annotations

import importlib

__all__ = [
    "CompiledSpGEMM",
    "SpGEMMSession",
    "compile_spgemm",
    "ExecutionPlan",
    "Route",
    "RowwisePlan",
    "OuterPlan",
    "MonoCPlan",
    "FinePlan",
    "SummaPlan",
    "ModelSpec",
    "MODEL_SPECS",
    "executable_models",
    "get_spec",
    "build_rowwise_plan",
    "build_outer_plan",
    "build_monoC_plan",
    "build_fine_plan",
    "build_volume_plan",
    "derive_owner_from_pins",
    "measured_route_words",
    "plan_fine_from_dense",
    "plan_monoC_from_dense",
    "build_summa_plan",
    "summa_words_ideal",
    "rowwise_spgemm",
    "outer_product_spgemm",
    "monoC_spgemm",
    "fine_spgemm",
    "spsumma",
]

_HOME = {
    "repro.distributed.plan_ir": (
        "ExecutionPlan",
        "FinePlan",
        "MonoCPlan",
        "OuterPlan",
        "Route",
        "RowwisePlan",
        "build_fine_plan",
        "build_monoC_plan",
        "build_outer_plan",
        "build_rowwise_plan",
        "build_volume_plan",
        "derive_owner_from_pins",
        "measured_route_words",
        "plan_fine_from_dense",
        "plan_monoC_from_dense",
    ),
    "repro.distributed.registry": (
        "MODEL_SPECS",
        "ModelSpec",
        "executable_models",
        "get_spec",
    ),
    "repro.distributed.runtime": ("CompiledSpGEMM", "compile_spgemm"),
    "repro.distributed.summa": (
        "SummaPlan",
        "build_summa_plan",
        "summa_words_ideal",
    ),
    "repro.distributed.session": ("SpGEMMSession",),
    "repro.distributed.spgemm_exec": (
        "fine_spgemm",
        "monoC_spgemm",
        "outer_product_spgemm",
        "rowwise_spgemm",
        "spsumma",
    ),
}
_EXPORT_TO_MODULE = {name: mod for mod, names in _HOME.items() for name in names}
assert set(_EXPORT_TO_MODULE) == set(__all__), "lazy export table out of sync"

_DEPRECATION_WARNED = False


def __getattr__(name: str):
    # deprecation shim (warn once): the loop reference left the public
    # surface in the api_redesign PR but old call sites keep working
    if name == "build_rowwise_plan_loop":
        global _DEPRECATION_WARNED
        if not _DEPRECATION_WARNED:
            import warnings

            warnings.warn(
                "repro.distributed.build_rowwise_plan_loop is deprecated; "
                "import it from repro.distributed.plan (it is a loop-based "
                "reference implementation, not a supported entry point)",
                DeprecationWarning,
                stacklevel=2,
            )
            _DEPRECATION_WARNED = True
        from repro.distributed.plan import build_rowwise_plan_loop

        return build_rowwise_plan_loop
    module = _EXPORT_TO_MODULE.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value  # cache: later lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
