"""Distributed SpGEMM executors (shard_map) + inspector-executor planning."""
from repro.distributed.plan_ir import (
    ExecutionPlan,
    MonoCPlan,
    OuterPlan,
    Route,
    RowwisePlan,
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
)
from repro.distributed.plan import build_rowwise_plan_loop
from repro.distributed.spgemm_exec import (
    monoC_spgemm,
    outer_product_spgemm,
    rowwise_spgemm,
    spsumma,
)

__all__ = [
    "ExecutionPlan",
    "Route",
    "RowwisePlan",
    "OuterPlan",
    "MonoCPlan",
    "build_rowwise_plan",
    "build_rowwise_plan_loop",
    "build_outer_plan",
    "build_monoC_plan",
    "rowwise_spgemm",
    "outer_product_spgemm",
    "monoC_spgemm",
    "spsumma",
]
