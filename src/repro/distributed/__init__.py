"""Distributed SpGEMM executors (shard_map) + inspector-executor planning."""
from repro.distributed.plan_ir import (
    ExecutionPlan,
    FinePlan,
    MonoCPlan,
    OuterPlan,
    Route,
    RowwisePlan,
    build_fine_plan,
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
    build_volume_plan,
    derive_owner_from_pins,
    plan_fine_from_dense,
    plan_monoC_from_dense,
)
from repro.distributed.plan import build_rowwise_plan_loop
from repro.distributed.runtime import CompiledSpGEMM, compile_spgemm
from repro.distributed.spgemm_exec import (
    fine_spgemm,
    monoC_spgemm,
    outer_product_spgemm,
    rowwise_spgemm,
    spsumma,
)

__all__ = [
    "CompiledSpGEMM",
    "compile_spgemm",
    "ExecutionPlan",
    "Route",
    "RowwisePlan",
    "OuterPlan",
    "MonoCPlan",
    "FinePlan",
    "build_rowwise_plan",
    "build_rowwise_plan_loop",
    "build_outer_plan",
    "build_monoC_plan",
    "build_fine_plan",
    "build_volume_plan",
    "derive_owner_from_pins",
    "plan_fine_from_dense",
    "plan_monoC_from_dense",
    "rowwise_spgemm",
    "outer_product_spgemm",
    "monoC_spgemm",
    "fine_spgemm",
    "spsumma",
]
