"""Resilient SpGEMM sessions: drift-aware replanning over a persistent pool.

The paper's amortization story assumes the sparsity structure holds still.
The workloads it benchmarks don't: MCL prunes the matrix every iteration,
AMG's Galerkin products change structure per level.  ``SpGEMMSession`` is
the long-lived handle for those loops — it wraps the ``repro.plan()``
pipeline and the AOT runtime into one object that survives structure drift,
stage failures, and process restarts:

- **Drift detection.**  Every ``multiply(A, B)`` fingerprints the operand
  structures (``sparse.structure.structure_fingerprint``).  An unchanged
  pair hits the warm executor pool (zero planning, zero retracing); a
  changed pair triggers a replan that *warm-starts* the partitioner from
  the previous labels: old vertices are matched to new ones by canonical
  per-model keys (row index, column index, (i,k,j) multiplication triple,
  (row,col) C coordinate), the surviving labels seed
  ``partition(..., warm_start=...)``, and cold partitioning runs only when
  drift exceeds the threshold or the warm result is infeasible.

- **Persistence.**  With ``store_dir`` set, every planned entry is written
  through ``checkpoint.save_plan`` (atomic, checksummed, versioned).  A
  restarted session rebuilds its warm pool from disk: restored plans are
  content-identical, so their fingerprints match and compilation hits the
  process-wide executor LRU — no re-partitioning, no retracing.  Corrupt
  entries are quarantined by the store and simply replanned.

- **Fault policy.**  A ``resilience.FaultPolicy`` governs every stage:
  transient failures (per ``is_retryable``) are retried with backoff;
  persistent partition failures walk the engine chain (device -> flat);
  persistent compile/execute failures walk the model chain
  (fine -> monoC -> rowwise), replanning with the cheaper model.  Every
  decision is recorded on ``session.events`` so tests and benchmarks can
  assert exactly what happened.

The session object itself stays jax-free until an entry is compiled — the
planning side (fingerprints, partitioning, plan lowering, the store) runs
without a device stack.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter, OrderedDict

import numpy as np

from repro.resilience import FaultPolicy, retry_call
from repro.sparse.structure import structure_and_values, structure_fingerprint

__all__ = ["SessionEvent", "SpGEMMSession"]


@dataclasses.dataclass
class SessionEvent:
    """One recorded session decision (pool hit, replan, retry, downgrade...)."""

    kind: str  # hit | warm_replan | cold_replan | restored | saved | evict |
    # retry | engine_fallback | model_downgrade | store_error
    key: str  # structure-pair key the decision applies to
    model: str | None = None
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Entry:
    """One warm pool slot: a planned + compiled pipeline and the label/key
    arrays future drifted structures warm-start from."""

    key: str
    model: str
    planned: object  # api.PlannedSpGEMM
    exe: object  # api.CompiledSpGEMM
    labels: np.ndarray  # partition of the model's vertices
    vertex_keys: np.ndarray  # canonical per-vertex match keys
    shape: tuple[int, int, int]


def _vertex_keys(inst, model: str) -> np.ndarray:
    """Canonical global id per partition vertex — the drift-stable identity
    used to carry labels across structure changes.  Vertices present in both
    the old and new structure (same row / column / multiplication / C
    coordinate) keep their label; everything else is placed fresh."""
    I, K, J = inst.shape
    if model == "rowwise":
        return np.arange(I, dtype=np.int64)
    if model == "outer":
        return np.arange(K, dtype=np.int64)
    if model == "fine":
        return (inst.mult_i * K + inst.mult_k) * J + inst.mult_j
    if model == "monoC":
        rows, cols = inst.c.coo()
        return rows * J + cols
    raise ValueError(f"no warm-start vertex keys for model {model!r}")


def _map_labels(
    old_keys: np.ndarray, old_labels: np.ndarray, new_keys: np.ndarray
) -> np.ndarray:
    """Carry labels from old vertices to new ones by key match; unmatched
    new vertices get -1 (the partitioner's 'place me fresh' marker)."""
    out = np.full(len(new_keys), -1, dtype=np.int64)
    if len(old_keys) == 0 or len(new_keys) == 0:
        return out
    order = np.argsort(old_keys, kind="stable")
    sorted_keys = old_keys[order]
    pos = np.searchsorted(sorted_keys, new_keys)
    pos = np.minimum(pos, len(sorted_keys) - 1)
    hit = sorted_keys[pos] == new_keys
    out[hit] = old_labels[order][pos[hit]]
    return out


class SpGEMMSession:
    """Failure-tolerant handle for iterated, structure-drifting SpGEMM.

    Construct via ``repro.session(...)``.  ``multiply(A, B)`` returns the
    dense product; everything else (planning, warm-starting, compiling,
    persisting, retrying, downgrading) happens behind it and is visible on
    ``session.events`` / ``session.stats()``.
    """

    def __init__(
        self,
        p: int = 8,
        model: str = "auto",
        eps: float = 0.10,
        seed: int = 0,
        engine: str = "flat",
        store_dir: str | None = None,
        policy: FaultPolicy | None = None,
        warm_drift_limit: float = 0.5,
        max_entries: int = 8,
        dtype=np.float32,
    ):
        self.p = p
        self.model = model
        self.eps = eps
        self.seed = seed
        self.engine = engine
        self.store_dir = store_dir
        self.policy = policy or FaultPolicy()
        self.warm_drift_limit = warm_drift_limit
        self.max_entries = max_entries
        self.dtype = np.dtype(dtype)
        self.events: list[SessionEvent] = []
        self._pool: OrderedDict[str, _Entry] = OrderedDict()
        self._last: _Entry | None = None
        # "auto" resolves on the first plan and then stays put: re-selecting
        # per drifted structure would defeat warm-starting (labels only carry
        # within one model's vertex space)
        self._model_resolved: str | None = None if model == "auto" else model

    # -- public API --------------------------------------------------------
    def entry_for(self, a_s, b_s) -> _Entry:
        """The warm pool entry for a structure pair, planning/restoring as
        needed (and classifying the access as hit / restored / warm_replan /
        cold_replan on ``events``).  This is the session's planning half —
        ``multiply`` executes through it, and the serving loop
        (``repro.launch.serve``) batches through it."""
        key = self._key(a_s, b_s)
        entry = self._pool.get(key)
        if entry is not None:
            self._pool.move_to_end(key)
            self._event("hit", key, entry.model)
        else:
            from repro.core.spgemm_models import SpGEMMInstance

            inst = SpGEMMInstance.from_operands(a_s, b_s, name="session")
            entry = self._restore(key, inst)
            if entry is None:
                entry = self._plan_entry(key, inst)
                self._persist(entry)
            self._admit(entry)
        self._last = entry
        return entry

    def multiply(self, A, B) -> np.ndarray:
        """Dense C = A @ B, planning/compiling/restoring only as needed.

        ``A`` / ``B`` are dense arrays, scipy sparse matrices, or
        ``(SparseStructure, values)`` pairs (values in canonical CSR order).
        """
        a_s, a_vals = structure_and_values(A)
        b_s, b_vals = structure_and_values(B)
        entry = self.entry_for(a_s, b_s)
        c = self._execute(entry, a_vals, b_vals, entry.key)
        self._last = self._pool.get(entry.key, self._last)
        return c

    __call__ = multiply

    def stats(self) -> dict:
        """Event counts + pool occupancy — the session's accounting view."""
        counts = Counter(e.kind for e in self.events)
        return {
            "pool_size": len(self._pool),
            "model": self._model_resolved or self.model,
            "events": dict(counts),
        }

    # -- internals ---------------------------------------------------------
    def _event(self, kind: str, key: str, model: str | None = None, **detail):
        ev = SessionEvent(kind=kind, key=key, model=model, detail=detail)
        self.events.append(ev)
        return ev

    def _on_retry(self, stage: str, attempt: int, exc: BaseException):
        self._event("retry", "", None, stage=stage, attempt=attempt, error=repr(exc))

    def _key(self, a_s, b_s) -> str:
        ident = (
            f"{structure_fingerprint(a_s)}/{structure_fingerprint(b_s)}"
            f"/p={self.p}/model={self.model}/eps={self.eps!r}/seed={self.seed}"
        )
        return hashlib.sha1(ident.encode()).hexdigest()

    def _admit(self, entry: _Entry) -> None:
        self._pool[entry.key] = entry
        self._pool.move_to_end(entry.key)
        while len(self._pool) > self.max_entries:
            old_key, old = self._pool.popitem(last=False)
            # the plan survives on disk (if a store is configured) and the
            # executable in the runtime LRU; only the pool slot is reclaimed
            self._event("evict", old_key, old.model)

    # -- planning ----------------------------------------------------------
    def _plan_entry(self, key: str, inst) -> _Entry:
        """Plan + compile an entry, walking the model downgrade chain on
        persistent failures."""
        start = self._model_resolved or self.model
        models = [start, *self.policy.downgrades(start, self.policy.model_chain)]
        last_exc: BaseException | None = None
        for i, model in enumerate(models):
            if i:
                self._event(
                    "model_downgrade",
                    key,
                    model,
                    from_model=models[i - 1],
                    error=repr(last_exc),
                )
            try:
                return self._build_entry(key, inst, model)
            except Exception as exc:
                last_exc = exc
        raise last_exc

    def _build_entry(self, key: str, inst, model: str) -> _Entry:
        warm_labels, drift = self._warm_labels(inst, model)
        planned = self._plan_model(key, inst, model, warm_labels)
        self._model_resolved = planned.model
        exe = retry_call(
            lambda: planned.compile(dtype=self.dtype),
            self.policy,
            stage="compile",
            on_retry=self._on_retry,
        )
        warm = bool(getattr(planned.partition, "warm", False))
        self._event(
            "warm_replan" if warm else "cold_replan",
            key,
            planned.model,
            drift=drift,
            connectivity=int(planned.partition.connectivity),
        )
        return _Entry(
            key=key,
            model=planned.model,
            planned=planned,
            exe=exe,
            labels=np.asarray(planned.partition.parts),
            vertex_keys=_vertex_keys(inst, planned.model),
            shape=tuple(inst.shape),
        )

    def _plan_model(self, key: str, inst, model: str, warm_labels):
        """Run the planning pipeline, walking the engine downgrade chain on
        persistent partitioner failures."""
        from repro import api

        engines = [
            self.engine,
            *self.policy.downgrades(self.engine, self.policy.engine_chain),
        ]
        last_exc: BaseException | None = None
        for i, eng in enumerate(engines):
            if i:
                self._event(
                    "engine_fallback", key, model, engine=eng, error=repr(last_exc)
                )

            def attempt(eng=eng):
                if model == "auto":
                    return api.plan(
                        inst,
                        p=self.p,
                        model="auto",
                        eps=self.eps,
                        seed=self.seed,
                        engine=eng,
                    )
                return api._plan_one(
                    inst,
                    model,
                    self.p,
                    self.eps,
                    self.seed,
                    include_nz=False,
                    engine=eng,
                    warm_start=warm_labels,
                    warm_drift_limit=self.warm_drift_limit,
                )

            try:
                return retry_call(
                    attempt, self.policy, stage="partition", on_retry=self._on_retry
                )
            except Exception as exc:
                last_exc = exc
        raise last_exc

    def _warm_labels(self, inst, model: str):
        """Map a previous entry's labels onto this instance's vertex set.
        Returns (labels-with--1-holes | None, drift fraction | None).

        Candidates are the last-touched entry plus every pool entry with the
        same model and shape, most recent first; the one with the lowest
        drift wins.  Searching the pool (not just ``_last``) matters for
        serving traffic, where several structures interleave and the drifted
        request's true predecessor is rarely the last entry touched."""
        if model == "auto":
            return None, None
        shape = tuple(inst.shape)
        candidates, seen = [], set()
        for ent in (self._last, *reversed(self._pool.values())):
            if ent is None or id(ent) in seen:
                continue
            seen.add(id(ent))
            if ent.model == model and ent.shape == shape:
                candidates.append(ent)
        if not candidates:
            return None, None
        new_keys = _vertex_keys(inst, model)
        best_labels, best_drift = None, None
        for ent in candidates:
            labels = _map_labels(ent.vertex_keys, ent.labels, new_keys)
            drift = float((labels < 0).mean()) if len(labels) else 1.0
            if best_drift is None or drift < best_drift:
                best_labels, best_drift = labels, drift
                if drift == 0.0:
                    break
        return best_labels, best_drift

    # -- execution ---------------------------------------------------------
    def _execute(self, entry: _Entry, a_vals, b_vals, key: str) -> np.ndarray:
        try:
            return retry_call(
                lambda: entry.exe(a_vals, b_vals),
                self.policy,
                stage="execute",
                on_retry=self._on_retry,
            )
        except Exception as exc:
            # persistent execute failure: replan with the next model down
            last_exc = exc
            inst = entry.planned.instance
            prev_model = entry.model
            for model in self.policy.downgrades(entry.model, self.policy.model_chain):
                self._event(
                    "model_downgrade",
                    key,
                    model,
                    from_model=prev_model,
                    error=repr(last_exc),
                )
                try:
                    entry2 = self._build_entry(key, inst, model)
                    c = retry_call(
                        lambda: entry2.exe(a_vals, b_vals),
                        self.policy,
                        stage="execute",
                        on_retry=self._on_retry,
                    )
                except Exception as exc2:
                    last_exc = exc2
                    prev_model = model
                    continue
                self._model_resolved = entry2.model
                self._admit(entry2)
                self._persist(entry2)
                return c
            raise last_exc

    # -- persistence -------------------------------------------------------
    def _persist(self, entry: _Entry) -> None:
        if self.store_dir is None or entry.planned.execution_plan is None:
            return
        from repro.checkpoint import save_plan

        meta = {
            "model": entry.model,
            "p": self.p,
            "eps": self.eps,
            "seed": self.seed,
            "shape": list(entry.shape),
            "connectivity": int(entry.planned.partition.connectivity),
        }
        try:
            retry_call(
                lambda: save_plan(
                    self.store_dir,
                    entry.key,
                    entry.planned.execution_plan,
                    arrays={
                        "labels": entry.labels,
                        "vertex_keys": entry.vertex_keys,
                    },
                    meta=meta,
                ),
                self.policy,
                stage="store_save",
                on_retry=self._on_retry,
            )
        except Exception as exc:
            # persistence is an optimization; losing it costs a future
            # replan, never the current multiply
            self._event("store_error", entry.key, entry.model, op="save", error=repr(exc))
            return
        self._event("saved", entry.key, entry.model)

    def _restore(self, key: str, inst) -> _Entry | None:
        if self.store_dir is None:
            return None
        from repro.checkpoint import restore_plan

        try:
            restored = retry_call(
                lambda: restore_plan(self.store_dir, key),
                self.policy,
                stage="store_restore",
                on_retry=self._on_retry,
            )
        except Exception as exc:
            self._event("store_error", key, None, op="restore", error=repr(exc))
            return None
        if restored is None:
            return None
        meta = restored.meta
        model = meta.get("model")
        if meta.get("p") != self.p or model is None:
            return None
        from repro.api import PlannedSpGEMM
        from repro.core.partition import PartitionResult

        labels = restored.arrays.get("labels")
        keys = restored.arrays.get("vertex_keys")
        if labels is None or keys is None:
            return None
        pres = PartitionResult(
            parts=np.asarray(labels),
            p=self.p,
            connectivity=int(meta.get("connectivity", 0)),
        )
        planned = PlannedSpGEMM(
            instance=inst,
            model=model,
            hypergraph=None,  # cost analysis unavailable on restored handles
            partition=pres,
            execution_plan=restored.plan,
            eps=self.eps,
            seed=self.seed,
        )
        try:
            exe = retry_call(
                lambda: planned.compile(dtype=self.dtype),
                self.policy,
                stage="compile",
                on_retry=self._on_retry,
            )
        except Exception as exc:
            # a stored plan that no longer compiles is worth exactly nothing:
            # fall through to a fresh replan
            self._event("store_error", key, model, op="compile", error=repr(exc))
            return None
        self._model_resolved = model
        self._event("restored", key, model)
        return _Entry(
            key=key,
            model=model,
            planned=planned,
            exe=exe,
            labels=np.asarray(labels),
            vertex_keys=np.asarray(keys),
            shape=tuple(meta.get("shape", inst.shape)),
        )
