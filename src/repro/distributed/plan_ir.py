"""Plan IR: one model-agnostic description of a lowered SpGEMM execution.

The paper's central claim is that a hypergraph partition *is* an SpGEMM
algorithm: the cut prescribes exactly the data movement.  ``ExecutionPlan``
is that prescription made concrete — the inspector output every executor in
``spgemm_exec`` consumes, whichever of the seven models produced it:

- **ownership**: global-id -> part maps, one per object family the model
  distributes ("a_row", "b_nz", "c_nz", ...).
- **local_ids**: per-device padded id lists (p, N_max) with -1 padding —
  the device-major inverse of each ownership map.
- **routes**: padded all_to_all routing tables (``Route``), one per expand
  phase.  A route realizes the cut nets of one operand: item t shipped from
  s to d is exactly one (cut net, touched part) pair of the partition, plus
  padding to the per-pair maximum so XLA sees static shapes.
- **compute**: per-device local work lists (e.g. the (pair_a, pair_b,
  pair_c) block multiplication lists the BSR kernel streams through).
- **stats**: scalar accounting that is not a routing table (fold volumes,
  pair counts).

Ideal (connectivity-metric) vs padded volume is tracked per route so
benchmarks can quantify executor overhead against the combinatorial cost
the partitioner minimized.

Plan *construction* is fully vectorized: every builder lowers a partition
to routing tables with CSR/CSC index arithmetic (``np.unique`` on encoded
(item, destination) keys, stable argsorts, bincount offsets) — no per-row
Python loops.  ``plan.py`` keeps the original loop-based rowwise builder as
an executable specification; ``tests/test_plan_ir.py`` pins byte-identical
equality between the two, and ``benchmarks/bench_plan_build.py`` measures
the speedup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.spgemm_models import SpGEMMInstance


# ---------------------------------------------------------------------------
# IR containers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Route:
    """One padded all_to_all expand phase.

    ``send_idx[s, d, t]`` is the *local* slot (into the sender's owned-item
    list) of the t-th item device s ships to device d; ``recv_key[s, d, t]``
    is that item's *global* id; -1 marks padding in both.  ``word_size`` is
    the payload words per item (a B row of J words, a b x b block, ...), so
    route volumes compose into word counts.
    """

    payload: str  # which operand moves: "A" | "B" | "C"
    send_idx: np.ndarray  # (p, p, T) int64, -1 padding
    recv_key: np.ndarray  # (p, p, T) int64 global item ids, -1 padding
    items_ideal: int  # (cut net, touched part) pairs = connectivity volume
    items_padded: int  # p * p * T actually shipped
    word_size: int = 1
    # per-item word accounting: when items carry different payload sizes
    # (e.g. a B row of nnz(row k) useful words), the cost-weighted ideal
    # volume and the static-slot padded volume (every slot sized to the
    # largest shipped item) are stored here; None means uniform word_size.
    words_ideal_override: int | None = None
    words_padded_override: int | None = None

    @property
    def T(self) -> int:
        return self.send_idx.shape[-1]

    @property
    def words_ideal(self) -> int:
        if self.words_ideal_override is not None:
            return int(self.words_ideal_override)
        return int(self.items_ideal * self.word_size)

    @property
    def words_padded(self) -> int:
        if self.words_padded_override is not None:
            return int(self.words_padded_override)
        return int(self.items_padded * self.word_size)

    @property
    def padding_fraction(self) -> float:
        return (self.items_padded - self.items_ideal) / max(self.items_padded, 1)


@dataclasses.dataclass
class ExecutionPlan:
    """Model-agnostic inspector output: ownership + routing + local work."""

    model: str
    p: int
    ownership: dict[str, np.ndarray]
    local_ids: dict[str, np.ndarray]
    routes: dict[str, Route] = dataclasses.field(default_factory=dict)
    compute: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def comm_words_ideal(self) -> int:
        route_words = sum(r.words_ideal for r in self.routes.values())
        return int(route_words + self.stats.get("fold_words_ideal", 0))

    @property
    def comm_words_padded(self) -> int:
        route_words = sum(r.words_padded for r in self.routes.values())
        return int(route_words + self.stats.get("fold_words_padded", 0))

    @property
    def padding_fraction(self) -> float:
        padded = self.comm_words_padded
        return (padded - self.comm_words_ideal) / max(padded, 1)


def measured_route_words(
    plan: "ExecutionPlan", item_words: dict[str, np.ndarray] | None = None
) -> int:
    """Words the plan's routing tables actually ship (valid slots only).

    Counted from the materialized ``recv_key`` tables — the executor moves
    exactly these entries (plus padding) — NOT from the hypergraph's lambda
    counting, so equality with ``evaluate().connectivity`` is a real check
    that the cut and the schedule describe the same traffic.  ``item_words``
    optionally maps a route name to per-global-item useful word counts
    (e.g. nnz per shipped B row); routes not named count ``word_size`` per
    item.  Fold-phase words tracked only in ``stats`` (the outer plan's
    psum_scatter) are added as-is since that phase has no routing table.
    """
    words = 0
    for name, r in plan.routes.items():
        keys = r.recv_key[r.recv_key >= 0]
        if item_words is not None and name in item_words:
            words += int(item_words[name][keys].sum())
        else:
            words += len(keys) * r.word_size
    return int(words + plan.stats.get("fold_words_ideal", 0))


def route_messages(plan: "ExecutionPlan") -> int:
    """Point-to-point messages the plan schedules: the number of non-empty
    ``(src, dst)`` cells across all routing tables (one padded all_to_all
    lane per pair, however many items it carries), plus fold-phase messages
    tracked only in ``stats`` (the outer plan's psum_scatter has no table —
    ``build_outer_plan`` records ``p * (p - 1)`` there).  The alpha term of
    the alpha-beta cost model, next to ``measured_route_words``'s beta."""
    msgs = 0
    for r in plan.routes.values():
        msgs += int((r.recv_key >= 0).any(axis=2).sum())
    return int(msgs + plan.stats.get("fold_messages", 0))


# ---------------------------------------------------------------------------
# Vectorized construction primitives
# ---------------------------------------------------------------------------
def padded_id_lists(part: np.ndarray, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Invert an ownership map into device-major padded id lists.

    Returns ``(local_ids, local_of)``: ``local_ids[d]`` lists the global ids
    owned by part d in ascending order (-1 padded to the per-part maximum,
    floor 1), and ``local_of[g]`` is g's position within its owner's list.
    """
    part = np.asarray(part, dtype=np.int64)
    n = len(part)
    order = np.argsort(part, kind="stable")  # groups by part, ids ascending
    counts = np.bincount(part, minlength=p) if n else np.zeros(p, dtype=np.int64)
    n_max = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    rank = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    local_ids = np.full((p, n_max), -1, dtype=np.int64)
    local_ids[part[order], rank] = order
    local_of = np.empty(n, dtype=np.int64)
    local_of[order] = rank
    return local_ids, local_of


def build_route(
    src: np.ndarray,
    dst: np.ndarray,
    item: np.ndarray,
    local_of: np.ndarray,
    p: int,
    payload: str,
    word_size: int = 1,
    send_slot: np.ndarray | None = None,
    item_words: np.ndarray | None = None,
) -> Route:
    """Lower a transfer list to a padded all_to_all routing table.

    ``(src[t], dst[t], item[t])`` enumerates every (cut net, touched part)
    pair — one shipped item per entry, ``dst != src`` already enforced.
    Entries must arrive sorted by item id; the stable per-(src, dst) grouping
    then keeps items ascending inside each cell, matching the loop-based
    reference builder byte for byte.

    ``send_slot`` overrides the sender-local slot per transfer when an item's
    slot depends on the sender (e.g. partial-C tables, where one C nonzero is
    produced on several devices); ``item_words`` gives per-item useful word
    counts for cost-weighted volume accounting (non-uniform net costs).
    """
    n = len(item)
    order = np.argsort(src * p + dst, kind="stable")
    s_o, d_o, it_o = src[order], dst[order], item[order]
    key = s_o * p + d_o
    _, counts = np.unique(key, return_counts=True)
    T = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    slot = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    send_idx = np.full((p, p, T), -1, dtype=np.int64)
    recv_key = np.full((p, p, T), -1, dtype=np.int64)
    send_idx[s_o, d_o, slot] = send_slot[order] if send_slot is not None else local_of[it_o]
    recv_key[s_o, d_o, slot] = it_o
    words_ideal = words_padded = None
    if item_words is not None:
        words_ideal = int(item_words[item].sum())
        # an executor's all_to_all slots are statically sized to the largest
        # shipped item, so the padded wire volume scales with that maximum
        words_padded = p * p * T * int(item_words[item].max(initial=0)) if n else 0
    return Route(
        payload=payload,
        send_idx=send_idx,
        recv_key=recv_key,
        items_ideal=n,
        items_padded=p * p * T if n else 0,
        word_size=word_size,
        words_ideal_override=words_ideal,
        words_padded_override=words_padded,
    )


def _expand_transfers(
    item_of_need: np.ndarray,
    part_of_need: np.ndarray,
    item_owner: np.ndarray,
    p: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deduplicate (item, consuming part) incidences into transfers.

    ``item_of_need[t]`` needs to be visible on ``part_of_need[t]`` (one entry
    per pin of the item's net); returns the unique (src, dst, item) transfer
    triples with dst != owner, sorted by item — the exact cut-net traffic
    sum_n c(n) * (lambda(n) - 1) of the partition.
    """
    pairs = np.unique(item_of_need * p + part_of_need)  # sorted by (item, part)
    items, dsts = pairs // p, pairs % p
    srcs = item_owner[items]
    keep = dsts != srcs
    return srcs[keep], dsts[keep], items[keep]


def derive_owner_from_pins(
    item_of_need: np.ndarray,
    part_of_need: np.ndarray,
    n_items: int,
    p: int,
) -> np.ndarray:
    """Assign each item to the lowest-numbered part that needs it.

    This is the paper's omitted-V^nz reading of the connectivity metric: a
    nonzero resides on one of the parts whose computation touches it, so a
    cut net of connectivity lambda costs exactly lambda - 1 transfers.  With
    ownership derived this way, every route's ``items_ideal`` equals the
    hypergraph connectivity contribution of its nets — predicted == planned.
    Items no computation touches (dead nonzeros) fall back to round-robin;
    they never generate traffic either way.
    """
    pairs = np.unique(item_of_need * p + part_of_need)  # sorted (item, part)
    items, parts = pairs // p, pairs % p
    first_item, first_pos = np.unique(items, return_index=True)
    owner = np.arange(n_items, dtype=np.int64) % p
    owner[first_item] = parts[first_pos]  # min part per item: pairs are sorted
    return owner


# ---------------------------------------------------------------------------
# 1D row-wise (Ex. 5.1)
# ---------------------------------------------------------------------------
class RowwisePlan(ExecutionPlan):
    """Row-wise plan: device d owns A/C row set R_d and B row set S_d; one
    expand route ships each cut B-net (B row) to every part whose A-columns
    touch it.  Legacy field names are accessors into the IR."""

    @property
    def row_part(self) -> np.ndarray:
        return self.ownership["a_row"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_row"]

    @property
    def local_rows(self) -> np.ndarray:
        return self.local_ids["a_row"]

    @property
    def local_b_rows(self) -> np.ndarray:
        return self.local_ids["b_row"]

    @property
    def send_idx(self) -> np.ndarray:
        return self.routes["expand"].send_idx

    @property
    def recv_key(self) -> np.ndarray:
        return self.routes["expand"].recv_key


def build_rowwise_plan(
    inst: SpGEMMInstance,
    row_part: np.ndarray,
    p: int,
    b_part: np.ndarray | None = None,
) -> RowwisePlan:
    """Vectorized inspector for the row-wise model (CSC index arithmetic;
    see ``plan.build_rowwise_plan_loop`` for the executable specification)."""
    I, K, J = inst.shape
    row_part = np.asarray(row_part, dtype=np.int64)
    if b_part is None:
        # default B distribution: round-robin rows (paper Sec. 6: V^nz omitted)
        b_part = np.arange(K, dtype=np.int64) % p
    else:
        b_part = np.asarray(b_part, dtype=np.int64)

    # B row k is needed wherever A column k has a nonzero: one incidence per
    # A nonzero, deduplicated to (k, part) pairs
    acsc = inst.a_csc
    ks = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
    src, dst, items = _expand_transfers(
        ks, row_part[acsc.indices.astype(np.int64)], b_part, p
    )
    local_b_rows, local_of_b = padded_id_lists(b_part, p)
    route = build_route(src, dst, items, local_of_b, p, payload="B")
    local_rows, _ = padded_id_lists(row_part, p)
    return RowwisePlan(
        model="rowwise",
        p=p,
        ownership={"a_row": row_part, "b_row": b_part},
        local_ids={"a_row": local_rows, "b_row": local_b_rows},
        routes={"expand": route},
    )


# ---------------------------------------------------------------------------
# 1D outer-product (Ex. 5.2)
# ---------------------------------------------------------------------------
class OuterPlan(ExecutionPlan):
    """Outer-product plan: device d owns A-column/B-row set K_d; the fold
    phase (psum_scatter over C row blocks) carries the C-net volume."""

    @property
    def k_part(self) -> np.ndarray:
        return self.ownership["k"]

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_row"]

    @property
    def local_ks(self) -> np.ndarray:
        return self.local_ids["k"]


def build_outer_plan(
    inst: SpGEMMInstance,
    k_part: np.ndarray,
    p: int,
    c_part: np.ndarray | None = None,
) -> OuterPlan:
    I, K, J = inst.shape
    k_part = np.asarray(k_part, dtype=np.int64)
    if c_part is None:
        c_part = np.arange(I, dtype=np.int64) % p
    else:
        c_part = np.asarray(c_part, dtype=np.int64)
    local_ks, _ = padded_id_lists(k_part, p)
    # ideal fold volume: per C nonzero, (#distinct contributing k-parts - 1)
    cpos = inst.mult_i * J + inst.mult_j
    pair = np.unique(cpos * p + k_part[inst.mult_k])
    lam = np.bincount(pair // p)
    ideal = int(np.maximum(lam[lam > 0] - 1, 0).sum())
    # realized fold: the executor's psum_scatter reduces dense padded C row
    # blocks regardless of sparsity — every device ships (p-1)/p of I_pad * J
    I_pad = (I + p - 1) // p * p
    padded = I_pad * (p - 1) * J if p > 1 else 0
    return OuterPlan(
        model="outer",
        p=p,
        ownership={"k": k_part, "c_row": c_part},
        local_ids={"k": local_ks},
        stats={
            "fold_words_ideal": ideal,
            "fold_words_padded": padded,
            # the psum_scatter is all-pairs: every device sends one C-row
            # chunk to each of the other p - 1
            "fold_messages": p * (p - 1) if p > 1 else 0,
        },
    )


# ---------------------------------------------------------------------------
# 2D monochrome-C (Ex. 5.4)
# ---------------------------------------------------------------------------
class MonoCPlan(ExecutionPlan):
    """Monochrome-C plan over a (block) SpGEMM instance.

    Vertices of the monoC hypergraph are C nonzeros; a partition of them is
    an ownership map for C.  A and B nonzeros are distributed by their own
    maps (default round-robin, matching the omitted-V^nz convention), and
    the cut A-nets / B-nets lower to two expand routes.  Per-device pair
    lists drive the BSR kernel over local slot tables laid out as
    ``[owned (N_max) | received (p * T) | zero pad (1)]``.
    """

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_nz"]

    @property
    def a_part(self) -> np.ndarray:
        return self.ownership["a_nz"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_nz"]

    # slot-table layout constants the executor mirrors
    @property
    def a_table_slots(self) -> int:
        return self.local_ids["a_nz"].shape[1] + self.p * self.routes["expand_a"].T + 1

    @property
    def b_table_slots(self) -> int:
        return self.local_ids["b_nz"].shape[1] + self.p * self.routes["expand_b"].T + 1

    @property
    def n_c_slots(self) -> int:
        """Local C slots incl. the trailing garbage slot padding pairs hit."""
        return self.local_ids["c_nz"].shape[1] + 1


def _table_slots(
    part: np.ndarray,
    local_of: np.ndarray,
    route: Route,
    n_items: int,
    p: int,
) -> np.ndarray:
    """(p, n_items) map: global item id -> per-device slot in the
    ``[owned | received | zero]`` table; -1 where the device never sees it."""
    n_owned = 0 if n_items == 0 else int(local_of.max(initial=-1)) + 1
    # owned slots span [0, N_max); N_max from the padded list width
    slots = np.full((p, n_items), -1, dtype=np.int64)
    slots[part, np.arange(n_items, dtype=np.int64)] = local_of
    T = route.T
    s_ids, d_ids, t_ids = np.nonzero(route.recv_key >= 0)
    keys = route.recv_key[s_ids, d_ids, t_ids]
    slots[d_ids, keys] = n_owned + s_ids * T + t_ids
    return slots


def build_monoC_plan(
    inst: SpGEMMInstance,
    c_part: np.ndarray,
    p: int,
    a_part: np.ndarray | None = None,
    b_part: np.ndarray | None = None,
    word_size: int = 1,
) -> MonoCPlan:
    """Lower a monoC partition to routes + per-device BSR pair lists.

    ``inst`` may be a scalar instance or the block structure of a tiled one
    (tiling is a vertex coarsening — the plan is the same object either
    way); ``word_size`` records the payload words per shipped nonzero
    (b*b for b x b blocks) for volume accounting.
    """
    nA, nB, nC = inst.a.nnz, inst.b.nnz, inst.c.nnz
    c_part = np.asarray(c_part, dtype=np.int64)
    if a_part is None:
        a_part = np.arange(nA, dtype=np.int64) % p
    else:
        a_part = np.asarray(a_part, dtype=np.int64)
    if b_part is None:
        b_part = np.arange(nB, dtype=np.int64) % p
    else:
        b_part = np.asarray(b_part, dtype=np.int64)

    a_pos, b_pos, c_pos = inst.mult_a_pos, inst.mult_b_pos, inst.mult_c_pos
    mult_dev = c_part[c_pos]

    # expand routes: A nonzero ik is needed on every part owning a pin of
    # net n^A_ik (a multiplication it feeds); same for B — Ex. 5.4's nets
    local_a, local_of_a = padded_id_lists(a_part, p)
    src, dst, items = _expand_transfers(a_pos, mult_dev, a_part, p)
    route_a = build_route(src, dst, items, local_of_a, p, "A", word_size)
    local_b, local_of_b = padded_id_lists(b_part, p)
    src, dst, items = _expand_transfers(b_pos, mult_dev, b_part, p)
    route_b = build_route(src, dst, items, local_of_b, p, "B", word_size)
    local_c, local_of_c = padded_id_lists(c_part, p)

    # per-device pair lists in table slots (vectorized: one lexsort)
    a_slots = _table_slots(a_part, local_of_a, route_a, nA, p)
    b_slots = _table_slots(b_part, local_of_b, route_b, nB, p)
    pa = a_slots[mult_dev, a_pos]
    pb = b_slots[mult_dev, b_pos]
    pc = local_of_c[c_pos]
    assert (pa >= 0).all() and (pb >= 0).all(), "routing missed a needed nonzero"
    # group by device, then C slot ascending (kernel accumulates runs), then
    # operand slots for determinism
    order = np.lexsort((pb, pa, pc, mult_dev))
    pa, pb, pc, dev = pa[order], pb[order], pc[order], mult_dev[order]
    counts = np.bincount(dev, minlength=p)
    P_max = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    rank = np.arange(len(dev), dtype=np.int64) - np.repeat(starts, counts)
    # padding pairs hit the all-zero operand slots and the garbage C slot
    A_max, B_max, C_max = local_a.shape[1], local_b.shape[1], local_c.shape[1]
    pair_a = np.full((p, P_max), A_max + p * route_a.T, dtype=np.int64)
    pair_b = np.full((p, P_max), B_max + p * route_b.T, dtype=np.int64)
    pair_c = np.full((p, P_max), C_max, dtype=np.int64)
    pair_a[dev, rank] = pa
    pair_b[dev, rank] = pb
    pair_c[dev, rank] = pc

    return MonoCPlan(
        model="monoC",
        p=p,
        ownership={"c_nz": c_part, "a_nz": a_part, "b_nz": b_part},
        local_ids={"c_nz": local_c, "a_nz": local_a, "b_nz": local_b},
        routes={"expand_a": route_a, "expand_b": route_b},
        compute={"pair_a": pair_a, "pair_b": pair_b, "pair_c": pair_c},
        stats={"n_pairs": int(len(dev)), "pairs_padded": int(p * P_max)},
    )


def plan_monoC_from_dense(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    block: int,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
) -> tuple[MonoCPlan, SpGEMMInstance]:
    """Tile, model, partition, plan — the full monoC inspector pipeline.

    Tiling into b x b blocks is a vertex coarsening of the fine-grained
    hypergraph (DESIGN.md), so the monoC model of the *block* instance is
    partitioned and the resulting plan drives the BSR executor directly.
    Returns (plan, block instance) — the instance is also what
    ``unpack_monoC_result`` needs (``inst.c`` and the padded shapes).
    """
    from repro.core.partition import partition
    from repro.core.spgemm_models import build_model
    from repro.sparse.bsr import to_bsr

    ab = to_bsr(np.asarray(a_dense), block, block)
    bb = to_bsr(np.asarray(b_dense), block, block)
    inst = SpGEMMInstance(ab.block_structure(), bb.block_structure(), name="monoC")
    hg = build_model(inst, "monoC")
    res = partition(hg, p, eps=eps, seed=seed)
    plan = build_monoC_plan(inst, res.parts, p, word_size=block * block)
    return plan, inst


# ---------------------------------------------------------------------------
# 3D fine-grained (Def. 3.1)
# ---------------------------------------------------------------------------
class FinePlan(ExecutionPlan):
    """Fine-grained plan: an arbitrary flop-level partition made executable.

    Vertices of the fine hypergraph are scalar multiplications a_ik * b_kj;
    the partition assigns each to a device.  Ownership maps distribute the
    A, B and C nonzeros (derived from the pins when not given, so a cut net
    of connectivity lambda costs exactly lambda - 1 transfers — predicted
    connectivity == planned words).  Three routes realize the three net
    families: ``expand_a`` / ``expand_b`` ship cut A-/B-nets before local
    compute, ``reduce_c`` ships partial C contributions to each C nonzero's
    owner afterwards — the paper's expand-expand-reduce schedule.

    Per-device state the executor mirrors:

    - operand slot tables ``[owned | received | zero]`` (as monoC);
    - a *produced-C* table: slot r on device d accumulates d's partial sum
      for the r-th distinct C nonzero d's multiplications contribute to
      (``local_ids["c_prod"]``), plus a trailing garbage slot for padding;
    - ``compute["pair_*"]``: padded (p, P_max) multiplication lists in slot
      coordinates — pair_a/pair_b index the operand tables, pair_c the
      produced table;
    - ``compute["reduce_recv_slot"]``: (p, p, T_r) owned-C slot each arriving
      reduce item folds into (-1 padding);
    - ``compute["prod_to_owned"]``: (p, R_max) owned-C slot of each produced
      slot when the producer already owns that C nonzero (-1 otherwise).
    """

    @property
    def mult_part(self) -> np.ndarray:
        return self.ownership["mult"]

    @property
    def a_part(self) -> np.ndarray:
        return self.ownership["a_nz"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_nz"]

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_nz"]

    @property
    def a_table_slots(self) -> int:
        return self.local_ids["a_nz"].shape[1] + self.p * self.routes["expand_a"].T + 1

    @property
    def b_table_slots(self) -> int:
        return self.local_ids["b_nz"].shape[1] + self.p * self.routes["expand_b"].T + 1

    @property
    def n_prod_slots(self) -> int:
        """Produced-C slots incl. the trailing garbage slot padding pairs hit."""
        return self.local_ids["c_prod"].shape[1] + 1

    @property
    def n_c_slots(self) -> int:
        """Owned-C slots incl. the trailing garbage slot padded arrivals hit."""
        return self.local_ids["c_nz"].shape[1] + 1


def build_fine_plan(
    inst: SpGEMMInstance,
    mult_part: np.ndarray,
    p: int,
    a_part: np.ndarray | None = None,
    b_part: np.ndarray | None = None,
    c_part: np.ndarray | None = None,
    word_size: int = 1,
) -> FinePlan:
    """Lower a fine-grained (flop-level) partition to an executable plan.

    ``mult_part`` is either a partition of the M multiplication vertices
    (the include_nz=False fine hypergraph) or of the full include_nz vertex
    set — in the latter case the nonzero-vertex assignments become the
    ownership maps.  Ownership not provided either way is derived from the
    pins (``derive_owner_from_pins``), which makes ``comm_words_ideal``
    equal the fine hypergraph's connectivity cost exactly.
    """
    M = inst.n_mult
    nA, nB, nC = inst.a.nnz, inst.b.nnz, inst.c.nnz
    mult_part = np.asarray(mult_part, dtype=np.int64)
    if len(mult_part) == M + nA + nB + nC and nA + nB + nC:
        if a_part is None:
            a_part = mult_part[M : M + nA]
        if b_part is None:
            b_part = mult_part[M + nA : M + nA + nB]
        if c_part is None:
            c_part = mult_part[M + nA + nB :]
        mult_part = mult_part[:M]
    elif len(mult_part) != M:
        raise ValueError(
            f"mult_part has {len(mult_part)} entries; expected {M} "
            f"(multiplications) or {M + nA + nB + nC} (include_nz vertices)"
        )
    mult_dev = mult_part
    a_pos, b_pos, c_pos = inst.mult_a_pos, inst.mult_b_pos, inst.mult_c_pos
    if a_part is None:
        a_part = derive_owner_from_pins(a_pos, mult_dev, nA, p)
    else:
        a_part = np.asarray(a_part, dtype=np.int64)
    if b_part is None:
        b_part = derive_owner_from_pins(b_pos, mult_dev, nB, p)
    else:
        b_part = np.asarray(b_part, dtype=np.int64)
    if c_part is None:
        c_part = derive_owner_from_pins(c_pos, mult_dev, nC, p)
    else:
        c_part = np.asarray(c_part, dtype=np.int64)

    # expand routes: exactly the cut A-/B-net traffic of the fine partition
    local_a, local_of_a = padded_id_lists(a_part, p)
    src, dst, items = _expand_transfers(a_pos, mult_dev, a_part, p)
    route_a = build_route(src, dst, items, local_of_a, p, "A", word_size)
    local_b, local_of_b = padded_id_lists(b_part, p)
    src, dst, items = _expand_transfers(b_pos, mult_dev, b_part, p)
    route_b = build_route(src, dst, items, local_of_b, p, "B", word_size)
    local_c, local_of_c = padded_id_lists(c_part, p)

    # produced-C table: the distinct C nonzeros each device contributes to,
    # device-major with ascending C ids (one partial-sum slot per entry)
    prod_pairs = np.unique(mult_dev * max(nC, 1) + c_pos)
    prod_dev, prod_c = prod_pairs // max(nC, 1), prod_pairs % max(nC, 1)
    prod_counts = np.bincount(prod_dev, minlength=p)
    R_max = max(int(prod_counts.max(initial=0)), 1)
    starts = np.cumsum(prod_counts) - prod_counts
    rank = np.arange(len(prod_dev), dtype=np.int64) - np.repeat(starts, prod_counts)
    prod_ids = np.full((p, R_max), -1, dtype=np.int64)
    prod_ids[prod_dev, rank] = prod_c
    prod_slot = np.full((p, nC), -1, dtype=np.int64)
    prod_slot[prod_dev, prod_c] = rank

    # per-device multiplication lists in slot coordinates (one lexsort)
    a_slots = _table_slots(a_part, local_of_a, route_a, nA, p)
    b_slots = _table_slots(b_part, local_of_b, route_b, nB, p)
    pa = a_slots[mult_dev, a_pos]
    pb = b_slots[mult_dev, b_pos]
    pc = prod_slot[mult_dev, c_pos]
    assert (pa >= 0).all() and (pb >= 0).all() and (pc >= 0).all(), (
        "routing missed a needed nonzero"
    )
    order = np.lexsort((pb, pa, pc, mult_dev))
    pa, pb, pc, dev = pa[order], pb[order], pc[order], mult_dev[order]
    counts = np.bincount(dev, minlength=p)
    P_max = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    rank = np.arange(len(dev), dtype=np.int64) - np.repeat(starts, counts)
    A_max, B_max = local_a.shape[1], local_b.shape[1]
    pair_a = np.full((p, P_max), A_max + p * route_a.T, dtype=np.int64)
    pair_b = np.full((p, P_max), B_max + p * route_b.T, dtype=np.int64)
    pair_c = np.full((p, P_max), R_max, dtype=np.int64)
    pair_a[dev, rank] = pa
    pair_b[dev, rank] = pb
    pair_c[dev, rank] = pc

    # reduce route: every (C net, producing part) pair with a foreign owner —
    # the cut C-net traffic.  Sender slots index the produced-C table.
    red_pairs = np.unique(c_pos * p + mult_dev)  # item-major (c, part)
    r_item, r_src = red_pairs // p, red_pairs % p
    r_dst = c_part[r_item]
    keep = r_src != r_dst
    route_r = build_route(
        r_src[keep],
        r_dst[keep],
        r_item[keep],
        local_of_c,
        p,
        "C",
        word_size,
        send_slot=prod_slot[r_src[keep], r_item[keep]],
    )
    recv_slot = np.where(
        route_r.recv_key >= 0, local_of_c[np.maximum(route_r.recv_key, 0)], -1
    )
    # produced slots the device itself owns fold straight into owned C slots
    prod_owned = np.full((p, R_max), -1, dtype=np.int64)
    d_ids, s_ids = np.nonzero(prod_ids >= 0)
    gids = prod_ids[d_ids, s_ids]
    own = c_part[gids] == d_ids
    prod_owned[d_ids[own], s_ids[own]] = local_of_c[gids[own]]

    return FinePlan(
        model="fine",
        p=p,
        ownership={"mult": mult_dev, "a_nz": a_part, "b_nz": b_part, "c_nz": c_part},
        local_ids={"a_nz": local_a, "b_nz": local_b, "c_nz": local_c, "c_prod": prod_ids},
        routes={"expand_a": route_a, "expand_b": route_b, "reduce_c": route_r},
        compute={
            "pair_a": pair_a,
            "pair_b": pair_b,
            "pair_c": pair_c,
            "reduce_recv_slot": recv_slot,
            "prod_to_owned": prod_owned,
        },
        stats={"n_mult": int(M), "pairs_padded": int(p * P_max)},
    )


def plan_fine_from_dense(
    a_dense,
    b_dense,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
    include_nz: bool = False,
) -> tuple[FinePlan, SpGEMMInstance]:
    """Model, partition, plan — the full fine-grained inspector pipeline.

    Builds the fine hypergraph of the scalar nonzero structures, partitions
    its multiplication vertices, and lowers the result to a ``FinePlan``.
    With ``include_nz`` the partitioner also places the nonzero vertices and
    those placements become the plan's ownership maps.

    The operands may each be a dense array, a scipy sparse matrix, or a
    ``SparseStructure`` — callers that already hold sparse structures never
    round-trip through dense.
    """
    from repro.core.partition import partition
    from repro.core.spgemm_models import build_model
    from repro.sparse.structure import as_structure

    a_s = as_structure(a_dense)
    b_s = as_structure(b_dense)
    inst = SpGEMMInstance(a_s, b_s, name="fine")
    hg = build_model(inst, "fine", include_nz=include_nz)
    res = partition(hg, p, eps=eps, seed=seed)
    plan = build_fine_plan(inst, res.parts, p)
    return plan, inst


# ---------------------------------------------------------------------------
# Generic predicted-volume plan (any model)
# ---------------------------------------------------------------------------
def build_volume_plan(hg, parts: np.ndarray, p: int) -> ExecutionPlan:
    """Lower ANY model hypergraph + partition to net-granularity routes.

    One route per net family (A-expand, B-expand, C-reduce), each shipping a
    cut net from a pin-derived owner to every other touched part, weighted by
    the net's cost.  ``comm_words_ideal`` therefore equals
    ``comm.evaluate(hg, parts, p).connectivity`` — computed here by an
    independent code path (transfer enumeration vs lambda counting), which is
    what the predicted-vs-planned property test pins for all seven models.
    Models with real executors refine this to item-granularity plans; this
    one exists so every model's predicted volume has an IR representation.
    """
    parts = np.asarray(parts, dtype=np.int64)
    pin_parts = parts[hg.net_pins]
    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), hg.net_sizes())
    owner = derive_owner_from_pins(net_ids, pin_parts, hg.n_nets, p)
    kinds = (
        hg.net_kind
        if hg.net_kind is not None
        else np.zeros(hg.n_nets, dtype=np.int8)
    )
    ident = np.arange(hg.n_nets, dtype=np.int64)
    routes = {}
    for kind, name, payload in (
        (0, "expand", "N"),
        (1, "expand_a", "A"),
        (2, "expand_b", "B"),
        (3, "reduce_c", "C"),
    ):
        sel = kinds[net_ids] == kind
        if not sel.any():
            continue
        src, dst, items = _expand_transfers(net_ids[sel], pin_parts[sel], owner, p)
        routes[name] = build_route(
            src, dst, items, ident, p, payload, item_words=hg.net_cost
        )
    return ExecutionPlan(
        model="volume",
        p=p,
        ownership={"net": owner},
        local_ids={},
        routes=routes,
    )
