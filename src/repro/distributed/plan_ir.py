"""Plan IR: one model-agnostic description of a lowered SpGEMM execution.

The paper's central claim is that a hypergraph partition *is* an SpGEMM
algorithm: the cut prescribes exactly the data movement.  ``ExecutionPlan``
is that prescription made concrete — the inspector output every executor in
``spgemm_exec`` consumes, whichever of the seven models produced it:

- **ownership**: global-id -> part maps, one per object family the model
  distributes ("a_row", "b_nz", "c_nz", ...).
- **local_ids**: per-device padded id lists (p, N_max) with -1 padding —
  the device-major inverse of each ownership map.
- **routes**: padded all_to_all routing tables (``Route``), one per expand
  phase.  A route realizes the cut nets of one operand: item t shipped from
  s to d is exactly one (cut net, touched part) pair of the partition, plus
  padding to the per-pair maximum so XLA sees static shapes.
- **compute**: per-device local work lists (e.g. the (pair_a, pair_b,
  pair_c) block multiplication lists the BSR kernel streams through).
- **stats**: scalar accounting that is not a routing table (fold volumes,
  pair counts).

Ideal (connectivity-metric) vs padded volume is tracked per route so
benchmarks can quantify executor overhead against the combinatorial cost
the partitioner minimized.

Plan *construction* is fully vectorized: every builder lowers a partition
to routing tables with CSR/CSC index arithmetic (``np.unique`` on encoded
(item, destination) keys, stable argsorts, bincount offsets) — no per-row
Python loops.  ``plan.py`` keeps the original loop-based rowwise builder as
an executable specification; ``tests/test_plan_ir.py`` pins byte-identical
equality between the two, and ``benchmarks/bench_plan_build.py`` measures
the speedup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.spgemm_models import SpGEMMInstance


# ---------------------------------------------------------------------------
# IR containers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Route:
    """One padded all_to_all expand phase.

    ``send_idx[s, d, t]`` is the *local* slot (into the sender's owned-item
    list) of the t-th item device s ships to device d; ``recv_key[s, d, t]``
    is that item's *global* id; -1 marks padding in both.  ``word_size`` is
    the payload words per item (a B row of J words, a b x b block, ...), so
    route volumes compose into word counts.
    """

    payload: str  # which operand moves: "A" | "B" | "C"
    send_idx: np.ndarray  # (p, p, T) int64, -1 padding
    recv_key: np.ndarray  # (p, p, T) int64 global item ids, -1 padding
    items_ideal: int  # (cut net, touched part) pairs = connectivity volume
    items_padded: int  # p * p * T actually shipped
    word_size: int = 1

    @property
    def T(self) -> int:
        return self.send_idx.shape[-1]

    @property
    def padding_fraction(self) -> float:
        return (self.items_padded - self.items_ideal) / max(self.items_padded, 1)


@dataclasses.dataclass
class ExecutionPlan:
    """Model-agnostic inspector output: ownership + routing + local work."""

    model: str
    p: int
    ownership: dict[str, np.ndarray]
    local_ids: dict[str, np.ndarray]
    routes: dict[str, Route] = dataclasses.field(default_factory=dict)
    compute: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def comm_words_ideal(self) -> int:
        route_words = sum(r.items_ideal * r.word_size for r in self.routes.values())
        return int(route_words + self.stats.get("fold_words_ideal", 0))

    @property
    def comm_words_padded(self) -> int:
        route_words = sum(r.items_padded * r.word_size for r in self.routes.values())
        return int(route_words + self.stats.get("fold_words_padded", 0))

    @property
    def padding_fraction(self) -> float:
        padded = self.comm_words_padded
        return (padded - self.comm_words_ideal) / max(padded, 1)


# ---------------------------------------------------------------------------
# Vectorized construction primitives
# ---------------------------------------------------------------------------
def padded_id_lists(part: np.ndarray, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Invert an ownership map into device-major padded id lists.

    Returns ``(local_ids, local_of)``: ``local_ids[d]`` lists the global ids
    owned by part d in ascending order (-1 padded to the per-part maximum,
    floor 1), and ``local_of[g]`` is g's position within its owner's list.
    """
    part = np.asarray(part, dtype=np.int64)
    n = len(part)
    order = np.argsort(part, kind="stable")  # groups by part, ids ascending
    counts = np.bincount(part, minlength=p) if n else np.zeros(p, dtype=np.int64)
    n_max = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    rank = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    local_ids = np.full((p, n_max), -1, dtype=np.int64)
    local_ids[part[order], rank] = order
    local_of = np.empty(n, dtype=np.int64)
    local_of[order] = rank
    return local_ids, local_of


def build_route(
    src: np.ndarray,
    dst: np.ndarray,
    item: np.ndarray,
    local_of: np.ndarray,
    p: int,
    payload: str,
    word_size: int = 1,
) -> Route:
    """Lower a transfer list to a padded all_to_all routing table.

    ``(src[t], dst[t], item[t])`` enumerates every (cut net, touched part)
    pair — one shipped item per entry, ``dst != src`` already enforced.
    Entries must arrive sorted by item id; the stable per-(src, dst) grouping
    then keeps items ascending inside each cell, matching the loop-based
    reference builder byte for byte.
    """
    n = len(item)
    order = np.argsort(src * p + dst, kind="stable")
    s_o, d_o, it_o = src[order], dst[order], item[order]
    key = s_o * p + d_o
    _, counts = np.unique(key, return_counts=True)
    T = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    slot = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    send_idx = np.full((p, p, T), -1, dtype=np.int64)
    recv_key = np.full((p, p, T), -1, dtype=np.int64)
    send_idx[s_o, d_o, slot] = local_of[it_o]
    recv_key[s_o, d_o, slot] = it_o
    return Route(
        payload=payload,
        send_idx=send_idx,
        recv_key=recv_key,
        items_ideal=n,
        items_padded=p * p * T if n else 0,
        word_size=word_size,
    )


def _expand_transfers(
    item_of_need: np.ndarray,
    part_of_need: np.ndarray,
    item_owner: np.ndarray,
    p: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deduplicate (item, consuming part) incidences into transfers.

    ``item_of_need[t]`` needs to be visible on ``part_of_need[t]`` (one entry
    per pin of the item's net); returns the unique (src, dst, item) transfer
    triples with dst != owner, sorted by item — the exact cut-net traffic
    sum_n c(n) * (lambda(n) - 1) of the partition.
    """
    pairs = np.unique(item_of_need * p + part_of_need)  # sorted by (item, part)
    items, dsts = pairs // p, pairs % p
    srcs = item_owner[items]
    keep = dsts != srcs
    return srcs[keep], dsts[keep], items[keep]


# ---------------------------------------------------------------------------
# 1D row-wise (Ex. 5.1)
# ---------------------------------------------------------------------------
class RowwisePlan(ExecutionPlan):
    """Row-wise plan: device d owns A/C row set R_d and B row set S_d; one
    expand route ships each cut B-net (B row) to every part whose A-columns
    touch it.  Legacy field names are accessors into the IR."""

    @property
    def row_part(self) -> np.ndarray:
        return self.ownership["a_row"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_row"]

    @property
    def local_rows(self) -> np.ndarray:
        return self.local_ids["a_row"]

    @property
    def local_b_rows(self) -> np.ndarray:
        return self.local_ids["b_row"]

    @property
    def send_idx(self) -> np.ndarray:
        return self.routes["expand"].send_idx

    @property
    def recv_key(self) -> np.ndarray:
        return self.routes["expand"].recv_key


def build_rowwise_plan(
    inst: SpGEMMInstance,
    row_part: np.ndarray,
    p: int,
    b_part: np.ndarray | None = None,
) -> RowwisePlan:
    """Vectorized inspector for the row-wise model (CSC index arithmetic;
    see ``plan.build_rowwise_plan_loop`` for the executable specification)."""
    I, K, J = inst.shape
    row_part = np.asarray(row_part, dtype=np.int64)
    if b_part is None:
        # default B distribution: round-robin rows (paper Sec. 6: V^nz omitted)
        b_part = np.arange(K, dtype=np.int64) % p
    else:
        b_part = np.asarray(b_part, dtype=np.int64)

    # B row k is needed wherever A column k has a nonzero: one incidence per
    # A nonzero, deduplicated to (k, part) pairs
    acsc = inst.a_csc
    ks = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
    src, dst, items = _expand_transfers(
        ks, row_part[acsc.indices.astype(np.int64)], b_part, p
    )
    local_b_rows, local_of_b = padded_id_lists(b_part, p)
    route = build_route(src, dst, items, local_of_b, p, payload="B")
    local_rows, _ = padded_id_lists(row_part, p)
    return RowwisePlan(
        model="rowwise",
        p=p,
        ownership={"a_row": row_part, "b_row": b_part},
        local_ids={"a_row": local_rows, "b_row": local_b_rows},
        routes={"expand": route},
    )


# ---------------------------------------------------------------------------
# 1D outer-product (Ex. 5.2)
# ---------------------------------------------------------------------------
class OuterPlan(ExecutionPlan):
    """Outer-product plan: device d owns A-column/B-row set K_d; the fold
    phase (psum_scatter over C row blocks) carries the C-net volume."""

    @property
    def k_part(self) -> np.ndarray:
        return self.ownership["k"]

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_row"]

    @property
    def local_ks(self) -> np.ndarray:
        return self.local_ids["k"]


def build_outer_plan(
    inst: SpGEMMInstance,
    k_part: np.ndarray,
    p: int,
    c_part: np.ndarray | None = None,
) -> OuterPlan:
    I, K, J = inst.shape
    k_part = np.asarray(k_part, dtype=np.int64)
    if c_part is None:
        c_part = np.arange(I, dtype=np.int64) % p
    else:
        c_part = np.asarray(c_part, dtype=np.int64)
    local_ks, _ = padded_id_lists(k_part, p)
    # ideal fold volume: per C nonzero, (#distinct contributing k-parts - 1)
    cpos = inst.mult_i * J + inst.mult_j
    pair = np.unique(cpos * p + k_part[inst.mult_k])
    lam = np.bincount(pair // p)
    ideal = int(np.maximum(lam[lam > 0] - 1, 0).sum())
    # realized fold: the executor's psum_scatter reduces dense padded C row
    # blocks regardless of sparsity — every device ships (p-1)/p of I_pad * J
    I_pad = (I + p - 1) // p * p
    padded = I_pad * (p - 1) * J if p > 1 else 0
    return OuterPlan(
        model="outer",
        p=p,
        ownership={"k": k_part, "c_row": c_part},
        local_ids={"k": local_ks},
        stats={"fold_words_ideal": ideal, "fold_words_padded": padded},
    )


# ---------------------------------------------------------------------------
# 2D monochrome-C (Ex. 5.4)
# ---------------------------------------------------------------------------
class MonoCPlan(ExecutionPlan):
    """Monochrome-C plan over a (block) SpGEMM instance.

    Vertices of the monoC hypergraph are C nonzeros; a partition of them is
    an ownership map for C.  A and B nonzeros are distributed by their own
    maps (default round-robin, matching the omitted-V^nz convention), and
    the cut A-nets / B-nets lower to two expand routes.  Per-device pair
    lists drive the BSR kernel over local slot tables laid out as
    ``[owned (N_max) | received (p * T) | zero pad (1)]``.
    """

    @property
    def c_part(self) -> np.ndarray:
        return self.ownership["c_nz"]

    @property
    def a_part(self) -> np.ndarray:
        return self.ownership["a_nz"]

    @property
    def b_part(self) -> np.ndarray:
        return self.ownership["b_nz"]

    # slot-table layout constants the executor mirrors
    @property
    def a_table_slots(self) -> int:
        return self.local_ids["a_nz"].shape[1] + self.p * self.routes["expand_a"].T + 1

    @property
    def b_table_slots(self) -> int:
        return self.local_ids["b_nz"].shape[1] + self.p * self.routes["expand_b"].T + 1

    @property
    def n_c_slots(self) -> int:
        """Local C slots incl. the trailing garbage slot padding pairs hit."""
        return self.local_ids["c_nz"].shape[1] + 1


def _table_slots(
    part: np.ndarray,
    local_of: np.ndarray,
    route: Route,
    n_items: int,
    p: int,
) -> np.ndarray:
    """(p, n_items) map: global item id -> per-device slot in the
    ``[owned | received | zero]`` table; -1 where the device never sees it."""
    n_owned = 0 if n_items == 0 else int(local_of.max(initial=-1)) + 1
    # owned slots span [0, N_max); N_max from the padded list width
    slots = np.full((p, n_items), -1, dtype=np.int64)
    slots[part, np.arange(n_items, dtype=np.int64)] = local_of
    T = route.T
    s_ids, d_ids, t_ids = np.nonzero(route.recv_key >= 0)
    keys = route.recv_key[s_ids, d_ids, t_ids]
    slots[d_ids, keys] = n_owned + s_ids * T + t_ids
    return slots


def build_monoC_plan(
    inst: SpGEMMInstance,
    c_part: np.ndarray,
    p: int,
    a_part: np.ndarray | None = None,
    b_part: np.ndarray | None = None,
    word_size: int = 1,
) -> MonoCPlan:
    """Lower a monoC partition to routes + per-device BSR pair lists.

    ``inst`` may be a scalar instance or the block structure of a tiled one
    (tiling is a vertex coarsening — the plan is the same object either
    way); ``word_size`` records the payload words per shipped nonzero
    (b*b for b x b blocks) for volume accounting.
    """
    nA, nB, nC = inst.a.nnz, inst.b.nnz, inst.c.nnz
    c_part = np.asarray(c_part, dtype=np.int64)
    if a_part is None:
        a_part = np.arange(nA, dtype=np.int64) % p
    else:
        a_part = np.asarray(a_part, dtype=np.int64)
    if b_part is None:
        b_part = np.arange(nB, dtype=np.int64) % p
    else:
        b_part = np.asarray(b_part, dtype=np.int64)

    a_pos, b_pos, c_pos = inst.mult_a_pos, inst.mult_b_pos, inst.mult_c_pos
    mult_dev = c_part[c_pos]

    # expand routes: A nonzero ik is needed on every part owning a pin of
    # net n^A_ik (a multiplication it feeds); same for B — Ex. 5.4's nets
    local_a, local_of_a = padded_id_lists(a_part, p)
    src, dst, items = _expand_transfers(a_pos, mult_dev, a_part, p)
    route_a = build_route(src, dst, items, local_of_a, p, "A", word_size)
    local_b, local_of_b = padded_id_lists(b_part, p)
    src, dst, items = _expand_transfers(b_pos, mult_dev, b_part, p)
    route_b = build_route(src, dst, items, local_of_b, p, "B", word_size)
    local_c, local_of_c = padded_id_lists(c_part, p)

    # per-device pair lists in table slots (vectorized: one lexsort)
    a_slots = _table_slots(a_part, local_of_a, route_a, nA, p)
    b_slots = _table_slots(b_part, local_of_b, route_b, nB, p)
    pa = a_slots[mult_dev, a_pos]
    pb = b_slots[mult_dev, b_pos]
    pc = local_of_c[c_pos]
    assert (pa >= 0).all() and (pb >= 0).all(), "routing missed a needed nonzero"
    # group by device, then C slot ascending (kernel accumulates runs), then
    # operand slots for determinism
    order = np.lexsort((pb, pa, pc, mult_dev))
    pa, pb, pc, dev = pa[order], pb[order], pc[order], mult_dev[order]
    counts = np.bincount(dev, minlength=p)
    P_max = max(int(counts.max(initial=0)), 1)
    starts = np.cumsum(counts) - counts
    rank = np.arange(len(dev), dtype=np.int64) - np.repeat(starts, counts)
    # padding pairs hit the all-zero operand slots and the garbage C slot
    A_max, B_max, C_max = local_a.shape[1], local_b.shape[1], local_c.shape[1]
    pair_a = np.full((p, P_max), A_max + p * route_a.T, dtype=np.int64)
    pair_b = np.full((p, P_max), B_max + p * route_b.T, dtype=np.int64)
    pair_c = np.full((p, P_max), C_max, dtype=np.int64)
    pair_a[dev, rank] = pa
    pair_b[dev, rank] = pb
    pair_c[dev, rank] = pc

    return MonoCPlan(
        model="monoC",
        p=p,
        ownership={"c_nz": c_part, "a_nz": a_part, "b_nz": b_part},
        local_ids={"c_nz": local_c, "a_nz": local_a, "b_nz": local_b},
        routes={"expand_a": route_a, "expand_b": route_b},
        compute={"pair_a": pair_a, "pair_b": pair_b, "pair_c": pair_c},
        stats={"n_pairs": int(len(dev)), "pairs_padded": int(p * P_max)},
    )


def plan_monoC_from_dense(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    block: int,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
) -> tuple[MonoCPlan, SpGEMMInstance]:
    """Tile, model, partition, plan — the full monoC inspector pipeline.

    Tiling into b x b blocks is a vertex coarsening of the fine-grained
    hypergraph (DESIGN.md), so the monoC model of the *block* instance is
    partitioned and the resulting plan drives the BSR executor directly.
    Returns (plan, block instance) — the instance is also what
    ``unpack_monoC_result`` needs (``inst.c`` and the padded shapes).
    """
    from repro.core.partition import partition
    from repro.core.spgemm_models import build_model
    from repro.sparse.bsr import to_bsr

    ab = to_bsr(np.asarray(a_dense), block, block)
    bb = to_bsr(np.asarray(b_dense), block, block)
    inst = SpGEMMInstance(ab.block_structure(), bb.block_structure(), name="monoC")
    hg = build_model(inst, "monoC")
    res = partition(hg, p, eps=eps, seed=seed)
    plan = build_monoC_plan(inst, res.parts, p, word_size=block * block)
    return plan, inst
