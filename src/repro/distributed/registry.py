"""Declarative model registry: one ``ModelSpec`` per paper model.

The paper's thesis is that a hypergraph partition IS an SpGEMM algorithm;
this module is where each algorithm's pieces are declared in one place
instead of being re-dispatched by name at three independent call sites
(``select.build_executable_plan``'s if/elif chain, ``runtime``'s per-model
packing branches, and the ``EXECUTABLE`` tuple).  A ``ModelSpec`` bundles:

- ``build``: the hypergraph builder (Sec. 5 / Def. 3.1, via ``core``);
- ``lower``: partition -> ``ExecutionPlan`` (pin-derived ownership so the
  planned words equal the model's connectivity prediction);
- ``mesh_shape`` / ``axis_names``: the process-grid geometry the executor
  wants — monoC's ``(2, p//2)`` (``(1, p)`` for odd p, including p=1) lives
  HERE, not at call sites;
- ``make_runner``: the value-time executor core (packing closure + step
  function) the compile-once runtime AOT-compiles;
- ``unpack`` / ``pack_values``: device-major shards <-> caller value layout;
- ``item_words`` / ``measured``: how the plan's routed words relate to the
  model's predicted words (exact, useful-exact, or volume-only).

All seven paper models are fully executable (lowerer + runner + unpacker);
columnwise rides the rowwise machinery under ``C^T = B^T A^T``, and
monoA/monoB lower through the fine plan with multiplications colocated
with their stationary operand.  The registry also carries one entry that
is *not* a hypergraph model: ``"summa2d"``, the sparsity-oblivious Sparse
SUMMA baseline (``build is None`` — no hypergraph, no partition; the
lowerer goes straight from the instance).  It is excluded from
``model="auto"`` via ``in_auto=False`` so selection stays a contest among
the paper's models, with SUMMA always available as the competitor.

Everything jax-flavored is imported inside the runner factories so that
importing the registry (and therefore ``select``/``api``) stays light.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.spgemm_models import MODELS, SpGEMMInstance, build_model
from repro.distributed.plan_ir import (
    ExecutionPlan,
    build_fine_plan,
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
    derive_owner_from_pins,
)
from repro.distributed.summa import _lower_summa, _summa_runner, summa_mesh_shape


# ---------------------------------------------------------------------------
# runner plumbing shared by the factories
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunnerSetup:
    """What the compile-once runtime needs to AOT-compile one executor:
    a jit-compatible ``run(a_values, b_values) -> c_shards`` closure (route
    tables and scatter indices baked in as constants), the value shapes it
    was built for, and the dense shape ``unpack`` recovers."""

    run: Callable
    a_shape: tuple[int, ...]
    b_shape: tuple[int, ...]
    out_shape: tuple[int, int]


def vmap_batched_runner(make_runner: Callable) -> Callable:
    """Lift an unbatched runner factory to a batched one by ``jax.vmap``.

    The returned factory has the runner signature plus ``batch``: the
    compiled step maps over a leading batch axis on both value buffers, so
    one AOT executable streams ``batch`` same-structure multiplies per
    dispatch (multi-RHS products, MCL/AMG iterated chains).  This is the
    default ``ModelSpec.make_batched_runner`` — a spec whose step can't be
    vmapped (or has a faster hand-batched lowering) declares its own.
    """

    def make_batched(
        plan, a_structure, b_structure, mesh, *, batch, **kwargs
    ) -> RunnerSetup:
        import jax

        setup = make_runner(plan, a_structure, b_structure, mesh, **kwargs)
        return RunnerSetup(
            run=jax.vmap(setup.run),
            a_shape=(batch, *setup.a_shape),
            b_shape=(batch, *setup.b_shape),
            out_shape=setup.out_shape,
        )

    return make_batched


def owner_slot(local_ids: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Invert a padded per-device id list into global-id -> (device, slot)
    lookup arrays (every id appears exactly once by construction)."""
    dev = np.empty(n, dtype=np.int64)
    slot = np.empty(n, dtype=np.int64)
    d, s = np.nonzero(local_ids >= 0)
    g = local_ids[d, s]
    dev[g] = d
    slot[g] = s
    return dev, slot


# ---------------------------------------------------------------------------
# plan lowerers (partition -> ExecutionPlan, pin-derived ownership)
# ---------------------------------------------------------------------------
def _lower_rowwise(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    I, K, _ = inst.shape
    acsc = inst.a_csc
    ks = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
    b_part = derive_owner_from_pins(ks, parts[acsc.indices.astype(np.int64)], K, p)
    return build_rowwise_plan(inst, parts, p, b_part=b_part)


def _lower_outer(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    return build_outer_plan(inst, parts, p)


def _lower_monoC(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    mult_dev = parts[inst.mult_c_pos]
    a_part = derive_owner_from_pins(inst.mult_a_pos, mult_dev, inst.a.nnz, p)
    b_part = derive_owner_from_pins(inst.mult_b_pos, mult_dev, inst.b.nnz, p)
    return build_monoC_plan(inst, parts, p, a_part=a_part, b_part=b_part)


def _lower_fine(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    return build_fine_plan(inst, parts, p)


def _transposed_instance(inst: SpGEMMInstance) -> SpGEMMInstance:
    """The ``C^T = B^T A^T`` instance: columnwise of ``inst`` IS rowwise of
    this (identical hypergraph — vertex ``v_j`` keeps its index, net
    ``n^A_k`` keeps its pins and its ``nnz(A col k)`` cost)."""
    return SpGEMMInstance(
        inst.b.transpose(), inst.a.transpose(), name=f"{inst.name}^T"
    )


def _lower_columnwise(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    plan = _lower_rowwise(_transposed_instance(inst), parts, p)
    plan.model = "columnwise"
    return plan


def _lower_monoA(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    # monoA vertices are A nonzeros; colocating every multiplication with
    # its A nonzero makes expand_a empty, expand_b ship each b_kj to the
    # parts of A-column k (= the pins of B-net n^B_k, so items weighted by
    # the net's nnz(B row k) cost sum to exactly the B-net connectivity)
    # and reduce_c ship lambda - 1 partials per C net — measured == predicted
    parts = np.asarray(parts, dtype=np.int64)
    plan = build_fine_plan(inst, parts[inst.mult_a_pos], p, a_part=parts)
    plan.model = "monoA"
    return plan


def _lower_monoB(inst: SpGEMMInstance, parts: np.ndarray, p: int) -> ExecutionPlan:
    # symmetric to monoA with B stationary (vertices are B nonzeros in CSR
    # order, matching the monoB builder's pin convention)
    parts = np.asarray(parts, dtype=np.int64)
    plan = build_fine_plan(inst, parts[inst.mult_b_pos], p, b_part=parts)
    plan.model = "monoB"
    return plan


# ---------------------------------------------------------------------------
# runner factories (value-time executor cores; moved out of runtime's
# per-model branches — jax imported inside so the registry stays light)
# ---------------------------------------------------------------------------
def _rowwise_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    import jax.numpy as jnp

    from repro.distributed import spgemm_exec as _exec

    p = plan.p
    I, K = a_structure.shape
    _, J = b_structure.shape
    if len(plan.ownership["a_row"]) != I or len(plan.ownership["b_row"]) != K:
        raise ValueError("plan was built for different operand shapes")
    ar, ac = a_structure.coo()
    br, bc = b_structure.coo()
    rdev, rslot = owner_slot(plan.local_ids["a_row"], I)
    bdev, bslot = owner_slot(plan.local_ids["b_row"], K)
    I_max = plan.local_ids["a_row"].shape[1]
    K_max = plan.local_ids["b_row"].shape[1]
    a_idx = tuple(jnp.asarray(v) for v in (rdev[ar], rslot[ar], ac))
    b_idx = tuple(jnp.asarray(v) for v in (bdev[br], bslot[br], bc))
    step = _exec.make_rowwise_step(plan, mesh, K, J, axis=axis)

    def run(a_values, b_values):
        a_local = jnp.zeros((p, I_max, K), dtype).at[a_idx].set(a_values)
        b_local = jnp.zeros((p, K_max, J), dtype).at[b_idx].set(b_values)
        return step(a_local, b_local)

    return RunnerSetup(run, (a_structure.nnz,), (b_structure.nnz,), (I, J))


def _outer_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    import jax.numpy as jnp

    from repro.distributed import spgemm_exec as _exec

    p = plan.p
    I, K = a_structure.shape
    _, J = b_structure.shape
    if len(plan.ownership["k"]) != K:
        raise ValueError("plan was built for different operand shapes")
    ar, ac = a_structure.coo()
    br, bc = b_structure.coo()
    kdev, kslot = owner_slot(plan.local_ids["k"], K)
    K_max = plan.local_ids["k"].shape[1]
    a_idx = tuple(jnp.asarray(v) for v in (kdev[ac], ar, kslot[ac]))
    b_idx = tuple(jnp.asarray(v) for v in (kdev[br], kslot[br], bc))
    step = _exec.make_outer_step(plan, mesh, I, J, axis=axis)

    def run(a_values, b_values):
        a_cols = jnp.zeros((p, I, K_max), dtype).at[a_idx].set(a_values)
        b_rows = jnp.zeros((p, K_max, J), dtype).at[b_idx].set(b_values)
        return step(a_cols, b_rows)

    return RunnerSetup(run, (a_structure.nnz,), (b_structure.nnz,), (I, J))


def _fine_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    import jax.numpy as jnp

    from repro.distributed import spgemm_exec as _exec

    p = plan.p
    I, _ = a_structure.shape
    _, J = b_structure.shape
    nA, nB = a_structure.nnz, b_structure.nnz
    if nA != len(plan.a_part) or nB != len(plan.b_part):
        raise ValueError("plan was built for a different nonzero structure")
    adev, aslot = owner_slot(plan.local_ids["a_nz"], nA)
    bdev, bslot = owner_slot(plan.local_ids["b_nz"], nB)
    N_a = plan.local_ids["a_nz"].shape[1]
    N_b = plan.local_ids["b_nz"].shape[1]
    a_idx = (jnp.asarray(adev), jnp.asarray(aslot))
    b_idx = (jnp.asarray(bdev), jnp.asarray(bslot))
    step = _exec.make_fine_step(plan, mesh, axis=axis)

    def run(a_values, b_values):
        a_own = jnp.zeros((p, N_a), dtype).at[a_idx].set(a_values)
        b_own = jnp.zeros((p, N_b), dtype).at[b_idx].set(b_values)
        return step(a_own, b_own)

    return RunnerSetup(run, (nA,), (nB,), (I, J))


def _columnwise_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    # run rowwise on the transposed operands: the plan was lowered from the
    # C^T = B^T A^T instance, so the inner runner sees A' = B^T, B' = A^T
    # and produces C^T shards; values arrive in the *original* CSR orders
    # and are permuted into the transposed (col-major) orders on device
    import jax.numpy as jnp

    a_t = b_structure.transpose()
    b_t = a_structure.transpose()
    inner = _rowwise_runner(
        plan, a_t, b_t, mesh,
        dtype=dtype, block=block, backend=backend, axis=axis, axes=axes,
    )
    ar, ac = a_structure.coo()
    br, bc = b_structure.coo()
    # CSR order of X^T enumerates X's nonzeros sorted by (col, row)
    perm_a = jnp.asarray(np.lexsort((ar, ac)))
    perm_b = jnp.asarray(np.lexsort((br, bc)))

    def run(a_values, b_values):
        return inner.run(b_values[perm_b], a_values[perm_a])

    I, _ = a_structure.shape
    _, J = b_structure.shape
    return RunnerSetup(run, (a_structure.nnz,), (b_structure.nnz,), (I, J))


def _monoC_runner(plan, a_structure, b_structure, mesh, *, dtype, block, backend, axis, axes):
    # a_structure / b_structure are the BLOCK structures here; values are
    # (nnz, block, block) arrays in block CSR (= to_bsr) order
    import jax.numpy as jnp

    from repro.distributed import spgemm_exec as _exec

    p = plan.p
    I, _ = a_structure.shape
    _, J = b_structure.shape
    nA, nB = a_structure.nnz, b_structure.nnz
    if nA != len(plan.a_part) or nB != len(plan.b_part):
        raise ValueError("plan was built for a different block structure")
    adev, aslot = owner_slot(plan.local_ids["a_nz"], nA)
    bdev, bslot = owner_slot(plan.local_ids["b_nz"], nB)
    N_a = plan.local_ids["a_nz"].shape[1]
    N_b = plan.local_ids["b_nz"].shape[1]
    a_idx = (jnp.asarray(adev), jnp.asarray(aslot))
    b_idx = (jnp.asarray(bdev), jnp.asarray(bslot))
    step = _exec.make_monoC_step(plan, mesh, block=block, backend=backend, axes=axes)

    def run(a_values, b_values):
        a_own = jnp.zeros((p, N_a, block, block), dtype).at[a_idx].set(a_values)
        b_own = jnp.zeros((p, N_b, block, block), dtype).at[b_idx].set(b_values)
        return step(a_own, b_own)

    return RunnerSetup(
        run, (nA, block, block), (nB, block, block), (I * block, J * block)
    )


# ---------------------------------------------------------------------------
# unpackers (uniform signature; device-major shards -> dense array)
# ---------------------------------------------------------------------------
def _unpack_rowwise(c_local, plan, c_structure, shape):
    from repro.distributed.spgemm_exec import unpack_rowwise_result

    return unpack_rowwise_result(c_local, plan, shape[0])


def _unpack_columnwise(c_local, plan, c_structure, shape):
    from repro.distributed.spgemm_exec import unpack_rowwise_result

    # the inner rowwise step computed C^T over J rows; transpose back
    return unpack_rowwise_result(c_local, plan, shape[1]).T


def _unpack_outer(c_local, plan, c_structure, shape):
    return np.asarray(c_local).reshape(-1, shape[1])[: shape[0]]


def _unpack_monoC(c_local, plan, c_structure, shape):
    from repro.distributed.spgemm_exec import unpack_monoC_result

    return unpack_monoC_result(c_local, plan, c_structure, shape)


def _unpack_fine(c_local, plan, c_structure, shape):
    from repro.distributed.spgemm_exec import unpack_fine_result

    return unpack_fine_result(c_local, plan, c_structure, shape)


# ---------------------------------------------------------------------------
# value packing (canonical 1-D nonzero vectors -> executor value layout)
# ---------------------------------------------------------------------------
def _values_flat(vals: np.ndarray, block: int) -> np.ndarray:
    return vals


def _values_blocked(vals: np.ndarray, block: int) -> np.ndarray:
    return np.asarray(vals).reshape(-1, block, block)


# ---------------------------------------------------------------------------
# mesh geometry
# ---------------------------------------------------------------------------
def _mesh_1d(p: int, inst: SpGEMMInstance | None = None) -> tuple[int, ...]:
    return (p,)


def _mesh_monoC(p: int, inst: SpGEMMInstance | None = None) -> tuple[int, ...]:
    # the executor flattens the 2D mesh for its all_to_alls, so any
    # factorization of p works; (1, p) covers odd p (and p=1) — the former
    # caller-side "odd p skipped" quirk is gone
    return (2, p // 2) if p % 2 == 0 and p > 1 else (1, p)


# ---------------------------------------------------------------------------
# the spec and the registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Everything one paper model needs, declared in one place.

    ``measured`` states how the plan's route-counted words relate to the
    model's prediction: "exact" (replicated-free plans — words on the
    wire == the predicted words), "useful" (unit-cost prediction recovered
    by nnz-weighting / fold accounting), or None (no executor).

    ``build is None`` marks a partition-free baseline (summa2d): there is
    no hypergraph — the lowerer goes straight from the instance and the
    prediction is the plan's analytic ``stats["words_analytic"]``.
    ``in_auto`` gates membership in ``model="auto"`` selection; the SUMMA
    baseline is executable but never auto-selected."""

    name: str
    family: str  # "1D" | "2D" | "3D" (paper Sec. 5 classification)
    build: Callable | None  # (inst, include_nz=False) -> Hypergraph; None: no hypergraph
    lower: Callable | None = None  # (inst, parts, p) -> ExecutionPlan
    make_runner: Callable | None = None  # see RunnerSetup
    make_batched_runner: Callable | None = None  # (..., batch=n) -> RunnerSetup
    unpack: Callable | None = None  # (c_local, plan, c_structure, shape) -> dense
    mesh_shape: Callable = _mesh_1d  # (p, inst=None) -> process-grid shape
    axis_names: tuple[str, ...] = ("x",)
    pack_values: Callable = _values_flat  # (vals, block) -> executor layout
    item_words: Callable = lambda inst: None  # (inst) -> {route: words-per-item}
    needs_c_structure: bool = False  # unpack requires inst.c
    lower_include_nz: bool = False  # lowerer accepts include_nz partitions
    compile_defaults: dict = dataclasses.field(default_factory=dict)
    measured: str | None = None  # "exact" | "useful" | None
    in_auto: bool = True  # participates in model="auto" selection
    notes: str = ""

    @property
    def executable(self) -> bool:
        return self.lower is not None and self.make_runner is not None

    def make_setup(
        self, plan, a_structure, b_structure, mesh, *, batch=None, **kwargs
    ) -> RunnerSetup:
        """Build the executor core the runtime AOT-compiles.

        ``batch=None`` is the classic one-multiply step; ``batch=n`` returns
        the model's batched lowering (its declared ``make_batched_runner``,
        else the generic vmap lift) compiled for exactly ``n`` value sets.
        """
        if self.make_runner is None:
            raise ValueError(f"no runtime lowering for model {self.name!r}")
        if batch is None:
            return self.make_runner(plan, a_structure, b_structure, mesh, **kwargs)
        factory = self.make_batched_runner or vmap_batched_runner(self.make_runner)
        return factory(plan, a_structure, b_structure, mesh, batch=batch, **kwargs)

    def default_mesh(self, p: int, devices=None, instance=None):
        """Build the model's process grid over ``devices`` (default: the
        first p of ``jax.devices()``) — mesh geometry is a property of the
        algorithm, not of call sites.  ``instance`` lets shape hooks pick a
        non-square aspect from the operands (summa2d's ``(pr, pc)``)."""
        import jax
        from jax.sharding import Mesh

        devs = list(jax.devices() if devices is None else devices)
        if len(devs) < p:
            raise ValueError(
                f"{self.name} needs p={p} devices but only {len(devs)} available"
            )
        shape = self.mesh_shape(p, instance)
        return Mesh(np.array(devs[:p]).reshape(shape), self.axis_names)


def _build(model: str) -> Callable:
    def build(inst: SpGEMMInstance, include_nz: bool = False):
        return build_model(inst, model, include_nz=include_nz)

    return build


MODEL_SPECS: dict[str, ModelSpec] = {
    "fine": ModelSpec(
        name="fine",
        family="3D",
        build=_build("fine"),
        lower=_lower_fine,
        make_runner=_fine_runner,
        unpack=_unpack_fine,
        needs_c_structure=True,
        # build_fine_plan adopts include_nz vertex placements as ownership
        lower_include_nz=True,
        measured="exact",
        notes="flop-level partition; expand-expand-reduce; words == connectivity",
    ),
    "rowwise": ModelSpec(
        name="rowwise",
        family="1D",
        build=_build("rowwise"),
        lower=_lower_rowwise,
        make_runner=_rowwise_runner,
        unpack=_unpack_rowwise,
        item_words=lambda inst: {"expand": inst.b.row_counts()},
        measured="useful",
        notes="ships whole B rows; nnz-weighted route words == prediction",
    ),
    "columnwise": ModelSpec(
        name="columnwise",
        family="1D",
        build=_build("columnwise"),
        lower=_lower_columnwise,
        make_runner=_columnwise_runner,
        unpack=_unpack_columnwise,
        item_words=lambda inst: {"expand": inst.a.col_counts()},
        measured="useful",
        notes="rowwise under C^T = B^T A^T; ships whole A columns",
    ),
    "outer": ModelSpec(
        name="outer",
        family="1D",
        build=_build("outer"),
        lower=_lower_outer,
        make_runner=_outer_runner,
        unpack=_unpack_outer,
        measured="useful",
        notes="fold phase is psum_scatter; ideal fold words == prediction",
    ),
    "monoA": ModelSpec(
        name="monoA",
        family="2D",
        build=_build("monoA"),
        lower=_lower_monoA,
        make_runner=_fine_runner,
        unpack=_unpack_fine,
        needs_c_structure=True,
        measured="exact",
        notes="A nonzero stationary; mults colocated with A, fine executor",
    ),
    "monoB": ModelSpec(
        name="monoB",
        family="2D",
        build=_build("monoB"),
        lower=_lower_monoB,
        make_runner=_fine_runner,
        unpack=_unpack_fine,
        needs_c_structure=True,
        measured="exact",
        notes="B nonzero stationary; mults colocated with B, fine executor",
    ),
    "monoC": ModelSpec(
        name="monoC",
        family="2D",
        build=_build("monoC"),
        lower=_lower_monoC,
        make_runner=_monoC_runner,
        unpack=_unpack_monoC,
        mesh_shape=_mesh_monoC,
        axis_names=("x", "y"),
        pack_values=_values_blocked,
        needs_c_structure=True,
        # scalar instances (block=1) through the BSR kernel pay interpret-mode
        # overhead on CPU for no reuse; the dense XLA fallback is the right
        # local-compute default until a caller opts into Pallas explicitly
        compile_defaults={"backend": "xla"},
        measured="exact",
        notes="C nonzero lives on one device; 2D mesh, BSR local compute",
    ),
    # -- not a hypergraph model: the oblivious competitor ------------------
    "summa2d": ModelSpec(
        name="summa2d",
        family="2D",
        build=None,
        lower=_lower_summa,
        make_runner=_summa_runner,
        unpack=_unpack_monoC,  # same device-major owned-C slot layout
        mesh_shape=summa_mesh_shape,
        axis_names=("x", "y"),
        pack_values=_values_blocked,
        needs_c_structure=True,
        # same rationale as monoC: scalar blocks through the BSR kernel pay
        # interpret-mode overhead on CPU; dense XLA fallback by default
        compile_defaults={"backend": "xla"},
        measured="exact",
        in_auto=False,
        notes="Sparse SUMMA (Buluc-Gilbert): sparsity-oblivious 2D baseline",
    ),
}

#: models whose partitions never lower to an executor (they still predict);
#: empty since every paper model gained its executor, kept as API surface
VOLUME_ONLY = tuple(n for n in MODELS if not MODEL_SPECS[n].executable)

assert set(MODELS) <= set(MODEL_SPECS), "registry out of sync with core MODELS"


def get_spec(model: str) -> ModelSpec:
    try:
        return MODEL_SPECS[model]
    except KeyError:
        raise ValueError(
            f"unknown model {model!r}; choose from {tuple(MODEL_SPECS)}"
        ) from None


def executable_models() -> tuple[str, ...]:
    """Names of the paper models with a full plan-lowering + executor path
    that participate in ``model="auto"``, in ``MODELS`` order (the summa2d
    baseline is executable but excluded via ``in_auto=False``)."""
    return tuple(
        n for n in MODELS if MODEL_SPECS[n].executable and MODEL_SPECS[n].in_auto
    )
