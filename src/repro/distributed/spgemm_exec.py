"""Executor phase: shard_map SpGEMM algorithms.

These realize the paper's algorithm classes as compiled JAX programs:

- ``rowwise_spgemm``: 1D row-wise (Ex. 5.1) with a sparsity-dependent expand
  phase — one padded ``all_to_all`` whose payload is exactly the cut B-nets
  of the partition (plus padding), per ``RowwisePlan``.
- ``outer_product_spgemm``: 1D outer-product (Ex. 5.2) — local rank-|K_d|
  products and a fold phase realized as ``psum_scatter`` over C row blocks.
- ``monoC_spgemm``: 2D sparsity-dependent monochrome-C (Ex. 5.4) — every
  C (block-)nonzero lives on one device; the cut A-nets and B-nets lower to
  two padded ``all_to_all`` expand phases on a 2D mesh, and local compute
  streams the plan's pair lists through the BSR Pallas kernel
  (``bsr_spgemm_local``, interpret-mode fallback on CPU) so the executor's
  arithmetic is exactly the coarsened multiplication vertices the model
  counts.
- ``fine_spgemm``: 3D fine-grained (Def. 3.1) — an arbitrary flop-level
  partition drives an expand-expand-reduce schedule: two padded
  ``all_to_all`` phases ship the cut A- and B-nets, each device evaluates
  exactly its multiplication vertices into a produced-partial-C table, and a
  third ``all_to_all`` (the cut C-nets) folds foreign partials into each
  C nonzero's owner.  Every word any phase moves is one (cut net, part)
  pair of the partition — the connectivity metric made executable.
- ``spsumma``: the sparsity-independent 2D baseline (Buluç–Gilbert SpSUMMA):
  stationary-C with A broadcast along mesh rows and B along mesh columns.

Every sparsity-dependent executor consumes an ``ExecutionPlan``
(``plan_ir``): ownership maps + padded routing tables + local work lists.

Structure-time vs value-time split (DESIGN.md §8): each executor's math
lives in a ``make_*_step`` builder that closes over the plan's routing
tables and work lists as compile-time constants and returns a jit-compatible
function over device-major *packed* operand arrays.  The dense entry points
below are thin wrappers over ``repro.distributed.runtime.compile_spgemm``,
which scatters nonzero value vectors into the packed layout *inside* the
compiled program and AOT-compiles the whole executor once per
(plan, structure, mesh, dtype, backend) — repeated same-structure calls pay
no host packing, no route re-upload and no retracing.  Correctness oracle:
plain ``A @ B``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.distributed.plan_ir import FinePlan, MonoCPlan, OuterPlan, RowwisePlan


def _take0(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather leading-axis slices with -1 padding -> zero slices."""
    safe = jnp.maximum(idx, 0)
    rows = x[safe]
    mask = (idx >= 0).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, rows, 0)


# ---------------------------------------------------------------------------
# 1D row-wise (Ex. 5.1)
# ---------------------------------------------------------------------------
def make_rowwise_step(plan: RowwisePlan, mesh: Mesh, K: int, J: int, axis: str = "x"):
    """Jit-compatible row-wise executor core.

    Returns ``fn(a_local, b_local) -> c_local`` over device-major packed row
    tables (``a_local``: (p, I_max, K); ``b_local``: (p, K_max, J)); the
    plan's route tables enter as compile-time constants, uploaded once.
    """
    send_idx = jnp.asarray(plan.send_idx)  # (p, p, T)
    recv_key = jnp.asarray(plan.recv_key)  # (p, p, T)
    local_b_rows = jnp.asarray(plan.local_b_rows)  # (p, K_max)

    def step(a_blk, b_blk, send_idx_blk, recv_key_all, my_b_rows):
        # a_blk: (1, I_max, K); b_blk: (1, K_max, J) — this device's shard
        a_blk = a_blk[0]
        b_blk = b_blk[0]
        send_idx_blk = send_idx_blk[0]  # (p, T) rows I must ship to each dest
        # build the send buffer: (p, T, J)
        send_buf = jax.vmap(lambda idx: _take0(b_blk, idx))(send_idx_blk)
        # expand phase: single all_to_all — THE cut-B-net traffic
        recv_buf = jax.lax.all_to_all(
            send_buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        # recv_buf: (p, T, J) — from each source. Scatter into K-slot table.
        me = jax.lax.axis_index(axis)
        keys = recv_key_all[:, me]  # (p, T) global B-row ids arriving here
        table = jnp.zeros((K, J), b_blk.dtype)
        flat_keys = keys.reshape(-1)
        flat_rows = recv_buf.reshape(-1, J)
        ok = flat_keys >= 0
        table = table.at[jnp.where(ok, flat_keys, K - 1)].add(
            jnp.where(ok[:, None], flat_rows, 0)
        )
        # plus the rows I already own
        my_rows = _take0(b_blk, jnp.arange(b_blk.shape[0]))
        okb = my_b_rows[0] >= 0
        table = table.at[jnp.where(okb, my_b_rows[0], K - 1)].add(
            jnp.where(okb[:, None], my_rows, 0)
        )
        # local compute: my C rows
        return (a_blk @ table)[None]

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(axis)),
        out_specs=P(axis),
    )

    def fn(a_local, b_local):
        return shard(a_local, b_local, send_idx, recv_key, local_b_rows)

    return fn


def _dense_call_1d(plan, a_dense, b_dense, mesh: Mesh, axis: str) -> jnp.ndarray:
    """Shared dense entry for the 1D executors: derive structures, hit the
    runtime cache, and feed the nonzero values through the AOT executable."""
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.structure import from_dense

    a_dense = np.asarray(a_dense)
    b_dense = np.asarray(b_dense)
    a_s, b_s = from_dense(a_dense), from_dense(b_dense)
    exe = compile_spgemm(
        plan,
        a_s,
        b_s,
        mesh,
        dtype=np.promote_types(a_dense.dtype, b_dense.dtype),
        axis=axis,
    )
    ar, ac = a_s.coo()
    br, bc = b_s.coo()
    return exe(a_dense[ar, ac], b_dense[br, bc])


def rowwise_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: RowwisePlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """Sparsity-dependent 1D row-wise SpGEMM.  Returns C rows in plan order
    (device-major: C[d, r] = row ``plan.local_rows[d, r]``).

    Thin wrapper over the compile-once runtime: repeated calls with the same
    sparsity structure hit the cached AOT executable.
    """
    return _dense_call_1d(plan, a_dense, b_dense, mesh, axis)


def unpack_rowwise_result(c_local: jnp.ndarray, plan: RowwisePlan, I: int) -> np.ndarray:
    c_np = np.asarray(c_local)
    out = np.zeros((I, c_np.shape[-1]), dtype=c_np.dtype)
    dev, slot = np.nonzero(plan.local_rows >= 0)
    out[plan.local_rows[dev, slot]] = c_np[dev, slot]
    return out


# ---------------------------------------------------------------------------
# 1D outer-product (Ex. 5.2)
# ---------------------------------------------------------------------------
def make_outer_step(plan: OuterPlan, mesh: Mesh, I: int, J: int, axis: str = "x"):
    """Jit-compatible outer-product executor core.

    Returns ``fn(a_cols, b_rows) -> c_shards`` over device-major packed
    operand tables (``a_cols``: (p, I, K_max); ``b_rows``: (p, K_max, J)).
    """
    p = plan.p
    I_pad = (I + p - 1) // p * p

    def step(a_blk, b_blk):
        # a_blk: (1, I, K_max); b_blk: (1, K_max, J)
        partial_c = a_blk[0] @ b_blk[0]  # (I, J) partial sum
        partial_c = jnp.pad(partial_c, ((0, I_pad - I), (0, 0)))
        # fold phase: reduce-scatter C row blocks
        mine = jax.lax.psum_scatter(
            partial_c.reshape(p, I_pad // p, J), axis, scatter_dimension=0, tiled=False
        )
        return mine[None]

    return shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
    )


def outer_product_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: OuterPlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """1D outer-product SpGEMM: device d computes sum_{k in K_d} a_:k b_k:,
    fold phase reduces partial C over devices, scattering C row blocks.

    Returns C sharded by row blocks of size ceil(I/p) (device-major).  Thin
    wrapper over the compile-once runtime (see ``rowwise_spgemm``).
    """
    return _dense_call_1d(plan, a_dense, b_dense, mesh, axis)


def spsumma(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    mesh: Mesh,
    axes: tuple[str, str] = ("x", "y"),
) -> jnp.ndarray:
    """Sparse SUMMA (2D, stationary C): K-step loop broadcasting A panels
    along mesh rows and B panels along mesh columns via collective permutes
    (systolic variant — bandwidth-equivalent to broadcast SUMMA)."""
    ax_r, ax_c = axes
    pr, pc = mesh.shape[ax_r], mesh.shape[ax_c]
    I, K = a_dense.shape
    _, J = b_dense.shape
    I_p = (I + pr - 1) // pr * pr
    K_p = (K + pr * pc - 1) // (pr * pc) * (pr * pc)
    J_p = (J + pc - 1) // pc * pc
    a_pad = np.zeros((I_p, K_p), a_dense.dtype)
    a_pad[:I, :K] = a_dense
    b_pad = np.zeros((K_p, J_p), b_dense.dtype)
    b_pad[:K, :J] = b_dense

    def step(a_blk, b_blk):
        # a_blk: (I_p/pr, K_p/pc); b_blk: (K_p/pr, J_p/pc)
        # Cannon-style: skew, then pr*pc rotate-multiply steps over the K axis
        # Simpler: all_gather panels (volume identical to SUMMA broadcasts).
        a_row = jax.lax.all_gather(a_blk, ax_c, axis=1, tiled=True)  # (I/pr, K_p)
        b_col = jax.lax.all_gather(b_blk, ax_r, axis=0, tiled=True)  # (K_p, J/pc)
        return a_row @ b_col

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(ax_r, ax_c), P(ax_r, ax_c)),
        out_specs=P(ax_r, ax_c),
    )
    out = shard(jnp.asarray(a_pad), jnp.asarray(b_pad))
    return out[:I, :J]


# ---------------------------------------------------------------------------
# 2D monochrome-C (Ex. 5.4)
# ---------------------------------------------------------------------------
def make_monoC_step(
    plan: MonoCPlan,
    mesh: Mesh,
    block: int = 8,
    backend: str | None = None,
    axes: tuple[str, str] = ("x", "y"),
):
    """Jit-compatible monochrome-C executor core.

    Returns ``fn(a_own, b_own) -> c_local`` over device-major packed block
    tables ((p, N_max, b, b)); route tables and BSR pair lists enter as
    compile-time constants.
    """
    from repro.kernels.bsr_spgemm import bsr_spgemm_local

    p = plan.p
    route_a, route_b = plan.routes["expand_a"], plan.routes["expand_b"]
    T_a, T_b = route_a.T, route_b.T
    n_c_slots = plan.n_c_slots
    sa = jnp.asarray(route_a.send_idx)
    sb = jnp.asarray(route_b.send_idx)
    pa = jnp.asarray(plan.compute["pair_a"], jnp.int32)
    pb = jnp.asarray(plan.compute["pair_b"], jnp.int32)
    pc = jnp.asarray(plan.compute["pair_c"], jnp.int32)

    def expand(own, send_idx_blk, T):
        # own: (N_max, b, b); send_idx_blk: (p, T) local slots to ship
        buf = _take0(own, send_idx_blk.reshape(-1)).reshape(p, T, block, block)
        # THE cut-net traffic of this operand: one all_to_all over the
        # flattened 2D mesh
        recv = jax.lax.all_to_all(
            buf[None], axes, split_axis=1, concat_axis=1, tiled=False
        )[0]
        zero = jnp.zeros((1, block, block), own.dtype)
        return jnp.concatenate([own, recv.reshape(p * T, block, block), zero], 0)

    def step(a_blk, b_blk, sa_, sb_, pa_, pb_, pc_):
        a_tab = expand(a_blk[0], sa_[0], T_a)
        b_tab = expand(b_blk[0], sb_[0], T_b)
        c = bsr_spgemm_local(
            a_tab, b_tab, pa_[0], pb_[0], pc_[0], n_c_blocks=n_c_slots, backend=backend
        )
        return c[None]

    spec = P(axes)
    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(spec,) * 7,
        out_specs=spec,
    )

    def fn(a_own, b_own):
        return shard(a_own, b_own, sa, sb, pa, pb, pc)

    return fn


def monoC_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: MonoCPlan,
    mesh: Mesh,
    axes: tuple[str, str] = ("x", "y"),
    block: int = 8,
    backend: str | None = None,
) -> jnp.ndarray:
    """2D sparsity-dependent monochrome-C SpGEMM (Ex. 5.4).

    ``plan`` must have been built on the b x b block structures of the
    operands (``plan_ir.plan_monoC_from_dense`` does both steps): C block
    (i, j) lives on one device; two padded ``all_to_all`` phases over the
    flattened 2D mesh ship exactly the cut A-nets and B-nets, after which
    each device streams its pair list through the BSR kernel path
    (``bsr_spgemm_local`` — Pallas on TPU, interpret-mode fallback on CPU,
    optional XLA dense fallback) over slot tables laid out as
    ``[owned | received | zero]``.

    Returns device-major C block shards (p, C_max + 1, b, b); the trailing
    slot per device is the padding sink.  Use ``unpack_monoC_result``.  Thin
    wrapper over the compile-once runtime: the tiling here is the only
    per-call structure work, and same-structure calls hit the cached AOT
    executable.
    """
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.bsr import to_bsr

    ab = to_bsr(np.asarray(a_dense), block, block)
    bb = to_bsr(np.asarray(b_dense), block, block)
    if len(plan.a_part) != ab.n_blocks or len(plan.b_part) != bb.n_blocks:
        raise ValueError("plan was built for a different block structure")
    exe = compile_spgemm(
        plan,
        ab.block_structure(),
        bb.block_structure(),
        mesh,
        dtype=np.promote_types(ab.blocks.dtype, bb.blocks.dtype),
        backend=backend,
        block=block,
        axes=axes,
    )
    return exe(ab.blocks, bb.blocks)


def unpack_monoC_result(
    c_local: jnp.ndarray,
    plan: MonoCPlan,
    c_structure,
    shape: tuple[int, int],
) -> np.ndarray:
    """Scatter device-major C block slots back to a dense array.

    ``c_structure`` is the block-grid structure of C (``inst.c`` of the plan
    instance); ``shape`` the padded dense shape (block-grid * block).
    """
    c_np = np.asarray(c_local)
    b = c_np.shape[-1]
    gr, gc = shape[0] // b, shape[1] // b
    crow, ccol = c_structure.coo()
    out = np.zeros((gr, gc, b, b), dtype=c_np.dtype)
    local_c = plan.local_ids["c_nz"]
    dev, slot = np.nonzero(local_c >= 0)
    gids = local_c[dev, slot]
    out[crow[gids], ccol[gids]] = c_np[dev, slot]
    return out.transpose(0, 2, 1, 3).reshape(shape)


# ---------------------------------------------------------------------------
# 3D fine-grained (Def. 3.1)
# ---------------------------------------------------------------------------
def make_fine_step(plan: FinePlan, mesh: Mesh, axis: str = "x"):
    """Jit-compatible fine-grained executor core (expand-expand-reduce).

    Returns ``fn(a_own, b_own) -> c_local`` over device-major packed scalar
    slot tables ((p, N_max)); all three route tables, the multiplication
    lists and the reduce/fold maps enter as compile-time constants.
    """
    p = plan.p
    route_a = plan.routes["expand_a"]
    route_b = plan.routes["expand_b"]
    route_r = plan.routes["reduce_c"]
    T_a, T_b, T_r = route_a.T, route_b.T, route_r.T
    R_max = plan.local_ids["c_prod"].shape[1]
    C_max = plan.local_ids["c_nz"].shape[1]
    sa = jnp.asarray(route_a.send_idx)
    sb = jnp.asarray(route_b.send_idx)
    sr = jnp.asarray(route_r.send_idx)
    pa = jnp.asarray(plan.compute["pair_a"])
    pb = jnp.asarray(plan.compute["pair_b"])
    pc = jnp.asarray(plan.compute["pair_c"])
    recv_slot = jnp.asarray(plan.compute["reduce_recv_slot"])
    prod_own = jnp.asarray(plan.compute["prod_to_owned"])

    def expand(own, send_idx_blk, T):
        # own: (N_max,); ship my cut-net scalars, receive the foreign ones
        buf = _take0(own, send_idx_blk.reshape(-1)).reshape(p, T)
        recv = jax.lax.all_to_all(
            buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        zero = jnp.zeros((1,), own.dtype)
        return jnp.concatenate([own, recv.reshape(p * T), zero], 0)

    def step(a_blk, b_blk, sa_, sb_, sr_, pa_, pb_, pc_, recv_slot_all, prod_own_):
        a_tab = expand(a_blk[0], sa_[0], T_a)
        b_tab = expand(b_blk[0], sb_[0], T_b)
        # local compute: exactly this device's multiplication vertices
        prods = a_tab[pa_[0]] * b_tab[pb_[0]]
        partial = jnp.zeros((R_max + 1,), a_tab.dtype).at[pc_[0]].add(prods)
        # reduce phase: ship foreign partials to their C owners
        buf = _take0(partial, sr_[0].reshape(-1)).reshape(p, T_r)
        recv = jax.lax.all_to_all(
            buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        me = jax.lax.axis_index(axis)
        slots = recv_slot_all[:, me].reshape(-1)  # owned-C slot per arrival
        ok = slots >= 0
        c = jnp.zeros((C_max + 1,), a_tab.dtype)
        c = c.at[jnp.where(ok, slots, C_max)].add(
            jnp.where(ok, recv.reshape(-1), 0)
        )
        # partials this device both produced and owns fold locally
        own_map = prod_own_[0]
        okp = own_map >= 0
        c = c.at[jnp.where(okp, own_map, C_max)].add(
            jnp.where(okp, partial[:R_max], 0)
        )
        return c[None]

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis),) * 8 + (P(), P(axis)),
        out_specs=P(axis),
    )

    def fn(a_own, b_own):
        return shard(a_own, b_own, sa, sb, sr, pa, pb, pc, recv_slot, prod_own)

    return fn


def fine_spgemm(
    a,
    b,
    plan: FinePlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """3D fine-grained SpGEMM (Def. 3.1): expand-expand-reduce.

    ``plan`` is a ``FinePlan`` over the scalar nonzero structures of the
    operands (``plan_ir.plan_fine_from_dense`` builds both).  Three padded
    ``all_to_all`` phases over the 1D device axis realize the three cut-net
    families of the fine hypergraph partition:

    1. A-expand: each device receives the foreign A nonzeros its
       multiplications read (slot table ``[owned | received | zero]``);
    2. B-expand: same for B;
    3. local compute: the device's multiplication list is two gathers, an
       elementwise product, and a segment-add into its produced-partial-C
       table — exactly its multiplication vertices, no more;
    4. C-reduce: foreign partials ship to each C nonzero's owner and fold
       into the owned-C table; partials the producer already owns fold
       locally through ``prod_to_owned``.

    ``a`` / ``b`` may each be a dense array, a scipy sparse matrix, or an
    ``(SparseStructure, values)`` pair — callers that already hold sparse
    operands never densify.  Returns device-major owned-C slot values
    (p, C_max + 1); the trailing slot per device is the padding sink.  Use
    ``unpack_fine_result``.  Thin wrapper over the compile-once runtime.
    """
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.structure import structure_and_values

    a_s, a_vals = structure_and_values(a)
    b_s, b_vals = structure_and_values(b)
    if a_s.nnz != len(plan.a_part) or b_s.nnz != len(plan.b_part):
        raise ValueError("plan was built for a different nonzero structure")
    exe = compile_spgemm(
        plan,
        a_s,
        b_s,
        mesh,
        dtype=np.promote_types(a_vals.dtype, b_vals.dtype),
        axis=axis,
    )
    return exe(a_vals, b_vals)


def unpack_fine_result(
    c_local: jnp.ndarray,
    plan: FinePlan,
    c_structure,
    shape: tuple[int, int],
) -> np.ndarray:
    """Scatter device-major owned-C slot values back to a dense array."""
    c_np = np.asarray(c_local)
    crow, ccol = c_structure.coo()
    out = np.zeros(shape, dtype=c_np.dtype)
    local_c = plan.local_ids["c_nz"]
    dev, slot = np.nonzero(local_c >= 0)
    gids = local_c[dev, slot]
    out[crow[gids], ccol[gids]] = c_np[dev, slot]
    return out
