"""Executor phase: shard_map SpGEMM algorithms.

These realize the paper's algorithm classes as compiled JAX programs:

- ``rowwise_spgemm``: 1D row-wise (Ex. 5.1) with a sparsity-dependent expand
  phase — one padded ``all_to_all`` whose payload is exactly the cut B-nets
  of the partition (plus padding), per ``RowwisePlan``.
- ``outer_product_spgemm``: 1D outer-product (Ex. 5.2) — local rank-|K_d|
  products and a fold phase realized as ``psum_scatter`` over C row blocks.
- ``spsumma``: the sparsity-independent 2D baseline (Buluç–Gilbert SpSUMMA):
  stationary-C with A broadcast along mesh rows and B along mesh columns.

Matrix values are dense arrays at validation scale (structure handling is
host-side; local compute at production scale goes through the BSR Pallas
kernels in ``repro.kernels``).  Correctness oracle: plain ``A @ B``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.plan import OuterPlan, RowwisePlan


def _take0(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather rows with -1 padding -> zero rows."""
    safe = jnp.maximum(idx, 0)
    rows = x[safe]
    return jnp.where((idx >= 0)[:, None], rows, 0)


def rowwise_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: RowwisePlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """Sparsity-dependent 1D row-wise SpGEMM.  Returns C rows in plan order
    (device-major: C[d, r] = row ``plan.local_rows[d, r]``)."""
    p = plan.p
    I, K = a_dense.shape
    _, J = b_dense.shape

    # host-side packing (inspector output -> device-major arrays)
    a_local = np.zeros((p, plan.local_rows.shape[1], K), a_dense.dtype)
    for d in range(p):
        rows = plan.local_rows[d]
        valid = rows >= 0
        a_local[d, valid] = a_dense[rows[valid]]
    b_local = np.zeros((p, plan.local_b_rows.shape[1], J), b_dense.dtype)
    for d in range(p):
        rows = plan.local_b_rows[d]
        valid = rows >= 0
        b_local[d, valid] = b_dense[rows[valid]]

    send_idx = jnp.asarray(plan.send_idx)  # (p, p, T)
    recv_key = jnp.asarray(plan.recv_key)  # (p, p, T)
    local_b_rows = jnp.asarray(plan.local_b_rows)  # (p, K_max)

    def step(a_blk, b_blk, send_idx_blk, recv_key_all, my_b_rows):
        # a_blk: (1, I_max, K); b_blk: (1, K_max, J) — this device's shard
        a_blk = a_blk[0]
        b_blk = b_blk[0]
        send_idx_blk = send_idx_blk[0]  # (p, T) rows I must ship to each dest
        # build the send buffer: (p, T, J)
        send_buf = jax.vmap(lambda idx: _take0(b_blk, idx))(send_idx_blk)
        # expand phase: single all_to_all — THE cut-B-net traffic
        recv_buf = jax.lax.all_to_all(
            send_buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        # recv_buf: (p, T, J) — from each source. Scatter into K-slot table.
        me = jax.lax.axis_index(axis)
        keys = recv_key_all[:, me]  # (p, T) global B-row ids arriving here
        table = jnp.zeros((K, J), b_blk.dtype)
        flat_keys = keys.reshape(-1)
        flat_rows = recv_buf.reshape(-1, J)
        ok = flat_keys >= 0
        table = table.at[jnp.where(ok, flat_keys, K - 1)].add(
            jnp.where(ok[:, None], flat_rows, 0)
        )
        # plus the rows I already own
        my_rows = _take0(b_blk, jnp.arange(b_blk.shape[0]))
        okb = my_b_rows[0] >= 0
        table = table.at[jnp.where(okb, my_b_rows[0], K - 1)].add(
            jnp.where(okb[:, None], my_rows, 0)
        )
        # local compute: my C rows
        return (a_blk @ table)[None]

    shard = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )
    c_local = shard(
        jnp.asarray(a_local),
        jnp.asarray(b_local),
        send_idx,
        recv_key,
        local_b_rows,
    )
    return c_local  # (p, I_max, J)


def unpack_rowwise_result(c_local: jnp.ndarray, plan: RowwisePlan, I: int) -> np.ndarray:
    out = np.zeros((I, c_local.shape[-1]), dtype=np.asarray(c_local).dtype)
    c_np = np.asarray(c_local)
    for d in range(plan.p):
        rows = plan.local_rows[d]
        valid = rows >= 0
        out[rows[valid]] = c_np[d, valid]
    return out


def outer_product_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: OuterPlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """1D outer-product SpGEMM: device d computes sum_{k in K_d} a_:k b_k:,
    fold phase reduces partial C over devices, scattering C row blocks.

    Returns C sharded by row blocks of size ceil(I/p) (device-major).
    """
    p = plan.p
    I, K = a_dense.shape
    _, J = b_dense.shape
    K_max = plan.local_ks.shape[1]
    I_pad = (I + p - 1) // p * p

    a_cols = np.zeros((p, I, K_max), a_dense.dtype)
    b_rows = np.zeros((p, K_max, J), b_dense.dtype)
    for d in range(p):
        ks = plan.local_ks[d]
        valid = ks >= 0
        a_cols[d, :, valid] = a_dense[:, ks[valid]].T
        b_rows[d, valid] = b_dense[ks[valid]]

    def step(a_blk, b_blk):
        # a_blk: (1, I, K_max); b_blk: (1, K_max, J)
        partial_c = a_blk[0] @ b_blk[0]  # (I, J) partial sum
        partial_c = jnp.pad(partial_c, ((0, I_pad - I), (0, 0)))
        # fold phase: reduce-scatter C row blocks
        mine = jax.lax.psum_scatter(
            partial_c.reshape(p, I_pad // p, J), axis, scatter_dimension=0, tiled=False
        )
        return mine[None]

    shard = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )
    return shard(jnp.asarray(a_cols), jnp.asarray(b_rows))  # (p, I_pad//p, J)


def spsumma(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    mesh: Mesh,
    axes: tuple[str, str] = ("x", "y"),
) -> jnp.ndarray:
    """Sparse SUMMA (2D, stationary C): K-step loop broadcasting A panels
    along mesh rows and B panels along mesh columns via collective permutes
    (systolic variant — bandwidth-equivalent to broadcast SUMMA)."""
    ax_r, ax_c = axes
    pr, pc = mesh.shape[ax_r], mesh.shape[ax_c]
    I, K = a_dense.shape
    _, J = b_dense.shape
    I_p = (I + pr - 1) // pr * pr
    K_p = (K + pr * pc - 1) // (pr * pc) * (pr * pc)
    J_p = (J + pc - 1) // pc * pc
    a_pad = np.zeros((I_p, K_p), a_dense.dtype)
    a_pad[:I, :K] = a_dense
    b_pad = np.zeros((K_p, J_p), b_dense.dtype)
    b_pad[:K, :J] = b_dense

    def step(a_blk, b_blk):
        # a_blk: (I_p/pr, K_p/pc); b_blk: (K_p/pr, J_p/pc)
        # Cannon-style: skew, then pr*pc rotate-multiply steps over the K axis
        # Simpler: all_gather panels (volume identical to SUMMA broadcasts).
        a_row = jax.lax.all_gather(a_blk, ax_c, axis=1, tiled=True)  # (I/pr, K_p)
        b_col = jax.lax.all_gather(b_blk, ax_r, axis=0, tiled=True)  # (K_p, J/pc)
        return a_row @ b_col

    shard = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(ax_r, ax_c), P(ax_r, ax_c)),
        out_specs=P(ax_r, ax_c),
        check_vma=False,
    )
    out = shard(jnp.asarray(a_pad), jnp.asarray(b_pad))
    return out[:I, :J]
