"""Executor phase: shard_map SpGEMM algorithms.

These realize the paper's algorithm classes as compiled JAX programs:

- ``rowwise_spgemm``: 1D row-wise (Ex. 5.1) with a sparsity-dependent expand
  phase — one padded ``all_to_all`` whose payload is exactly the cut B-nets
  of the partition (plus padding), per ``RowwisePlan``.
- ``outer_product_spgemm``: 1D outer-product (Ex. 5.2) — local rank-|K_d|
  products and a fold phase realized as ``psum_scatter`` over C row blocks.
- ``monoC_spgemm``: 2D sparsity-dependent monochrome-C (Ex. 5.4) — every
  C (block-)nonzero lives on one device; the cut A-nets and B-nets lower to
  two padded ``all_to_all`` expand phases on a 2D mesh, and local compute
  streams the plan's pair lists through the BSR Pallas kernel
  (``bsr_spgemm_local``, interpret-mode fallback on CPU) so the executor's
  arithmetic is exactly the coarsened multiplication vertices the model
  counts.
- ``fine_spgemm``: 3D fine-grained (Def. 3.1) — an arbitrary flop-level
  partition drives an expand-expand-reduce schedule: two padded
  ``all_to_all`` phases ship the cut A- and B-nets, each device evaluates
  exactly its multiplication vertices into a produced-partial-C table, and a
  third ``all_to_all`` (the cut C-nets) folds foreign partials into each
  C nonzero's owner.  Every word any phase moves is one (cut net, part)
  pair of the partition — the connectivity metric made executable.
- ``spsumma``: the sparsity-independent 2D baseline (Buluç–Gilbert SpSUMMA):
  stationary-C with A broadcast along mesh rows and B along mesh columns.

Every sparsity-dependent executor consumes an ``ExecutionPlan``
(``plan_ir``): ownership maps + padded routing tables + local work lists.
Matrix values are dense arrays at validation scale (structure handling is
host-side; local compute at production scale goes through the BSR Pallas
kernels in ``repro.kernels``).  Correctness oracle: plain ``A @ B``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.distributed.plan_ir import FinePlan, MonoCPlan, OuterPlan, RowwisePlan


def _take0(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather leading-axis slices with -1 padding -> zero slices."""
    safe = jnp.maximum(idx, 0)
    rows = x[safe]
    mask = (idx >= 0).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, rows, 0)


def rowwise_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: RowwisePlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """Sparsity-dependent 1D row-wise SpGEMM.  Returns C rows in plan order
    (device-major: C[d, r] = row ``plan.local_rows[d, r]``)."""
    p = plan.p
    I, K = a_dense.shape
    _, J = b_dense.shape

    # host-side packing (inspector output -> device-major arrays)
    a_local = np.zeros((p, plan.local_rows.shape[1], K), a_dense.dtype)
    for d in range(p):
        rows = plan.local_rows[d]
        valid = rows >= 0
        a_local[d, valid] = a_dense[rows[valid]]
    b_local = np.zeros((p, plan.local_b_rows.shape[1], J), b_dense.dtype)
    for d in range(p):
        rows = plan.local_b_rows[d]
        valid = rows >= 0
        b_local[d, valid] = b_dense[rows[valid]]

    send_idx = jnp.asarray(plan.send_idx)  # (p, p, T)
    recv_key = jnp.asarray(plan.recv_key)  # (p, p, T)
    local_b_rows = jnp.asarray(plan.local_b_rows)  # (p, K_max)

    def step(a_blk, b_blk, send_idx_blk, recv_key_all, my_b_rows):
        # a_blk: (1, I_max, K); b_blk: (1, K_max, J) — this device's shard
        a_blk = a_blk[0]
        b_blk = b_blk[0]
        send_idx_blk = send_idx_blk[0]  # (p, T) rows I must ship to each dest
        # build the send buffer: (p, T, J)
        send_buf = jax.vmap(lambda idx: _take0(b_blk, idx))(send_idx_blk)
        # expand phase: single all_to_all — THE cut-B-net traffic
        recv_buf = jax.lax.all_to_all(
            send_buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        # recv_buf: (p, T, J) — from each source. Scatter into K-slot table.
        me = jax.lax.axis_index(axis)
        keys = recv_key_all[:, me]  # (p, T) global B-row ids arriving here
        table = jnp.zeros((K, J), b_blk.dtype)
        flat_keys = keys.reshape(-1)
        flat_rows = recv_buf.reshape(-1, J)
        ok = flat_keys >= 0
        table = table.at[jnp.where(ok, flat_keys, K - 1)].add(
            jnp.where(ok[:, None], flat_rows, 0)
        )
        # plus the rows I already own
        my_rows = _take0(b_blk, jnp.arange(b_blk.shape[0]))
        okb = my_b_rows[0] >= 0
        table = table.at[jnp.where(okb, my_b_rows[0], K - 1)].add(
            jnp.where(okb[:, None], my_rows, 0)
        )
        # local compute: my C rows
        return (a_blk @ table)[None]

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(axis)),
        out_specs=P(axis),
    )
    c_local = shard(
        jnp.asarray(a_local),
        jnp.asarray(b_local),
        send_idx,
        recv_key,
        local_b_rows,
    )
    return c_local  # (p, I_max, J)


def unpack_rowwise_result(c_local: jnp.ndarray, plan: RowwisePlan, I: int) -> np.ndarray:
    out = np.zeros((I, c_local.shape[-1]), dtype=np.asarray(c_local).dtype)
    c_np = np.asarray(c_local)
    for d in range(plan.p):
        rows = plan.local_rows[d]
        valid = rows >= 0
        out[rows[valid]] = c_np[d, valid]
    return out


def outer_product_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: OuterPlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """1D outer-product SpGEMM: device d computes sum_{k in K_d} a_:k b_k:,
    fold phase reduces partial C over devices, scattering C row blocks.

    Returns C sharded by row blocks of size ceil(I/p) (device-major).
    """
    p = plan.p
    I, K = a_dense.shape
    _, J = b_dense.shape
    K_max = plan.local_ks.shape[1]
    I_pad = (I + p - 1) // p * p

    a_cols = np.zeros((p, I, K_max), a_dense.dtype)
    b_rows = np.zeros((p, K_max, J), b_dense.dtype)
    for d in range(p):
        ks = plan.local_ks[d]
        valid = ks >= 0
        a_cols[d, :, valid] = a_dense[:, ks[valid]].T
        b_rows[d, valid] = b_dense[ks[valid]]

    def step(a_blk, b_blk):
        # a_blk: (1, I, K_max); b_blk: (1, K_max, J)
        partial_c = a_blk[0] @ b_blk[0]  # (I, J) partial sum
        partial_c = jnp.pad(partial_c, ((0, I_pad - I), (0, 0)))
        # fold phase: reduce-scatter C row blocks
        mine = jax.lax.psum_scatter(
            partial_c.reshape(p, I_pad // p, J), axis, scatter_dimension=0, tiled=False
        )
        return mine[None]

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
    )
    return shard(jnp.asarray(a_cols), jnp.asarray(b_rows))  # (p, I_pad//p, J)


def spsumma(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    mesh: Mesh,
    axes: tuple[str, str] = ("x", "y"),
) -> jnp.ndarray:
    """Sparse SUMMA (2D, stationary C): K-step loop broadcasting A panels
    along mesh rows and B panels along mesh columns via collective permutes
    (systolic variant — bandwidth-equivalent to broadcast SUMMA)."""
    ax_r, ax_c = axes
    pr, pc = mesh.shape[ax_r], mesh.shape[ax_c]
    I, K = a_dense.shape
    _, J = b_dense.shape
    I_p = (I + pr - 1) // pr * pr
    K_p = (K + pr * pc - 1) // (pr * pc) * (pr * pc)
    J_p = (J + pc - 1) // pc * pc
    a_pad = np.zeros((I_p, K_p), a_dense.dtype)
    a_pad[:I, :K] = a_dense
    b_pad = np.zeros((K_p, J_p), b_dense.dtype)
    b_pad[:K, :J] = b_dense

    def step(a_blk, b_blk):
        # a_blk: (I_p/pr, K_p/pc); b_blk: (K_p/pr, J_p/pc)
        # Cannon-style: skew, then pr*pc rotate-multiply steps over the K axis
        # Simpler: all_gather panels (volume identical to SUMMA broadcasts).
        a_row = jax.lax.all_gather(a_blk, ax_c, axis=1, tiled=True)  # (I/pr, K_p)
        b_col = jax.lax.all_gather(b_blk, ax_r, axis=0, tiled=True)  # (K_p, J/pc)
        return a_row @ b_col

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(ax_r, ax_c), P(ax_r, ax_c)),
        out_specs=P(ax_r, ax_c),
    )
    out = shard(jnp.asarray(a_pad), jnp.asarray(b_pad))
    return out[:I, :J]


def monoC_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: MonoCPlan,
    mesh: Mesh,
    axes: tuple[str, str] = ("x", "y"),
    block: int = 8,
    backend: str | None = None,
) -> jnp.ndarray:
    """2D sparsity-dependent monochrome-C SpGEMM (Ex. 5.4).

    ``plan`` must have been built on the b x b block structures of the
    operands (``plan_ir.plan_monoC_from_dense`` does both steps): C block
    (i, j) lives on one device; two padded ``all_to_all`` phases over the
    flattened 2D mesh ship exactly the cut A-nets and B-nets, after which
    each device streams its pair list through the BSR kernel path
    (``bsr_spgemm_local`` — Pallas on TPU, interpret-mode fallback on CPU,
    optional XLA dense fallback) over slot tables laid out as
    ``[owned | received | zero]``.

    Returns device-major C block shards (p, C_max + 1, b, b); the trailing
    slot per device is the padding sink.  Use ``unpack_monoC_result``.
    """
    from repro.kernels.bsr_spgemm import bsr_spgemm_local
    from repro.sparse.bsr import to_bsr

    p = plan.p
    if mesh.devices.size != p:
        raise ValueError(f"plan is for p={p} but mesh has {mesh.devices.size} devices")
    ab = to_bsr(a_dense, block, block)
    bb = to_bsr(b_dense, block, block)
    if len(plan.a_part) != ab.n_blocks or len(plan.b_part) != bb.n_blocks:
        raise ValueError("plan was built for a different block structure")
    route_a, route_b = plan.routes["expand_a"], plan.routes["expand_b"]
    T_a, T_b = route_a.T, route_b.T
    n_c_slots = plan.n_c_slots

    def pack(blocks, local_ids):
        out = np.zeros((p, local_ids.shape[1], block, block), blocks.dtype)
        dev, slot = np.nonzero(local_ids >= 0)
        out[dev, slot] = blocks[local_ids[dev, slot]]
        return out

    a_own = pack(ab.blocks, plan.local_ids["a_nz"])
    b_own = pack(bb.blocks, plan.local_ids["b_nz"])

    def expand(own, send_idx_blk, T):
        # own: (N_max, b, b); send_idx_blk: (p, T) local slots to ship
        buf = _take0(own, send_idx_blk.reshape(-1)).reshape(p, T, block, block)
        # THE cut-net traffic of this operand: one all_to_all over the
        # flattened 2D mesh
        recv = jax.lax.all_to_all(
            buf[None], axes, split_axis=1, concat_axis=1, tiled=False
        )[0]
        zero = jnp.zeros((1, block, block), own.dtype)
        return jnp.concatenate([own, recv.reshape(p * T, block, block), zero], 0)

    def step(a_blk, b_blk, sa, sb, pa, pb, pc):
        a_tab = expand(a_blk[0], sa[0], T_a)
        b_tab = expand(b_blk[0], sb[0], T_b)
        c = bsr_spgemm_local(
            a_tab, b_tab, pa[0], pb[0], pc[0], n_c_blocks=n_c_slots, backend=backend
        )
        return c[None]

    spec = P(axes)
    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(spec,) * 7,
        out_specs=spec,
    )
    return shard(
        jnp.asarray(a_own),
        jnp.asarray(b_own),
        jnp.asarray(route_a.send_idx),
        jnp.asarray(route_b.send_idx),
        jnp.asarray(plan.compute["pair_a"], jnp.int32),
        jnp.asarray(plan.compute["pair_b"], jnp.int32),
        jnp.asarray(plan.compute["pair_c"], jnp.int32),
    )


def unpack_monoC_result(
    c_local: jnp.ndarray,
    plan: MonoCPlan,
    c_structure,
    shape: tuple[int, int],
) -> np.ndarray:
    """Scatter device-major C block slots back to a dense array.

    ``c_structure`` is the block-grid structure of C (``inst.c`` of the plan
    instance); ``shape`` the padded dense shape (block-grid * block).
    """
    c_np = np.asarray(c_local)
    b = c_np.shape[-1]
    gr, gc = shape[0] // b, shape[1] // b
    crow, ccol = c_structure.coo()
    out = np.zeros((gr, gc, b, b), dtype=c_np.dtype)
    local_c = plan.local_ids["c_nz"]
    dev, slot = np.nonzero(local_c >= 0)
    gids = local_c[dev, slot]
    out[crow[gids], ccol[gids]] = c_np[dev, slot]
    return out.transpose(0, 2, 1, 3).reshape(shape)


def fine_spgemm(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    plan: FinePlan,
    mesh: Mesh,
    axis: str = "x",
) -> jnp.ndarray:
    """3D fine-grained SpGEMM (Def. 3.1): expand-expand-reduce.

    ``plan`` is a ``FinePlan`` over the scalar nonzero structures of the
    operands (``plan_ir.plan_fine_from_dense`` builds both).  Three padded
    ``all_to_all`` phases over the 1D device axis realize the three cut-net
    families of the fine hypergraph partition:

    1. A-expand: each device receives the foreign A nonzeros its
       multiplications read (slot table ``[owned | received | zero]``);
    2. B-expand: same for B;
    3. local compute: the device's multiplication list is two gathers, an
       elementwise product, and a segment-add into its produced-partial-C
       table — exactly its multiplication vertices, no more;
    4. C-reduce: foreign partials ship to each C nonzero's owner and fold
       into the owned-C table; partials the producer already owns fold
       locally through ``prod_to_owned``.

    Returns device-major owned-C slot values (p, C_max + 1); the trailing
    slot per device is the padding sink.  Use ``unpack_fine_result``.
    """
    import scipy.sparse as sp

    p = plan.p
    if mesh.devices.size != p:
        raise ValueError(f"plan is for p={p} but mesh has {mesh.devices.size} devices")
    a_csr = sp.csr_matrix(np.asarray(a_dense))
    b_csr = sp.csr_matrix(np.asarray(b_dense))
    for m in (a_csr, b_csr):
        m.sum_duplicates()
        m.sort_indices()
    if a_csr.nnz != len(plan.a_part) or b_csr.nnz != len(plan.b_part):
        raise ValueError("plan was built for a different nonzero structure")
    route_a = plan.routes["expand_a"]
    route_b = plan.routes["expand_b"]
    route_r = plan.routes["reduce_c"]
    T_a, T_b, T_r = route_a.T, route_b.T, route_r.T
    R_max = plan.local_ids["c_prod"].shape[1]
    C_max = plan.local_ids["c_nz"].shape[1]
    dtype = np.promote_types(a_csr.dtype, b_csr.dtype)

    def pack(vals, local_ids):
        out = np.zeros((p, local_ids.shape[1]), dtype)
        dev, slot = np.nonzero(local_ids >= 0)
        out[dev, slot] = vals[local_ids[dev, slot]]
        return out

    a_own = pack(a_csr.data, plan.local_ids["a_nz"])
    b_own = pack(b_csr.data, plan.local_ids["b_nz"])

    def expand(own, send_idx_blk, T):
        # own: (N_max,); ship my cut-net scalars, receive the foreign ones
        buf = _take0(own, send_idx_blk.reshape(-1)).reshape(p, T)
        recv = jax.lax.all_to_all(
            buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        zero = jnp.zeros((1,), own.dtype)
        return jnp.concatenate([own, recv.reshape(p * T), zero], 0)

    def step(a_blk, b_blk, sa, sb, sr, pa, pb, pc, recv_slot_all, prod_own):
        a_tab = expand(a_blk[0], sa[0], T_a)
        b_tab = expand(b_blk[0], sb[0], T_b)
        # local compute: exactly this device's multiplication vertices
        prods = a_tab[pa[0]] * b_tab[pb[0]]
        partial = jnp.zeros((R_max + 1,), a_tab.dtype).at[pc[0]].add(prods)
        # reduce phase: ship foreign partials to their C owners
        buf = _take0(partial, sr[0].reshape(-1)).reshape(p, T_r)
        recv = jax.lax.all_to_all(
            buf[None], axis, split_axis=1, concat_axis=1, tiled=False
        )[0]
        me = jax.lax.axis_index(axis)
        slots = recv_slot_all[:, me].reshape(-1)  # owned-C slot per arrival
        ok = slots >= 0
        c = jnp.zeros((C_max + 1,), a_tab.dtype)
        c = c.at[jnp.where(ok, slots, C_max)].add(
            jnp.where(ok, recv.reshape(-1), 0)
        )
        # partials this device both produced and owns fold locally
        own_map = prod_own[0]
        okp = own_map >= 0
        c = c.at[jnp.where(okp, own_map, C_max)].add(
            jnp.where(okp, partial[:R_max], 0)
        )
        return c[None]

    shard = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis),) * 8 + (P(), P(axis)),
        out_specs=P(axis),
    )
    return shard(
        jnp.asarray(a_own),
        jnp.asarray(b_own),
        jnp.asarray(route_a.send_idx),
        jnp.asarray(route_b.send_idx),
        jnp.asarray(route_r.send_idx),
        jnp.asarray(plan.compute["pair_a"]),
        jnp.asarray(plan.compute["pair_b"]),
        jnp.asarray(plan.compute["pair_c"]),
        jnp.asarray(plan.compute["reduce_recv_slot"]),
        jnp.asarray(plan.compute["prod_to_owned"]),
    )


def unpack_fine_result(
    c_local: jnp.ndarray,
    plan: FinePlan,
    c_structure,
    shape: tuple[int, int],
) -> np.ndarray:
    """Scatter device-major owned-C slot values back to a dense array."""
    c_np = np.asarray(c_local)
    crow, ccol = c_structure.coo()
    out = np.zeros(shape, dtype=c_np.dtype)
    local_c = plan.local_ids["c_nz"]
    dev, slot = np.nonzero(local_c >= 0)
    gids = local_c[dev, slot]
    out[crow[gids], ccol[gids]] = c_np[dev, slot]
    return out
