"""Sparsity-dependent model selection: the model zoo as an algorithm picker.

The paper's seven hypergraph models are seven SpGEMM algorithms; which one
communicates least depends on the sparsity structure of the instance.  This
module closes the loop the models only predict:

1. ``sweep_instance`` partitions *every* model of an instance and records
   each one's predicted communication (the connectivity metric,
   ``comm.evaluate``);
2. for the models with executable plans it lowers the partition to an
   ``ExecutionPlan`` whose routing tables are built by an independent code
   path (transfer enumeration, ``plan_ir``), and counts the words those
   tables actually ship (``measured_route_words``);
3. when the process owns enough devices it runs the executors against the
   dense oracle, so "the words the cut prescribes" and "the words the
   program moves" are pinned to each other end to end.

For replicated-free plans — fine-grained, monochrome-A/B/C, where every
shipped item is a single nonzero payload — measured == predicted exactly.
Row-wise (and columnwise, its ``C^T = B^T A^T`` mirror) ships whole dense
rows, so its measured *useful* words match the unit-cost prediction while
its wire words exceed the nnz-weighted cost; the sweep reports both so the
gap is visible, as are the padded all_to_all overhead and the message
count (``planned_messages``) for every model.

Everything model-specific (which models lower, how routed words are
weighted, what mesh/backend an executor wants) comes from the declarative
``registry.ModelSpec`` table — this module contains no per-model dispatch.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import partition
from repro.core.spgemm_models import MODELS, SpGEMMInstance
from repro.distributed.plan_ir import (  # noqa: F401  (re-export: tests use
    ExecutionPlan,                       # measured_route_words from here)
    build_volume_plan,
    measured_route_words,
)
from repro.distributed.registry import executable_models, get_spec

#: models whose partitions we can lower to an item-granularity executable
#: plan (derived from the registry — the old hand-maintained tuple is gone)
EXECUTABLE = executable_models()


def build_executable_plan(
    inst: SpGEMMInstance, model: str, parts: np.ndarray, p: int
) -> ExecutionPlan | None:
    """Lower a model partition to its executable plan, or None.

    Pure registry lookup: the per-model lowerers (with their pin-derived
    ownership — ``derive_owner_from_pins`` — so each cut net of
    connectivity lambda costs exactly lambda - 1 shipped items) live on the
    ``ModelSpec`` entries.
    """
    spec = get_spec(model)
    if spec.lower is None:
        return None
    return spec.lower(inst, np.asarray(parts, dtype=np.int64), p)


def _execute(handle, a_dense: np.ndarray, b_dense: np.ndarray, want: np.ndarray) -> dict:
    """Run a planned pipeline's executor on this process' devices and report
    wall time + max error vs the dense oracle ``want`` (computed once per
    instance by the caller).  Requires the process to own >= p devices (the
    multi-device CI job forces 8).

    Goes through the ``repro.api`` front door — mesh geometry, value
    packing, dtype promotion and backend defaults all come from the model's
    ``ModelSpec`` — with values taken straight off the instance structures
    (no dense -> sparse round trip): ``exec_s`` is the cold cost (structure
    work + AOT compile + first call), ``exec_warm_us`` the steady-state
    value-only per-call latency the runtime amortizes to.
    """
    import jax

    inst = handle.instance
    ar, ac = inst.a.coo()
    br, bc = inst.b.coo()
    a_vals = a_dense[ar, ac]
    b_vals = b_dense[br, bc]
    t0 = time.time()
    exe = handle.compile(dtype=np.promote_types(a_vals.dtype, b_vals.dtype))
    got = exe(a_vals, b_vals)
    cold_s = time.time() - t0
    # steady-state timing on the raw runtime executable (device shards out,
    # no host unpack), matching bench_exec's us_per_call convention
    a_packed, b_packed = exe.pack(a_vals, b_vals)
    reps = 3
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(exe.runtime(a_packed, b_packed))
    warm_us = (time.time() - t0) / reps * 1e6
    return {
        "exec_s": round(cold_s, 3),
        "exec_warm_us": int(warm_us),
        "exec_max_err": float(np.abs(got - want).max()),
    }


def sweep_instance(
    inst: SpGEMMInstance,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
    models: tuple[str, ...] = MODELS,
    a_dense: np.ndarray | None = None,
    b_dense: np.ndarray | None = None,
    execute: bool = False,
    pin_cap: int | None = None,
) -> list[dict]:
    """Partition every model, plan and (optionally) execute the executable
    ones, and report predicted vs planned vs measured words per model.

    Returns one record per model; the minimum ``predicted_words`` row is the
    selected algorithm for this instance.  ``execute`` additionally runs the
    executors when the process owns >= p devices (a no-op otherwise, so the
    sweep is safe in single-device harness runs).
    """
    from repro.api import PlannedSpGEMM, device_count

    records = []
    can_exec = False
    if execute and a_dense is not None:
        can_exec = device_count() >= p
    # the oracle matmul is only worth materializing when executors will run
    want = a_dense @ b_dense if can_exec else None
    for model in models:
        spec = get_spec(model)
        t0 = time.time()
        hg = spec.build(inst)
        if pin_cap is not None and hg.n_pins > pin_cap:
            records.append(
                {
                    "name": f"{inst.name}/select/{model}/p{p}",
                    "model": model,
                    "status": "skipped",
                    "reason": f"pins {hg.n_pins} > cap {pin_cap}",
                }
            )
            continue
        res = partition(hg, p, eps=eps, seed=seed)
        handle = PlannedSpGEMM(
            instance=inst,
            model=model,
            hypergraph=hg,
            partition=res,
            execution_plan=build_executable_plan(inst, model, res.parts, p),
            eps=eps,
            seed=seed,
        )
        # the handle's cost report is the single source for the per-model
        # numbers; this sweep only adds the cross-check volume plan, timing,
        # and (optionally) live execution
        report = handle.cost_report()
        vol_plan = build_volume_plan(hg, res.parts, p)
        rec = {
            "name": f"{inst.name}/select/{model}/p{p}",
            "model": model,
            "status": "ok",
            "us_per_call": int((time.time() - t0) * 1e6),
            "n_vertices": report["n_vertices"],
            "n_pins": report["n_pins"],
            "predicted_words": report["predicted_words"],
            "predicted_max_part": report["predicted_max_part"],
            "volume_plan_words": vol_plan.comm_words_ideal,
            "comp_imbalance": report["comp_imbalance"],
            "executable": spec.executable,
            # always surfaced (volume-plan fallback included) so benchmark
            # consumers get wire volume and message counts without
            # re-lowering: the alpha (messages) and padded-beta terms next
            # to the ideal words
            "padded_words": report["padded_words"],
            "planned_messages": report["planned_messages"],
        }
        assert rec["volume_plan_words"] == rec["predicted_words"], (
            f"{model}: volume plan diverged from connectivity metric"
        )
        if handle.execution_plan is not None:
            # sweep-historical names: measured_* == the report's planned_*
            rec["measured_words"] = report["planned_words"]
            if "planned_items" in report:
                # the unit count is the number of item transfers (e.g. row
                # shipments); the weighted count above is the useful words
                rec["measured_items"] = report["planned_items"]
            if execute and a_dense is not None:
                if can_exec:
                    rec.update(_execute(handle, a_dense, b_dense, want))
                else:
                    rec["exec"] = (
                        f"skipped ({device_count()} device(s) < p={p})"
                    )
        records.append(rec)
    ok = [r for r in records if r["status"] == "ok"]
    if ok:
        best = min(ok, key=lambda r: r["predicted_words"])
        for r in records:
            r["selected"] = r is best
    return records
