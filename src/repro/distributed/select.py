"""Sparsity-dependent model selection: the model zoo as an algorithm picker.

The paper's seven hypergraph models are seven SpGEMM algorithms; which one
communicates least depends on the sparsity structure of the instance.  This
module closes the loop the models only predict:

1. ``sweep_instance`` partitions *every* model of an instance and records
   each one's predicted communication (the connectivity metric,
   ``comm.evaluate``);
2. for the models with executable plans it lowers the partition to an
   ``ExecutionPlan`` whose routing tables are built by an independent code
   path (transfer enumeration, ``plan_ir``), and counts the words those
   tables actually ship (``measured_route_words``);
3. when the process owns enough devices it runs the executors against the
   dense oracle, so "the words the cut prescribes" and "the words the
   program moves" are pinned to each other end to end.

For replicated-free plans — fine-grained and monochrome-C, where every
shipped item is a single nonzero payload — measured == predicted exactly.
Row-wise ships whole dense B rows, so its measured *useful* words match the
unit-cost prediction while its wire words exceed the nnz-weighted cost; the
sweep reports both so the gap is visible, as is the padded all_to_all
overhead for every route.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import build_model, evaluate, partition
from repro.core.spgemm_models import MODELS, SpGEMMInstance
from repro.distributed.plan_ir import (
    ExecutionPlan,
    build_fine_plan,
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
    build_volume_plan,
    derive_owner_from_pins,
)

#: models whose partitions we can lower to an item-granularity executable plan
EXECUTABLE = ("rowwise", "outer", "monoC", "fine")


def measured_route_words(
    plan: ExecutionPlan, item_words: dict[str, np.ndarray] | None = None
) -> int:
    """Words the plan's routing tables actually ship (valid slots only).

    Counted from the materialized ``recv_key`` tables — the executor moves
    exactly these entries (plus padding) — NOT from the hypergraph's lambda
    counting, so equality with ``evaluate().connectivity`` is a real check
    that the cut and the schedule describe the same traffic.  ``item_words``
    optionally maps a route name to per-global-item useful word counts
    (e.g. nnz per shipped B row); routes not named count ``word_size`` per
    item.  Fold-phase words tracked only in ``stats`` (the outer plan's
    psum_scatter) are added as-is since that phase has no routing table.
    """
    words = 0
    for name, r in plan.routes.items():
        keys = r.recv_key[r.recv_key >= 0]
        if item_words is not None and name in item_words:
            words += int(item_words[name][keys].sum())
        else:
            words += len(keys) * r.word_size
    return int(words + plan.stats.get("fold_words_ideal", 0))


def build_executable_plan(
    inst: SpGEMMInstance, model: str, parts: np.ndarray, p: int
) -> ExecutionPlan | None:
    """Lower a model partition to its executable plan, or None.

    Nonzero ownership is derived from the pins (``derive_owner_from_pins``)
    so each cut net of connectivity lambda costs exactly lambda - 1 shipped
    items — the omitted-V^nz reading of the metric — making the planned
    words comparable with the hypergraph prediction.
    """
    parts = np.asarray(parts, dtype=np.int64)
    if model == "rowwise":
        I, K, _ = inst.shape
        acsc = inst.a_csc
        ks = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
        b_part = derive_owner_from_pins(
            ks, parts[acsc.indices.astype(np.int64)], K, p
        )
        return build_rowwise_plan(inst, parts, p, b_part=b_part)
    if model == "outer":
        return build_outer_plan(inst, parts, p)
    if model == "monoC":
        mult_dev = parts[inst.mult_c_pos]
        a_part = derive_owner_from_pins(inst.mult_a_pos, mult_dev, inst.a.nnz, p)
        b_part = derive_owner_from_pins(inst.mult_b_pos, mult_dev, inst.b.nnz, p)
        return build_monoC_plan(inst, parts, p, a_part=a_part, b_part=b_part)
    if model == "fine":
        return build_fine_plan(inst, parts, p)
    return None


def _execute(
    inst: SpGEMMInstance,
    model: str,
    plan: ExecutionPlan,
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    want: np.ndarray,
) -> dict:
    """Run the executor for ``plan`` on a mesh over this process' devices and
    report wall time + max error vs the dense oracle ``want`` (computed once
    per instance by the caller).  Requires the process to own >= plan.p
    devices (the multi-device CI job forces 8).

    Goes through the compile-once runtime with values taken straight off the
    instance structures (no dense -> sparse round trip): ``exec_s`` is the
    cold cost (structure work + AOT compile + first call), ``exec_warm_us``
    the steady-state value-only per-call latency the runtime amortizes to.
    """
    import jax
    from jax.sharding import Mesh

    from repro.distributed.runtime import compile_spgemm

    p = plan.p
    I, _, J = inst.shape
    ar, ac = inst.a.coo()
    br, bc = inst.b.coo()
    a_vals = a_dense[ar, ac]
    b_vals = b_dense[br, bc]
    dtype = np.promote_types(a_vals.dtype, b_vals.dtype)
    t0 = time.time()
    if model == "monoC":
        if p % 2:
            return {"exec": f"skipped (odd p={p}; executor mesh is (2, p//2))"}
        mesh = Mesh(np.array(jax.devices()[:p]).reshape(2, p // 2), ("x", "y"))
        # scalar instance == 1x1 block structure; XLA local compute (no TPU)
        exe = compile_spgemm(
            plan, inst.a, inst.b, mesh, dtype=dtype, block=1, backend="xla",
            c_structure=inst.c,
        )
        a_vals = a_vals.reshape(-1, 1, 1)
        b_vals = b_vals.reshape(-1, 1, 1)
    elif model in ("rowwise", "outer", "fine"):
        mesh = Mesh(np.array(jax.devices()[:p]), ("x",))
        exe = compile_spgemm(plan, inst.a, inst.b, mesh, dtype=dtype, c_structure=inst.c)
    else:
        return {}
    got = exe.unpack(jax.block_until_ready(exe(a_vals, b_vals)))
    cold_s = time.time() - t0
    reps = 3
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(exe(a_vals, b_vals))
    warm_us = (time.time() - t0) / reps * 1e6
    return {
        "exec_s": round(cold_s, 3),
        "exec_warm_us": int(warm_us),
        "exec_max_err": float(np.abs(got[:I, :J] - want).max()),
    }


def sweep_instance(
    inst: SpGEMMInstance,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
    models: tuple[str, ...] = MODELS,
    a_dense: np.ndarray | None = None,
    b_dense: np.ndarray | None = None,
    execute: bool = False,
    pin_cap: int | None = None,
) -> list[dict]:
    """Partition every model, plan and (optionally) execute the executable
    ones, and report predicted vs planned vs measured words per model.

    Returns one record per model; the minimum ``predicted_words`` row is the
    selected algorithm for this instance.  ``execute`` additionally runs the
    executors when the process owns >= p devices (a no-op otherwise, so the
    sweep is safe in single-device harness runs).
    """
    records = []
    can_exec = False
    if execute and a_dense is not None:
        import jax

        can_exec = jax.device_count() >= p
    # the oracle matmul is only worth materializing when executors will run
    want = a_dense @ b_dense if can_exec else None
    for model in models:
        t0 = time.time()
        hg = build_model(inst, model)
        if pin_cap is not None and hg.n_pins > pin_cap:
            records.append(
                {
                    "name": f"{inst.name}/select/{model}/p{p}",
                    "model": model,
                    "status": "skipped",
                    "reason": f"pins {hg.n_pins} > cap {pin_cap}",
                }
            )
            continue
        res = partition(hg, p, eps=eps, seed=seed)
        costs = evaluate(hg, res.parts, p)
        vol_plan = build_volume_plan(hg, res.parts, p)
        rec = {
            "name": f"{inst.name}/select/{model}/p{p}",
            "model": model,
            "status": "ok",
            "us_per_call": int((time.time() - t0) * 1e6),
            "n_vertices": hg.n_vertices,
            "n_pins": hg.n_pins,
            "predicted_words": int(costs.connectivity),
            "predicted_max_part": int(costs.max_part_cost),
            "volume_plan_words": vol_plan.comm_words_ideal,
            "comp_imbalance": round(costs.comp_imbalance, 4),
            "executable": model in EXECUTABLE,
        }
        assert rec["volume_plan_words"] == rec["predicted_words"], (
            f"{model}: volume plan diverged from connectivity metric"
        )
        plan = build_executable_plan(inst, model, res.parts, p)
        if plan is not None:
            if model == "rowwise":
                # the route ships whole B rows; nnz-weighting its table
                # entries recovers the model's useful-word prediction, while
                # the unit count is the number of row transfers
                rec["measured_words"] = measured_route_words(
                    plan, {"expand": inst.b.row_counts()}
                )
                rec["measured_items"] = measured_route_words(plan)
            else:
                rec["measured_words"] = measured_route_words(plan)
            rec["padded_words"] = plan.comm_words_padded
            if execute and a_dense is not None:
                if can_exec:
                    rec.update(_execute(inst, model, plan, a_dense, b_dense, want))
                else:
                    import jax

                    rec["exec"] = f"skipped ({jax.device_count()} device(s) < p={p})"
        records.append(rec)
    ok = [r for r in records if r["status"] == "ok"]
    if ok:
        best = min(ok, key=lambda r: r["predicted_words"])
        for r in records:
            r["selected"] = r is best
    return records
