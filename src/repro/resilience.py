"""Failure classification + retry/downgrade policy.

One place answers "is this exception worth retrying?" for every layer that
restarts work — the elastic step loop (``launch/elastic.py``), the resilient
session (``distributed/session.py``) and the fault-injection harness
(``repro.testing.faults``).  The old behavior — substring-matching
``"RESOURCE_EXHAUSTED"`` on any ``RuntimeError`` at one call site — grows
here into an explicit predicate plus a declarative ``FaultPolicy`` (retries,
backoff, downgrade chains) the session threads through every stage.

Nothing here imports jax: XLA's ``XlaRuntimeError`` is recognized by type
*name* so the planning side stays importable without a device stack.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = [
    "FaultPolicy",
    "RetryableError",
    "is_retryable",
    "retry_call",
]


class RetryableError(RuntimeError):
    """Transient by construction — simulated node loss, injected faults,
    and any library error explicitly raised as worth-retrying."""


# transient-resource markers XLA / distributed runtimes put in messages
_RETRYABLE_MARKERS = (
    "RESOURCE_EXHAUSTED",
    "DEADLINE_EXCEEDED",
    "UNAVAILABLE",
    "out of memory",
)
# exception type names (matched without importing their home modules)
_RETRYABLE_TYPE_NAMES = ("XlaRuntimeError",)
# OSError subclasses that are *state*, not transience: retrying a missing
# path or a permission wall burns the retry budget for nothing
_PERMANENT_OS_ERRORS = (
    FileNotFoundError,
    IsADirectoryError,
    NotADirectoryError,
    PermissionError,
)


def is_retryable(exc: BaseException) -> bool:
    """Explicit retryable-exception predicate.

    Retryable: ``RetryableError`` (incl. injected faults and the elastic
    loop's ``InjectedFailure``), memory pressure (``MemoryError`` or an
    XLA/runtime error carrying a transient-resource marker), timeouts,
    connection blips, and transient filesystem errors.  Everything else —
    shape mismatches, missing files, plain ``ValueError`` bugs — is
    permanent and must surface immediately.
    """
    if isinstance(exc, RetryableError):
        return True
    if isinstance(exc, (MemoryError, TimeoutError, ConnectionError)):
        return True
    if isinstance(exc, OSError):
        return not isinstance(exc, _PERMANENT_OS_ERRORS)
    name = type(exc).__name__
    if name in _RETRYABLE_TYPE_NAMES or isinstance(exc, RuntimeError):
        msg = str(exc)
        return any(marker in msg for marker in _RETRYABLE_MARKERS)
    return False


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """How a resilient caller reacts to a failing stage.

    - ``max_retries`` / ``backoff_s`` / ``backoff_factor``: transient
      failures (per :func:`is_retryable`, overridable via ``retryable``)
      are retried up to ``max_retries`` times with exponential backoff.
    - ``engine_chain``: partitioner downgrade order — a failing
      ``engine="device"`` plan falls back to the host ``"flat"`` engine.
    - ``model_chain``: executor downgrade order — a model whose
      compile/execute keeps failing (e.g. fine's 3-route program OOMs) is
      replanned with the next cheaper-to-run model in the chain.
    """

    max_retries: int = 2
    backoff_s: float = 0.02
    backoff_factor: float = 2.0
    engine_chain: tuple[str, ...] = ("device", "flat")
    model_chain: tuple[str, ...] = ("fine", "monoC", "rowwise")
    retryable: Callable[[BaseException], bool] = is_retryable

    def delays(self, n: int | None = None):
        """Backoff delays (seconds) for retry 1, 2, ... — exponential."""
        n = self.max_retries if n is None else n
        d = self.backoff_s
        for _ in range(n):
            yield d
            d *= self.backoff_factor

    def downgrades(self, current: str, chain: tuple[str, ...]) -> list[str]:
        """Fallbacks to try after ``current``, in chain order.  A ``current``
        not in the chain downgrades to the whole chain."""
        if current in chain:
            return list(chain[chain.index(current) + 1 :])
        return [c for c in chain if c != current]


def retry_call(
    fn: Callable,
    policy: FaultPolicy,
    *,
    stage: str = "",
    on_retry: Callable | None = None,
    sleep: Callable = time.sleep,
):
    """Call ``fn()`` with the policy's retry budget.

    Retries only exceptions ``policy.retryable`` accepts; sleeps the
    policy's backoff between attempts; re-raises the final failure.
    ``on_retry(stage, attempt_index, exc)`` observes each retry (the
    session turns these into events).
    """
    delays = policy.delays()
    for attempt in range(policy.max_retries + 1):
        try:
            return fn()
        except Exception as exc:
            if attempt >= policy.max_retries or not policy.retryable(exc):
                raise
            if on_retry is not None:
                on_retry(stage, attempt, exc)
            delay = next(delays)
            if delay > 0:
                sleep(delay)
