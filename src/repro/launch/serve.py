"""SpGEMM serving loop: warm pool of compiled handles + batched value streams.

The paper's premise makes SpGEMM a compile-once workload: the expensive work
(partition, lower, AOT compile) is per-*structure*, while production traffic
(AMG setup chains, MCL iterations, multi-RHS products) re-runs the same
structure with new values thousands of times.  This module is the traffic
side of that story — a bounded request queue drained by a loop that

- **classifies** every request by structure fingerprint through a
  ``SpGEMMSession`` warm pool (PR 7): an unchanged structure is a pool hit
  (zero planning), a drifted one warm-start-replans, a new one plans cold,
  and the pool's LRU eviction + optional plan store bound memory;
- **batches** same-structure requests into one dispatch through the batched
  executor (``PlannedSpGEMM.compile(batch=n)``): value batches are padded to
  geometric capacity buckets so ragged batch sizes share one AOT executable
  (the runtime LRU from PR 4 holds one executable per bucket);
- **accounts** per-request latency (p50/p99), aggregate throughput (QPS),
  and batch efficiency (items shipped / padded slots), so the serving claim
  is a measured number, not a vibe (``benchmarks/bench_serve.py`` gates it).

Admission is reject-on-full (``QueueFull``): a bounded queue keeps worst-case
latency bounded and pushes overload back to the caller.  Execution failures
go through the session's ``FaultPolicy`` (transients retried with backoff);
a batch that fails permanently marks only its own requests failed — the loop
keeps serving.

Planning-side imports stay jax-free (the PR 5 contract): jax is touched only
when a handle compiles, so ``import repro.launch.serve`` works on a
device-less planning host.

Usage (in-container, forced host devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.launch.serve --p 4 --requests 64 --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import OrderedDict

import numpy as np

from repro.resilience import FaultPolicy, retry_call
from repro.sparse.structure import structure_and_values, structure_fingerprint

__all__ = [
    "QueueFull",
    "Request",
    "ServeConfig",
    "ServeStats",
    "SpGEMMServer",
    "serve_spgemm",
]


class QueueFull(RuntimeError):
    """Admission rejection: the bounded request queue is at capacity."""


@dataclasses.dataclass
class ServeConfig:
    """Serving-loop knobs (defaults sized for the in-container smoke)."""

    p: int = 4
    model: str = "auto"
    eps: float = 0.10
    seed: int = 0
    engine: str = "flat"
    max_batch: int = 8  # largest per-dispatch value batch (bucket ceiling)
    batch_window: int = 32  # requests drained per step() across structures
    queue_limit: int = 256  # admission bound; submit() raises QueueFull past it
    pool_entries: int = 8  # warm pool LRU slots (session max_entries)
    store_dir: str | None = None  # plan persistence (survives restarts)
    dtype: str = "float32"
    policy: FaultPolicy | None = None


@dataclasses.dataclass
class Request:
    """One queued multiply: structures + canonical CSR values + timestamps."""

    rid: int
    a_s: object  # SparseStructure
    b_s: object
    a_vals: np.ndarray
    b_vals: np.ndarray
    t_submit: float
    result: np.ndarray | None = None
    error: BaseException | None = None
    t_done: float | None = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class ServeStats:
    """Aggregate accounting for one server lifetime."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    dispatches: int = 0
    batch_items: int = 0  # real multiplies shipped
    batch_slots: int = 0  # padded capacity those dispatches were compiled for

    @property
    def batch_efficiency(self) -> float:
        """Items shipped / padded batch slots (1.0 == no padding waste)."""
        return self.batch_items / self.batch_slots if self.batch_slots else 0.0


class SpGEMMServer:
    """The serving loop: bounded queue -> structure groups -> batched dispatch.

    ``submit(A, B)`` enqueues a multiply (rejecting when the queue is full);
    ``step()`` drains one batching window — it groups queued requests by
    structure fingerprint, fetches each group's warm pool entry through the
    session (hit / warm replan / cold plan / restore, all on
    ``server.session.events``), and streams each group through the batched
    executor in ``max_batch``-bounded chunks.  ``drain()`` loops ``step()``
    until the queue is empty.  All results land on the ``Request`` objects.
    """

    def __init__(self, config: ServeConfig | None = None, **overrides):
        from repro.distributed.session import SpGEMMSession

        cfg = config or ServeConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.config = cfg
        self.session = SpGEMMSession(
            p=cfg.p,
            model=cfg.model,
            eps=cfg.eps,
            seed=cfg.seed,
            engine=cfg.engine,
            store_dir=cfg.store_dir,
            policy=cfg.policy,
            max_entries=cfg.pool_entries,
            dtype=cfg.dtype,
        )
        self.stats = ServeStats()
        self._queue: OrderedDict[int, Request] = OrderedDict()
        self._latencies: list[float] = []
        self._next_rid = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    # -- admission ---------------------------------------------------------
    def submit(self, A, B) -> Request:
        """Enqueue C = A @ B.  ``A``/``B`` are dense arrays, scipy sparse
        matrices, or ``(SparseStructure, values)`` pairs.  Raises
        :class:`QueueFull` when the queue is at ``queue_limit`` — overload
        is the caller's problem by design (bounded worst-case latency)."""
        if len(self._queue) >= self.config.queue_limit:
            self.stats.rejected += 1
            raise QueueFull(
                f"queue at capacity ({self.config.queue_limit}); retry after drain"
            )
        a_s, a_vals = structure_and_values(A)
        b_s, b_vals = structure_and_values(B)
        req = Request(
            rid=self._next_rid,
            a_s=a_s,
            b_s=b_s,
            a_vals=np.asarray(a_vals),
            b_vals=np.asarray(b_vals),
            t_submit=time.perf_counter(),
        )
        self._next_rid += 1
        self._queue[req.rid] = req
        self.stats.submitted += 1
        if self._t_first is None:
            self._t_first = req.t_submit
        return req

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # -- the loop ----------------------------------------------------------
    def step(self) -> int:
        """Drain one batching window; returns the number of requests served
        (completed or failed).  Requests leave the queue in FIFO order, but
        same-structure requests inside the window ride one dispatch."""
        window: list[Request] = []
        while self._queue and len(window) < self.config.batch_window:
            _, req = self._queue.popitem(last=False)
            window.append(req)
        if not window:
            return 0
        groups: OrderedDict[str, list[Request]] = OrderedDict()
        for req in window:
            key = f"{structure_fingerprint(req.a_s)}/{structure_fingerprint(req.b_s)}"
            groups.setdefault(key, []).append(req)
        served = 0
        for reqs in groups.values():
            served += self._serve_group(reqs)
        return served

    def drain(self, max_steps: int | None = None) -> int:
        """Run ``step()`` until the queue empties; returns requests served."""
        served = 0
        steps = 0
        while self._queue:
            served += self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return served

    # -- dispatch ----------------------------------------------------------
    def _serve_group(self, reqs: list[Request]) -> int:
        """One structure group: fetch the warm entry, stream the values
        through the batched executor in ``max_batch``-bounded chunks."""
        try:
            entry = self.session.entry_for(reqs[0].a_s, reqs[0].b_s)
        except Exception as exc:
            return self._fail(reqs, exc)
        served = 0
        for i in range(0, len(reqs), self.config.max_batch):
            served += self._dispatch(entry, reqs[i : i + self.config.max_batch])
        return served

    def _dispatch(self, entry, chunk: list[Request]) -> int:
        m = len(chunk)
        try:
            if m == 1:
                # singletons ride the entry's own (unbatched) executable
                exe = entry.exe
                run = lambda: exe(chunk[0].a_vals, chunk[0].b_vals)  # noqa: E731
                capacity = 1
            else:
                exe = entry.planned.compile(batch=m, dtype=self.session.dtype)
                capacity = exe.batch_capacity
                a = np.stack([r.a_vals for r in chunk])
                b = np.stack([r.b_vals for r in chunk])
                run = lambda: exe(a, b)  # noqa: E731
            c = np.asarray(
                retry_call(
                    run,
                    self.session.policy,
                    stage="execute",
                    on_retry=self.session._on_retry,
                )
            )
        except Exception as exc:
            return self._fail(chunk, exc)
        now = time.perf_counter()
        self.stats.dispatches += 1
        self.stats.batch_items += m
        self.stats.batch_slots += capacity
        for i, req in enumerate(chunk):
            req.result = c if m == 1 else c[i]
            req.t_done = now
            self._latencies.append(req.latency_s)
        self.stats.completed += m
        self._t_last = now
        return m

    def _fail(self, reqs: list[Request], exc: BaseException) -> int:
        now = time.perf_counter()
        for req in reqs:
            req.error = exc
            req.t_done = now
        self.stats.failed += len(reqs)
        self._t_last = now
        return len(reqs)

    # -- accounting --------------------------------------------------------
    def report(self) -> dict:
        """Latency / throughput / batching / classification summary."""
        lat = np.asarray(self._latencies) if self._latencies else np.zeros(0)
        elapsed = (
            (self._t_last - self._t_first)
            if self._t_first is not None and self._t_last is not None
            else 0.0
        )
        s = self.stats
        session_stats = self.session.stats()
        return {
            "submitted": s.submitted,
            "completed": s.completed,
            "failed": s.failed,
            "rejected": s.rejected,
            "dispatches": s.dispatches,
            "qps": round(s.completed / elapsed, 1) if elapsed > 0 else 0.0,
            "p50_us": int(np.percentile(lat, 50) * 1e6) if lat.size else 0,
            "p99_us": int(np.percentile(lat, 99) * 1e6) if lat.size else 0,
            "batch_efficiency": round(s.batch_efficiency, 3),
            "pool": session_stats,
        }


def serve_spgemm(workload, config: ServeConfig | None = None, **overrides):
    """Drive a whole workload through one server: submit everything (stepping
    inline when the queue fills), drain, and return (requests, report).

    ``workload`` is an iterable of (A, B) operand pairs.  This is the
    offline/batched entry point — the benchmark and the CLI both use it; a
    live system would call ``submit``/``step`` from its own event loop.
    """
    server = SpGEMMServer(config, **overrides)
    requests = []
    for A, B in workload:
        while True:
            try:
                requests.append(server.submit(A, B))
                break
            except QueueFull:
                server.step()
    server.drain()
    return requests, server.report()


# ---------------------------------------------------------------------------
# CLI: synthetic mixed traffic (pool hits, drifting structures, cold loads)
# ---------------------------------------------------------------------------
def _mixed_workload(n, density, structures, requests, drift, seed):
    """(A, B) pairs mixing the three serving regimes: repeated same-structure
    value streams (pool hits), periodically drifted structures (warm
    replans), and fresh structures (cold plans)."""
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    pool = [random_structure(n, n, density, rng) for _ in range(structures)]

    def drifted(s):
        rows, cols = s.coo()
        keep = rng.random(len(rows)) > drift
        extra = max(1, int(drift * len(rows)))
        from repro.sparse.structure import from_coo

        return from_coo(
            np.concatenate([rows[keep], rng.integers(0, n, extra)]),
            np.concatenate([cols[keep], rng.integers(0, n, extra)]),
            s.shape,
        )

    for i in range(requests):
        if i and i % 16 == 0:
            pool[i % structures] = drifted(pool[i % structures])  # warm replan
        elif i and i % 24 == 0:
            pool[i % structures] = random_structure(n, n, density, rng)  # cold
        s = pool[i % structures]
        vals_a = rng.standard_normal(s.nnz).astype(np.float32)
        vals_b = rng.standard_normal(s.nnz).astype(np.float32)
        yield (s, vals_a), (s, vals_b)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--model", default="fine")
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--density", type=float, default=0.06)
    ap.add_argument("--structures", type=int, default=3)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--drift", type=float, default=0.1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--smoke", action="store_true", help="tiny sizes for a fast in-container run"
    )
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.requests, args.structures = 48, 24, 2

    from repro.api import device_count

    if device_count() < args.p:
        print(
            f"only {device_count()} device(s) visible; rerun with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={args.p} "
            f"(falling back to --p 1)"
        )
        args.p = 1

    workload = _mixed_workload(
        args.n, args.density, args.structures, args.requests, args.drift, args.seed
    )
    requests, report = serve_spgemm(
        workload,
        p=args.p,
        model=args.model,
        max_batch=args.max_batch,
        batch_window=args.window,
        seed=args.seed,
    )
    # spot-check one product against numpy so the smoke proves correctness,
    # not just liveness
    done = [r for r in requests if r.result is not None]
    probe = done[len(done) // 2]
    a = np.zeros(probe.a_s.shape, np.float32)
    b = np.zeros(probe.b_s.shape, np.float32)
    a[probe.a_s.coo()] = probe.a_vals
    b[probe.b_s.coo()] = probe.b_vals
    np.testing.assert_allclose(probe.result, a @ b, rtol=1e-4, atol=1e-4)
    print("serve report:")
    for k, v in report.items():
        print(f"  {k}: {v}")
    print("oracle spot-check: OK")
    return report


if __name__ == "__main__":
    main()
