"""Fault tolerance + straggler mitigation around the step loop.

Production mapping (1000+ nodes): each restart is a JAX multi-controller
re-initialization from the latest atomic checkpoint; the checkpoint layout is
mesh-shape-agnostic (repro.checkpoint), so the restarted job may come up with
fewer/more pods (elastic re-scale).  In-container we exercise the same code
paths by injecting failures into the step loop and restarting in-process.

Straggler mitigation: per-step wall-time watchdog; a step exceeding
``straggler_factor`` x the running median is recorded and (at scale) would
trigger the slot-exclusion path — here we surface it in the stats so tests
can assert on detection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.resilience import RetryableError, is_retryable


@dataclasses.dataclass
class RunStats:
    steps_run: int = 0
    restarts: int = 0
    stragglers: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)


class InjectedFailure(RetryableError):
    """Simulated node failure (tests)."""


def run_loop(
    state,
    step_fn: Callable,  # (state, step_idx) -> state
    n_steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    failure_injector: Callable[[int], None] | None = None,
    straggler_factor: float = 3.0,
    state_to_tree: Callable = lambda s: s,
    tree_to_state: Callable = lambda t, s: t,
    retryable: Callable[[BaseException], bool] = is_retryable,
    restart_backoff_s: float = 0.0,
    restart_backoff_factor: float = 2.0,
    sleep: Callable = time.sleep,
) -> tuple[object, RunStats]:
    """Checkpointed, restartable step loop.

    Restarts only on ``retryable`` failures (``resilience.is_retryable`` by
    default — the predicate ``FaultPolicy`` shares, replacing the old
    ``"RESOURCE_EXHAUSTED"`` substring match), waiting ``restart_backoff_s``
    (doubled per consecutive restart) before each restart so a crash-looping
    resource isn't hammered."""
    stats = RunStats()
    start = 0
    if ckpt_dir is not None and latest_step(ckpt_dir) is not None:
        tree, start = restore_checkpoint(ckpt_dir)
        state = tree_to_state(tree, state)
    step = start
    restarts = 0
    backoff = restart_backoff_s
    while step < n_steps:
        try:
            t0 = time.monotonic()
            if failure_injector is not None:
                failure_injector(step)
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            stats.step_times.append(dt)
            med = sorted(stats.step_times)[len(stats.step_times) // 2]
            if len(stats.step_times) >= 5 and dt > straggler_factor * med:
                stats.stragglers.append((step, dt, med))
            step += 1
            stats.steps_run += 1
            backoff = restart_backoff_s  # a completed step resets the backoff
            if ckpt_dir is not None and (
                step % ckpt_every == 0 or step == n_steps
            ):
                save_checkpoint(ckpt_dir, step, state_to_tree(state))
        except Exception as e:
            if not retryable(e):
                raise
            restarts += 1
            stats.restarts = restarts
            if restarts > max_restarts:
                raise
            if ckpt_dir is None:
                raise
            if backoff > 0:
                sleep(backoff)
                backoff *= restart_backoff_factor
            if latest_step(ckpt_dir) is not None:
                tree, step = restore_checkpoint(ckpt_dir)
                state = tree_to_state(tree, state)
            else:
                step = 0
    return state, stats
