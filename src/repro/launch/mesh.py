"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization.  Single pod: 16 x 16 = 256 chips (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model) — the 'pod' axis is
pure data parallelism across the inter-pod (DCI) links.
"""
from __future__ import annotations

import jax

from repro import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic entry point: any (pod, data, model) factorization."""
    return _mk(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = n // model
    return _mk((data, model), ("data", "model"))
