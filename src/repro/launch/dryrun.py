import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)
"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, on the 16x16 single-pod mesh and
the 2x16x16 multi-pod mesh:  jit(step).lower(**ShapeDtypeStructs).compile(),
then record memory_analysis(), cost_analysis() and the per-collective byte
census parsed from the compiled HLO.  No arrays are ever allocated.

Usage:
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

(The two os.environ lines above MUST run before any jax import — jax locks
the device count at first init.  Override via REPRO_XLA_FLAGS for tests.)
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, all_arch_ids, get_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import init_params, init_kv_cache
from repro.models.sharding import (
    batch_sharding,
    param_logical_axes,
    param_shardings,
    fit_sharding_tree,
    spec_for,
    _fit_spec,
)
from repro.training.optimizer import adamw_init
from repro.training.step import make_train_step, make_prefill_step, make_decode_step

SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1,
}


_CENSUS_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\("
)


def collective_census(hlo_text: str) -> dict:
    """Sum result bytes per collective kind over the compiled module."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _CENSUS_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        shapes = SHAPE_RE.findall(m.group(1))  # result type(s) on the lhs
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        rec = out.setdefault(kind, {"count": 0, "result_bytes": 0})
        rec["count"] += 1
        rec["result_bytes"] += nbytes
    return out


def wire_bytes(census: dict, factor_all_reduce: float = 2.0) -> int:
    """Ring-model effective wire bytes: AG/RS/A2A ~ result bytes, AR ~ 2x."""
    total = 0
    for kind, rec in census.items():
        f = factor_all_reduce if kind == "all-reduce" else 1.0
        total += int(rec["result_bytes"] * f)
    return total


def _opt_state_shardings(params_sh, mesh):
    rep = NamedSharding(mesh, P())
    return {
        "mu": params_sh,
        "nu": params_sh,
        "count": rep,
    }


def _cache_logical_axes(cfg):
    ax = {"pos": ()}
    kv_seq = "kv_seq" if cfg.kv_shard_mode == "seq" else "seq"
    if cfg.layer_kind in ("attn", "hybrid"):
        ax["k"] = ("layers", "batch", kv_seq, "kv_heads", "head_dim")
        ax["v"] = ("layers", "batch", kv_seq, "kv_heads", "head_dim")
        ax["cache_pos"] = ("layers", "seq")
    if cfg.layer_kind in ("mamba", "hybrid"):
        ax["conv"] = ("layers", "batch", "conv", "ssm_inner")
        ax["h"] = ("layers", "batch", "ssm_inner", "ssm_state")
    return ax


def build_cell(arch: str, shape: str, mesh, cfg=None, opts=()):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, donate) for the
    cell.  ``opts`` are the §Perf knobs: serve_shardings, donate, remat_dots,
    remat_none, seq_shard."""
    import dataclasses as _dc

    if cfg is None:
        cfg = get_config(arch)
    if "remat_dots" in opts:
        cfg = _dc.replace(cfg, remat_policy="dots")
    if "remat_none" in opts:
        cfg = _dc.replace(cfg, remat_policy="none")
    if "seq_shard" in opts:
        cfg = _dc.replace(cfg, seq_shard_residual=True)
    if "gather_weights" in opts:
        cfg = _dc.replace(cfg, gather_weights=True)
    if "kv_none" in opts:
        cfg = _dc.replace(cfg, kv_shard_mode="none")
    if "kv_seq" in opts:
        cfg = _dc.replace(cfg, kv_shard_mode="seq")
    spec = SHAPES[shape]
    serve = "serve_shardings" in opts and spec.kind in ("prefill", "decode")
    params_shapes = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    params_sh = param_shardings(cfg, mesh, serve=serve)
    batch_shapes = input_specs(cfg, shape)
    b_sh = {
        k: batch_sharding(mesh, v.shape[0], v.ndim) for k, v in batch_shapes.items()
    }

    if spec.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        opt_sh = _opt_state_shardings(params_sh, mesh)
        step = make_train_step(cfg)
        args = (params_shapes, opt_shapes, batch_shapes)
        in_sh = (params_sh, opt_sh, b_sh)
        out_sh = (params_sh, opt_sh, None)
        return step, args, in_sh, out_sh, (0, 1)
    logits_sh = NamedSharding(
        mesh, _fit_spec(P(None, "model"), (spec.global_batch, cfg.vocab), mesh)
    )
    if spec.kind == "prefill":
        step = make_prefill_step(cfg)
        cache_shapes = jax.eval_shape(
            lambda: init_kv_cache(cfg, spec.global_batch, spec.seq_len)
        )
        cache_sh = fit_sharding_tree(cache_shapes, _cache_axes_tree(cfg, cache_shapes), mesh)
        args = (params_shapes, batch_shapes)
        return step, args, (params_sh, b_sh), (logits_sh, cache_sh), ()
    # decode
    step = make_decode_step(cfg)
    cache_shapes = jax.eval_shape(
        lambda: init_kv_cache(cfg, spec.global_batch, spec.seq_len)
    )
    cache_sh = fit_sharding_tree(cache_shapes, _cache_axes_tree(cfg, cache_shapes), mesh)
    args = (params_shapes, cache_shapes, batch_shapes["tokens"])
    in_sh = (params_sh, cache_sh, b_sh["tokens"])
    return step, args, in_sh, (logits_sh, cache_sh), (1,)


def _cache_axes_tree(cfg, cache_shapes):
    ax = _cache_logical_axes(cfg)
    # structure must match exactly (dict keys align by construction)
    return {k: tuple(ax[k]) for k in cache_shapes}


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    out_dir: str | None,
    opts: tuple = (),
) -> dict:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "opts": list(opts),
        "status": "skipped",
        "reason": why,
    }
    if not ok:
        print(f"[dryrun] SKIP {arch} x {shape} ({why})")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        import dataclasses as _dc

        compat.set_mesh(mesh)  # ambient mesh: with_sharding_constraint sees it
        donate_on = "donate" in opts
        # --- 1. full-depth compile (the deliverable): memory + success ---
        fn, args, in_sh, out_sh, don = build_cell(arch, shape, mesh, opts=opts)
        lowered = jax.jit(
            fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=don if donate_on else (),
        ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem_rec = {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # backend without memory stats
            mem_rec = {"unavailable": str(e)}

        # --- 2. depth-2 / depth-4 unrolled compiles: exact per-layer cost
        # (XLA counts while-loop bodies once; layers are homogeneous, so
        # linear extrapolation in depth is exact — see module docstring) ---
        L = cfg.n_layers
        per_depth = {}
        for u in (2, 4):
            cfg_u = _dc.replace(cfg, n_layers=u, scan_unroll=True)
            fn_u, args_u, in_u, out_u, don_u = build_cell(
                arch, shape, mesh, cfg=cfg_u, opts=opts
            )
            comp_u = (
                jax.jit(
                    fn_u,
                    in_shardings=in_u,
                    out_shardings=out_u,
                    donate_argnums=don_u if donate_on else (),
                )
                .lower(*args_u)
                .compile()
            )
            cost_u = comp_u.cost_analysis()
            if isinstance(cost_u, (list, tuple)):
                cost_u = cost_u[0]
            per_depth[u] = {
                "flops": float(cost_u.get("flops", 0.0)),
                "bytes": float(cost_u.get("bytes accessed", 0.0)),
                "census": collective_census(comp_u.as_text()),
            }

        def _extrap(f2, f4):
            per_layer = (f4 - f2) / 2.0
            return f2 + per_layer * (L - 2)

        flops = _extrap(per_depth[2]["flops"], per_depth[4]["flops"])
        bytes_acc = _extrap(per_depth[2]["bytes"], per_depth[4]["bytes"])
        census = {}
        kinds = set(per_depth[2]["census"]) | set(per_depth[4]["census"])
        for kind in kinds:
            c2 = per_depth[2]["census"].get(kind, {"count": 0, "result_bytes": 0})
            c4 = per_depth[4]["census"].get(kind, {"count": 0, "result_bytes": 0})
            census[kind] = {
                "count": int(round(_extrap(c2["count"], c4["count"]))),
                "result_bytes": int(round(_extrap(c2["result_bytes"], c4["result_bytes"]))),
            }
        n_dev = mesh.devices.size
        rec.update(
            status="ok",
            n_devices=int(n_dev),
            n_layers=L,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem_rec,
            flops=flops,
            bytes_accessed=bytes_acc,
            collectives=census,
            wire_bytes=wire_bytes(census),
            per_depth={str(k): v for k, v in per_depth.items()},
        )
        print(
            f"[dryrun] OK {arch} x {shape} x {mesh_name}: "
            f"flops={flops:.3e} bytes={bytes_acc:.3e} "
            f"wire={rec['wire_bytes']:.3e} "
            f"temp/dev={mem_rec.get('temp_size_in_bytes', 0)/1e9:.2f}GB "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
        )
        print(f"[dryrun]   memory_analysis: {mem_rec}")
        print(f"[dryrun]   collectives(extrap): {json.dumps(census)}")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
        print(f"[dryrun] FAIL {arch} x {shape} x {mesh_name}: {e}")
        traceback.print_exc()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = ("+" + "+".join(opts)) if opts else ""
        fname = f"{arch}_{shape}_{mesh_name}{tag}.json".replace("/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=all_arch_ids())
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument(
        "--opt",
        default="",
        help="comma list: serve_shardings,donate,remat_dots,remat_none,seq_shard",
    )
    args = ap.parse_args(argv)
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = all_arch_ids() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, opts=opts)
                n_fail += rec["status"] == "error"
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
