"""End-to-end training driver.

Usage (in-container, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

At production scale the same driver runs the full config on the
make_production_mesh topology (multi-controller init happens outside, via the
cluster launcher); everything below is topology-agnostic.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticTokens
from repro.launch.elastic import run_loop
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.sharding import batch_sharding, param_shardings
from repro.training.optimizer import OPTIMIZERS
from repro.training.step import make_train_step


def build_trainer(cfg, mesh, lr=3e-4, optimizer="adamw"):
    params_sh = param_shardings(cfg, mesh)
    opt_init, _ = OPTIMIZERS[optimizer]
    step = make_train_step(cfg, optimizer=optimizer, lr=lr)
    jitted = jax.jit(step, donate_argnums=(0, 1))
    return jitted, params_sh, opt_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=all_arch_ids())
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_parallel)
    compat.set_mesh(mesh)
    jitted, params_sh, opt_init = build_trainer(
        cfg, mesh, lr=args.lr, optimizer=args.optimizer
    )

    params = jax.jit(partial(init_params, cfg), out_shardings=params_sh)(
        jax.random.key(args.seed)
    )
    opt_state = jax.jit(opt_init)(params)

    data = SyntheticTokens(
        vocab=cfg.vocab,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        seed=args.seed,
    )
    b_sh = batch_sharding(mesh, args.global_batch, 2)

    def step_fn(state, idx):
        params, opt_state = state
        batch = {
            k: jax.device_put(v, b_sh) for k, v in data.batch(idx).items()
        }
        params, opt_state, metrics = jitted(params, opt_state, batch)
        if idx % 5 == 0 or idx == args.steps - 1:
            print(
                f"step {idx:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f}",
                flush=True,
            )
        return params, opt_state

    t0 = time.time()
    (params, opt_state), stats = run_loop(
        (params, opt_state),
        step_fn,
        args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        state_to_tree=lambda s: {"params": s[0], "opt": s[1]},
        tree_to_state=lambda t, s: (
            jax.device_put(t["params"], params_sh),
            jax.tree.map(jnp.asarray, t["opt"]),
        ),
    )
    dt = time.time() - t0
    toks = args.steps * args.global_batch * args.seq_len
    print(
        f"done: {stats.steps_run} steps, {stats.restarts} restarts, "
        f"{toks/dt:.0f} tok/s, {len(stats.stragglers)} straggler events"
    )
    return params


if __name__ == "__main__":
    main()
