"""Falcon-Mamba-7B [arXiv:2410.05355]: pure Mamba-1, attention-free, no FFN
sub-block (d_ff=0), ssm_state=16.  Sub-quadratic: runs long_500k."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,       # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,          # no FFN sub-block
    vocab=65024,
    d_head=64,
    layer_kind="mamba",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    norm="rms",
    use_rope=False,
)
SMOKE = CONFIG.scaled_down(d_ff=0)
