"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6]: VLM; anyres-tiling vision frontend
is a STUB (input_specs supplies precomputed patch embeddings); backbone is a
dense GQA decoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    d_head=128,
    act="swiglu",
    norm="rms",
    frontend="vision",
)
SMOKE = CONFIG.scaled_down()
