"""Command-R-35B [hf:CohereForAI/c4ai-command-r-v01]: dense, GQA kv=8,
no-bias, parallel attention+FFN block, LayerNorm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    d_head=128,
    act="swiglu",
    norm="layer",
    parallel_block=True,
    tie_embeddings=True,
)
SMOKE = CONFIG.scaled_down()
