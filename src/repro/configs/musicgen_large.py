"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens
(vocab 2048).  The EnCodec frontend is a stub — inputs are already token ids.
Positional encoding: RoPE stands in for the paper's sinusoidal embeddings
(DESIGN.md assumption note)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    d_head=64,
    act="gelu",
    norm="layer",
    frontend="audio",
)
SMOKE = CONFIG.scaled_down()
