"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-size ModelConfig;
``get_smoke_config(arch_id)`` the reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "starcoder2_15b",
    "internlm2_1_8b",
    "phi3_mini_3_8b",
    "command_r_35b",
    "llava_next_34b",
    "falcon_mamba_7b",
    "qwen3_moe_235b_a22b",
    "dbrx_132b",
    "musicgen_large",
    "hymba_1_5b",
)

# CLI ids (--arch) with dashes/dots, mapped to module names
ARCH_IDS = {
    "starcoder2-15b": "starcoder2_15b",
    "internlm2-1.8b": "internlm2_1_8b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "command-r-35b": "command_r_35b",
    "llava-next-34b": "llava_next_34b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "dbrx-132b": "dbrx_132b",
    "musicgen-large": "musicgen_large",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(arch: str):
    mod = ARCH_IDS.get(arch, arch).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    m = _module(arch)
    return getattr(m, "SMOKE", m.CONFIG.scaled_down())


def all_arch_ids() -> list[str]:
    return list(ARCH_IDS)
