"""Phi-3-mini-3.8B [arXiv:2404.14219]: dense, kv=32 (MHA), RoPE, SwiGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    d_head=96,
    act="swiglu",
    norm="rms",
)
SMOKE = CONFIG.scaled_down()
