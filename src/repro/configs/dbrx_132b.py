"""DBRX-132B [hf:databricks/dbrx-base]: 40L, GQA kv=8, 16 experts top-4
(fine-grained), expert d_ff=10752."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=0,
    vocab=100352,
    d_head=128,
    act="swiglu",
    norm="layer",
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)
SMOKE = CONFIG.scaled_down()
