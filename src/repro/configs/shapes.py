"""Assigned input-shape set for the LM-family architectures.

Each shape names a workload kind:
- train_4k:     train_step,  seq 4,096 x global_batch 256
- prefill_32k:  serve prefill, seq 32,768 x batch 32
- decode_32k:   serve decode (1 new token, KV cache 32,768), batch 128
- long_500k:    long-context decode, cache 524,288, batch 1
                (sub-quadratic archs only; full-attention archs skip)

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

VISION_FRONT_TOKENS = 576  # one anyres tile of patch embeddings (stub)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §long_500k)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention: 500k decode KV infeasible"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    out: dict = {}
    if spec.kind == "train":
        n_front = VISION_FRONT_TOKENS if cfg.frontend == "vision" else 0
        s_txt = S - n_front
        if n_front:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, n_front, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        out["tokens"] = jax.ShapeDtypeStruct((B, s_txt), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, s_txt), i32)
    elif spec.kind == "prefill":
        n_front = VISION_FRONT_TOKENS if cfg.frontend == "vision" else 0
        if n_front:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, n_front, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        out["tokens"] = jax.ShapeDtypeStruct((B, S - n_front), i32)
    else:  # decode: one new token + the cache (cache specs built separately)
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return out


def cache_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the KV/SSM cache at this decode shape."""
    from repro.models.transformer import init_kv_cache

    spec = SHAPES[shape]
    return jax.eval_shape(
        lambda: init_kv_cache(cfg, spec.global_batch, spec.seq_len)
    )
