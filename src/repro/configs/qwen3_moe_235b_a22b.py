"""Qwen3-MoE-235B-A22B [hf:Qwen family]: 94L, GQA kv=4, 128 experts top-8,
expert d_ff=1536, no shared expert.  The MoE dispatch is the SpGEMM the
hypergraph comm planner (repro.core.moe_planner) optimizes."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,          # all-MoE FFN (no dense/shared branch)
    vocab=151936,
    d_head=128,
    act="swiglu",
    norm="rms",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
)
SMOKE = CONFIG.scaled_down()
