"""Hymba-1.5B [arXiv:2411.13676]: hybrid — parallel attention + Mamba heads in
every layer; sliding-window attention (the paper's 3 global-attention layers
are approximated as SWA to keep the scanned layer stack uniform — DESIGN.md
§Arch-applicability).  Sub-quadratic: runs long_500k."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    d_head=64,
    layer_kind="hybrid",
    sliding_window=2048,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    act="swiglu",
    norm="rms",
)
SMOKE = CONFIG.scaled_down()
