"""StarCoder2-15B [arXiv:2402.19173]: dense, GQA kv=4, RoPE, GeLU, LayerNorm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    d_head=128,
    act="gelu",
    norm="layer",
    use_rope=True,
    qkv_bias=True,
    mlp_bias=False,
)
SMOKE = CONFIG.scaled_down()
