"""Fault-injection harness: scripted failures at named stage boundaries.

The resilient-session claim ("an iterated SpGEMM loop survives a failure at
any stage boundary") is only testable if failures can be *produced* at every
boundary, deterministically, without reaching into implementation details.
This module gives production code named patch points:

    from repro.testing import faults
    ...
    faults.fire("partition")     # first line of core.partition.partition

and tests (or a scripted benchmark schedule) arm them:

    with faults.inject("partition", times=1):
        session.multiply(A, B)   # first partition call raises InjectedFault

When nothing is armed, ``fire`` is a dict lookup + counter increment — cheap
enough to live on planning hot paths.  Stages are just strings; the ones the
library fires today are in ``STAGES``.  Every injected failure is counted on
the script object, so tests can assert "the fault actually fired" instead of
passing vacuously when a code path moves.

``inject`` raises ``InjectedFault`` by default — a ``RetryableError``
subclass, so the session's ``FaultPolicy`` treats it as transient (the
common case: exercising retry/restart).  Pass ``exc=ValueError`` (or any
factory) to model a *permanent* failure and exercise the downgrade chain
instead.
"""
from __future__ import annotations

import contextlib

from repro.resilience import RetryableError

__all__ = [
    "STAGES",
    "InjectedFault",
    "call_counts",
    "fire",
    "inject",
    "reset_counts",
    "scripted",
]

#: boundaries the library fires today (any string is accepted)
STAGES = ("partition", "compile", "execute", "store_save", "store_restore")


class InjectedFault(RetryableError):
    """A scripted failure from the fault-injection harness (transient)."""


class _Script:
    """One armed injection: counts the calls it sees, fails the scripted
    ones.  ``seen``/``fired`` are public so tests can assert the fault
    actually triggered."""

    def __init__(self, stage, exc, message, times, after, on_calls):
        self.stage = stage
        self.exc = exc
        self.message = message or f"injected {stage} fault"
        self.times = times
        self.after = after
        self.on_calls = None if on_calls is None else set(int(i) for i in on_calls)
        self.seen = 0
        self.fired = 0

    def check(self) -> None:
        i = self.seen
        self.seen += 1
        if self.on_calls is not None:
            hit = i in self.on_calls
        else:
            hit = i >= self.after and self.fired < self.times
        if hit:
            self.fired += 1
            raise self.exc(f"{self.message} (call {i} of stage {self.stage!r})")


_ACTIVE: dict[str, list[_Script]] = {}
_CALLS: dict[str, int] = {}


def fire(stage: str) -> None:
    """Patch point.  Called by production code at a stage boundary; raises
    when a script armed via :func:`inject` says this call should fail."""
    _CALLS[stage] = _CALLS.get(stage, 0) + 1
    scripts = _ACTIVE.get(stage)
    if not scripts:
        return
    for script in tuple(scripts):
        script.check()


@contextlib.contextmanager
def inject(
    stage: str,
    exc=InjectedFault,
    message: str | None = None,
    times: int = 1,
    after: int = 0,
    on_calls=None,
):
    """Arm ``stage`` to fail while the context is active.

    ``times``/``after``: fail the next ``times`` calls after skipping
    ``after`` of them.  ``on_calls``: explicit 0-based call indices (relative
    to entering the context) to fail instead — a scripted schedule.  Yields
    the script object (``.seen`` / ``.fired`` counters).
    """
    script = _Script(stage, exc, message, times, after, on_calls)
    _ACTIVE.setdefault(stage, []).append(script)
    try:
        yield script
    finally:
        _ACTIVE[stage].remove(script)
        if not _ACTIVE[stage]:
            del _ACTIVE[stage]


@contextlib.contextmanager
def scripted(schedule: dict):
    """Arm several stages at once: ``{stage: on_calls iterable}``.  Yields
    ``{stage: script}`` — the benchmark's failure-schedule entry point."""
    with contextlib.ExitStack() as stack:
        yield {
            stage: stack.enter_context(inject(stage, on_calls=calls))
            for stage, calls in schedule.items()
        }


def call_counts() -> dict:
    """Calls seen per stage since the last :func:`reset_counts` (counts
    accumulate whether or not anything is armed)."""
    return dict(_CALLS)


def reset_counts() -> None:
    _CALLS.clear()
