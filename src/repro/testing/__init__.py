"""Test-support machinery that ships with the library.

``repro.testing.faults`` is the fault-injection harness: production code
exposes named patch points (``faults.fire("partition")`` etc.) and tests
script failures at those boundaries without monkeypatching internals.
"""
from repro.testing import faults

__all__ = ["faults"]
