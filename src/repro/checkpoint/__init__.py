from repro.checkpoint.store import (
    PLAN_STORE_VERSION,
    PlanStoreError,
    RestoredPlan,
    latest_step,
    list_plans,
    quarantine_plan,
    restore_checkpoint,
    restore_plan,
    save_checkpoint,
    save_plan,
)

__all__ = [
    "PLAN_STORE_VERSION",
    "PlanStoreError",
    "RestoredPlan",
    "latest_step",
    "list_plans",
    "quarantine_plan",
    "restore_checkpoint",
    "restore_plan",
    "save_checkpoint",
    "save_plan",
]
