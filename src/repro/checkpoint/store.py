"""Checkpointing + persistent plan store: atomic, versioned, crash-safe.

Two families of on-disk state share one commit discipline:

- **Step checkpoints** (``save_checkpoint``/``restore_checkpoint``): arrays
  are saved as logical (unsharded) .npy files plus a JSON manifest with the
  pytree structure; restore re-shards onto whatever mesh the restarted job
  brings up, so elastic re-scaling (grow/shrink the pod/data axes) is free.
  At true multi-host scale the same layout is written as per-host shard
  files; the manifest format already records per-array metadata to allow
  that extension.

- **Plan store** (``save_plan``/``restore_plan``): lowered ``ExecutionPlan``
  objects keyed by structure fingerprint, written as one ``arrays.npz``
  (every ndarray field) plus a versioned ``manifest.json`` (scalar fields,
  route metadata, a sha256 over the array file).  A restarted session
  rebuilds its warm executor pool from here instead of re-partitioning and
  re-lowering the world (DESIGN.md §10).  Corrupt or version-mismatched
  entries are *quarantined* — renamed aside and logged, never fatal — so a
  bad byte on disk costs one replan, not the process.

Commit protocol (both families): write the payload into a ``*.tmp`` sibling,
rename any existing final dir aside to ``*.prev``, ``os.replace`` the tmp
into place, then drop the ``.prev``.  Every crash window leaves either the
old or the new copy intact; readers call ``_recover_prev`` to promote an
orphaned ``.prev`` back after a crash between the two renames.  (The old
protocol — ``rmtree(final)`` then ``rename`` — had a window where a crash
lost the only copy.)

Pytree manifests record container types: tuples are marked
``{"__tuple__": [...]}`` so ``tree_to_state`` round-trips pytrees exactly
(lists used to come back for both).  The keys ``__tuple__``/``__leaf__``
are reserved — state dicts must not use them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil

import numpy as np

PLAN_STORE_VERSION = 1
_KEY_RE = re.compile(r"[A-Za-z0-9_-]+")


class PlanStoreError(RuntimeError):
    """A plan-store entry failed integrity checks (corrupt, truncated, or
    written by an incompatible version).  Permanent for that entry — the
    caller quarantines and replans instead of retrying."""


# ---------------------------------------------------------------------------
# crash-safe directory commit (shared by checkpoints and the plan store)
# ---------------------------------------------------------------------------
def _commit_dir(tmp: str, final: str) -> None:
    """Atomically promote ``tmp`` to ``final``.

    The old final (if any) is renamed aside to ``final + ".prev"`` first, so
    at every instant at least one complete copy exists under a recoverable
    name; ``os.replace`` then moves the new dir into place and the ``.prev``
    is dropped."""
    prev = final + ".prev"
    if os.path.exists(prev):
        shutil.rmtree(prev)
    if os.path.exists(final):
        os.rename(final, prev)
    os.replace(tmp, final)
    if os.path.exists(prev):
        shutil.rmtree(prev)


def _recover_prev(final: str) -> None:
    """Reader-side crash recovery for ``_commit_dir``: an orphaned ``.prev``
    with no final (crash between the two renames) is promoted back; a stale
    ``.prev`` next to a live final (crash before cleanup) is dropped."""
    prev = final + ".prev"
    if not os.path.exists(prev):
        return
    if os.path.exists(final):
        shutil.rmtree(prev, ignore_errors=True)
    else:
        os.rename(prev, final)


# ---------------------------------------------------------------------------
# pytree <-> flat arrays
# ---------------------------------------------------------------------------
def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict, manifest):
    if isinstance(manifest, dict) and manifest.get("__leaf__"):
        return flat[manifest["key"]]
    if isinstance(manifest, dict) and "__tuple__" in manifest:
        return tuple(_unflatten(flat, v) for v in manifest["__tuple__"])
    if isinstance(manifest, dict):
        return {k: _unflatten(flat, v) for k, v in manifest.items()}
    if isinstance(manifest, list):
        return [_unflatten(flat, v) for v in manifest]
    raise TypeError(type(manifest))


def _manifest_of(tree, prefix=""):
    if isinstance(tree, dict):
        return {k: _manifest_of(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, tuple):
        return {
            "__tuple__": [_manifest_of(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        }
    if isinstance(tree, list):
        return [_manifest_of(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
    return {"__leaf__": True, "key": prefix[:-1]}


# ---------------------------------------------------------------------------
# step checkpoints
# ---------------------------------------------------------------------------
def save_checkpoint(ckpt_dir: str, step: int, state, keep_last: int = 3) -> str:
    """Atomically write ``state`` (pytree of arrays) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    index = {}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), np.asarray(arr))
        index[key] = fname
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {"step": step, "index": index, "tree": _manifest_of(state)}, f, indent=1
        )
    _commit_dir(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{12})\.prev", name)
        if m:
            _recover_prev(os.path.join(ckpt_dir, name[: -len(".prev")]))
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{12})", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None = None, shardings=None):
    """Load a checkpoint; optionally device_put each leaf onto ``shardings``
    (a matching pytree of NamedSharding) — this is the elastic-rescale path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:012d}")
    _recover_prev(d)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {
        key: np.load(os.path.join(d, fname))
        for key, fname in manifest["index"].items()
    }
    state = _unflatten(flat, manifest["tree"])
    if shardings is not None:
        import jax  # lazy: plain restores stay importable without a device stack

        state = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), state, shardings
        )
    return state, step


# ---------------------------------------------------------------------------
# persistent plan store
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RestoredPlan:
    """One plan-store entry read back: the rebuilt ``ExecutionPlan``, the
    caller's side arrays (partition labels, warm-start vertex keys, ...) and
    the caller's JSON metadata."""

    key: str
    plan: object
    arrays: dict[str, np.ndarray]
    meta: dict


_ROUTE_SCALARS = (
    "payload",
    "items_ideal",
    "items_padded",
    "word_size",
    "words_ideal_override",
    "words_padded_override",
)


def _plan_classes():
    from repro.distributed import plan_ir, summa

    return {
        cls.__name__: cls
        for cls in (
            plan_ir.ExecutionPlan,
            plan_ir.RowwisePlan,
            plan_ir.OuterPlan,
            plan_ir.MonoCPlan,
            plan_ir.FinePlan,
            summa.SummaPlan,
        )
    }


def _check_key(key: str) -> str:
    if not _KEY_RE.fullmatch(key):
        raise ValueError(f"plan key must match [A-Za-z0-9_-]+, got {key!r}")
    return key


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_plan(
    store_dir: str,
    key: str,
    plan,
    arrays: dict[str, np.ndarray] | None = None,
    meta: dict | None = None,
) -> str:
    """Atomically persist ``plan`` (an ``ExecutionPlan``) under ``key``.

    ``arrays``: extra ndarrays to store alongside the plan (the session puts
    partition labels and warm-start vertex keys here).  ``meta``: extra
    JSON-serializable metadata (fingerprints, model selection, ...).
    Returns the committed directory."""
    from repro.testing import faults

    faults.fire("store_save")
    _check_key(key)
    os.makedirs(store_dir, exist_ok=True)
    final = os.path.join(store_dir, key)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    blobs: dict[str, np.ndarray] = {}
    for name, arr in plan.ownership.items():
        blobs[f"own__{name}"] = np.asarray(arr)
    for name, arr in plan.local_ids.items():
        blobs[f"lid__{name}"] = np.asarray(arr)
    for name, route in plan.routes.items():
        blobs[f"route__{name}__send_idx"] = route.send_idx
        blobs[f"route__{name}__recv_key"] = route.recv_key
    for name, arr in plan.compute.items():
        blobs[f"cmp__{name}"] = np.asarray(arr)
    for name, arr in (arrays or {}).items():
        blobs[f"extra__{name}"] = np.asarray(arr)
    arr_path = os.path.join(tmp, "arrays.npz")
    np.savez_compressed(arr_path, **blobs)

    manifest = {
        "format": "repro-plan-store",
        "version": PLAN_STORE_VERSION,
        "key": key,
        "plan_class": type(plan).__name__,
        "model": plan.model,
        "p": int(plan.p),
        "routes": {
            name: {
                field: (
                    None
                    if getattr(route, field) is None
                    else getattr(route, field)
                    if field == "payload"
                    else int(getattr(route, field))
                )
                for field in _ROUTE_SCALARS
            }
            for name, route in plan.routes.items()
        },
        "stats": {k: int(v) for k, v in plan.stats.items()},
        "arrays_sha256": _sha256(arr_path),
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _commit_dir(tmp, final)
    return final


def _read_plan_entry(entry_dir: str, key: str) -> RestoredPlan:
    """Parse + integrity-check one entry; raises ``PlanStoreError`` on any
    corruption or version mismatch (the quarantinable failures)."""
    man_path = os.path.join(entry_dir, "manifest.json")
    arr_path = os.path.join(entry_dir, "arrays.npz")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except FileNotFoundError as e:
        raise PlanStoreError(f"plan {key!r}: missing manifest") from e
    except json.JSONDecodeError as e:
        raise PlanStoreError(f"plan {key!r}: corrupt manifest: {e}") from e
    if manifest.get("format") != "repro-plan-store":
        raise PlanStoreError(f"plan {key!r}: not a plan-store entry")
    version = manifest.get("version")
    if version != PLAN_STORE_VERSION:
        raise PlanStoreError(
            f"plan {key!r}: version {version} != {PLAN_STORE_VERSION}"
        )
    if not os.path.exists(arr_path):
        raise PlanStoreError(f"plan {key!r}: missing arrays.npz")
    digest = _sha256(arr_path)
    if digest != manifest.get("arrays_sha256"):
        raise PlanStoreError(
            f"plan {key!r}: arrays.npz checksum mismatch "
            f"({digest[:12]} != {str(manifest.get('arrays_sha256'))[:12]})"
        )
    classes = _plan_classes()
    cls = classes.get(manifest.get("plan_class"))
    if cls is None:
        raise PlanStoreError(
            f"plan {key!r}: unknown plan class {manifest.get('plan_class')!r}"
        )

    from repro.distributed.plan_ir import Route

    try:
        with np.load(arr_path) as z:
            blobs = {name: z[name] for name in z.files}
    except Exception as e:  # zipfile/np errors on truncated archives
        raise PlanStoreError(f"plan {key!r}: unreadable arrays.npz: {e}") from e

    ownership, local_ids, compute, extra = {}, {}, {}, {}
    route_arrays: dict[str, dict[str, np.ndarray]] = {}
    for name, arr in blobs.items():
        if name.startswith("own__"):
            ownership[name[5:]] = arr
        elif name.startswith("lid__"):
            local_ids[name[5:]] = arr
        elif name.startswith("cmp__"):
            compute[name[5:]] = arr
        elif name.startswith("extra__"):
            extra[name[7:]] = arr
        elif name.startswith("route__"):
            rname, _, field = name[7:].rpartition("__")
            route_arrays.setdefault(rname, {})[field] = arr
        else:
            raise PlanStoreError(f"plan {key!r}: unexpected array {name!r}")
    routes = {}
    try:
        for rname, scalars in manifest["routes"].items():
            arrs = route_arrays[rname]
            routes[rname] = Route(
                payload=scalars["payload"],
                send_idx=arrs["send_idx"],
                recv_key=arrs["recv_key"],
                items_ideal=scalars["items_ideal"],
                items_padded=scalars["items_padded"],
                word_size=scalars["word_size"],
                words_ideal_override=scalars["words_ideal_override"],
                words_padded_override=scalars["words_padded_override"],
            )
        plan = cls(
            model=manifest["model"],
            p=int(manifest["p"]),
            ownership=ownership,
            local_ids=local_ids,
            routes=routes,
            compute=compute,
            stats=dict(manifest["stats"]),
        )
    except KeyError as e:
        raise PlanStoreError(f"plan {key!r}: manifest/arrays mismatch: {e}") from e
    return RestoredPlan(key=key, plan=plan, arrays=extra, meta=manifest["meta"])


def quarantine_plan(store_dir: str, key: str, reason: str = "") -> str | None:
    """Rename a bad entry aside (``<key>.quarantined-<n>``) and log it.
    Returns the quarantine path, or None if the entry vanished meanwhile."""
    import warnings

    entry = os.path.join(store_dir, _check_key(key))
    if not os.path.exists(entry):
        return None
    n = 0
    while os.path.exists(dst := f"{entry}.quarantined-{n}"):
        n += 1
    os.rename(entry, dst)
    warnings.warn(
        f"plan store: quarantined {key!r} -> {os.path.basename(dst)}"
        + (f" ({reason})" if reason else ""),
        RuntimeWarning,
        stacklevel=2,
    )
    return dst


def restore_plan(
    store_dir: str, key: str, quarantine: bool = True
) -> RestoredPlan | None:
    """Read back one plan-store entry.

    Returns None when the entry does not exist — and, with ``quarantine``
    (the default), also when it exists but fails integrity checks, in which
    case it is renamed aside first (a bad entry costs one replan, never the
    process).  With ``quarantine=False`` integrity failures raise
    ``PlanStoreError``.  Transient IO errors propagate either way (they are
    retryable; quarantining on them would discard good data)."""
    from repro.testing import faults

    faults.fire("store_restore")
    entry = os.path.join(store_dir, _check_key(key))
    _recover_prev(entry)
    if not os.path.isdir(entry):
        return None
    try:
        return _read_plan_entry(entry, key)
    except PlanStoreError as e:
        if not quarantine:
            raise
        quarantine_plan(store_dir, key, reason=str(e))
        return None


def list_plans(store_dir: str) -> list[str]:
    """Keys of the committed (non-quarantined, non-tmp) entries."""
    if not os.path.isdir(store_dir):
        return []
    for name in os.listdir(store_dir):
        if name.endswith(".prev"):
            _recover_prev(os.path.join(store_dir, name[: -len(".prev")]))
    out = []
    for name in os.listdir(store_dir):
        if _KEY_RE.fullmatch(name) and os.path.isdir(os.path.join(store_dir, name)):
            out.append(name)
    return sorted(out)
