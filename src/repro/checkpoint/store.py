"""Checkpointing: atomic, step-indexed, mesh-shape-agnostic.

Arrays are saved as logical (unsharded) .npy files plus a JSON manifest with
the pytree structure; restore re-shards onto whatever mesh the restarted job
brings up, so elastic re-scaling (grow/shrink the pod/data axes) is free.
Commit is atomic (write to ``.tmp-<step>`` then ``os.rename``), so a crash
mid-save can never corrupt the latest checkpoint.  At true multi-host scale
the same layout is written as per-host shard files; the manifest format
already records per-array metadata to allow that extension.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict, manifest):
    if isinstance(manifest, dict) and manifest.get("__leaf__"):
        return flat[manifest["key"]]
    if isinstance(manifest, dict):
        return {k: _unflatten(flat, v) for k, v in manifest.items()}
    if isinstance(manifest, list):
        return [_unflatten(flat, v) for v in manifest]
    raise TypeError(type(manifest))


def _manifest_of(tree, prefix=""):
    if isinstance(tree, dict):
        return {k: _manifest_of(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_manifest_of(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
    return {"__leaf__": True, "key": prefix[:-1]}


def save_checkpoint(ckpt_dir: str, step: int, state, keep_last: int = 3) -> str:
    """Atomically write ``state`` (pytree of arrays) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    index = {}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), np.asarray(arr))
        index[key] = fname
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {"step": step, "index": index, "tree": _manifest_of(state)}, f, indent=1
        )
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{12})", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None = None, shardings=None):
    """Load a checkpoint; optionally device_put each leaf onto ``shardings``
    (a matching pytree of NamedSharding) — this is the elastic-rescale path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {
        key: np.load(os.path.join(d, fname))
        for key, fname in manifest["index"].items()
    }
    state = _unflatten(flat, manifest["tree"])
    if shardings is not None:
        state = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), state, shardings
        )
    return state, step
