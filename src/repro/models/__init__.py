"""Model stack: decoder transformer/SSM/hybrid layers + full models."""
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import (
    init_params,
    init_kv_cache,
    forward,
    train_loss,
    decode_step,
    param_count,
    active_param_count,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "init_params",
    "init_kv_cache",
    "forward",
    "train_loss",
    "decode_step",
    "param_count",
    "active_param_count",
]
