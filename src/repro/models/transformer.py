"""Full decoder model: init, forward (train/prefill), decode step, KV cache.

Layers are stacked (leading axis = n_layers) and iterated with ``lax.scan``
so the HLO stays one-layer-sized regardless of depth (compile-time critical
for the 94-layer MoE dry-runs).  Heterogeneous-per-layer behaviour (hybrid
global/SWA patterns) rides through per-layer scalar scan inputs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Materialize parameters.  Use jax.eval_shape(init_params, ...) for
    allocation-free shapes (the dry-run path)."""
    dt = jnp.dtype(cfg.dtype)
    d, H, KVH, Dh, F, V, Ln = (
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        cfg.d_ff,
        cfg.vocab,
        cfg.n_layers,
    )
    keys = iter(jax.random.split(key, 64))
    s_embed = 1.0 / np.sqrt(d)
    params: dict = {
        "embed": {"tokens": _init(next(keys), (V, d), s_embed, dt)},
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(next(keys), (d, V), s_embed, dt)
    layer: dict = {
        "ln1": jnp.ones((Ln, d), dt),
        "ln2": jnp.ones((Ln, d), dt),
    }
    if cfg.layer_kind in ("attn", "hybrid"):
        attn = {
            "wq": _init(next(keys), (Ln, d, H, Dh), s_embed, dt),
            "wk": _init(next(keys), (Ln, d, KVH, Dh), s_embed, dt),
            "wv": _init(next(keys), (Ln, d, KVH, Dh), s_embed, dt),
            "wo": _init(next(keys), (Ln, H, Dh, d), 1.0 / np.sqrt(H * Dh), dt),
        }
        if cfg.qkv_bias:
            attn["bq"] = jnp.zeros((Ln, H, Dh), dt)
            attn["bk"] = jnp.zeros((Ln, KVH, Dh), dt)
            attn["bv"] = jnp.zeros((Ln, KVH, Dh), dt)
        layer["attn"] = attn
    if cfg.layer_kind in ("mamba", "hybrid"):
        Di = cfg.d_inner
        N = (cfg.ssm.d_state if cfg.ssm else 16)
        Kc = (cfg.ssm.d_conv if cfg.ssm else 4)
        layer["ssm"] = {
            "in_proj": _init(next(keys), (Ln, d, Di), s_embed, dt),
            "gate_proj": _init(next(keys), (Ln, d, Di), s_embed, dt),
            "conv_w": _init(next(keys), (Ln, Kc, Di), 0.5, dt),
            "x_proj_b": _init(next(keys), (Ln, Di, N), s_embed, dt),
            "x_proj_c": _init(next(keys), (Ln, Di, N), s_embed, dt),
            "dt_proj": jnp.ones((Ln, Di), dt) * 0.1,
            "a_log": jnp.tile(
                jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, None],
                (Ln, Di, 1),
            ).astype(dt),
            "d_skip": jnp.ones((Ln, Di), dt),
            "out_proj": _init(next(keys), (Ln, Di, d), 1.0 / np.sqrt(Di), dt),
        }
    if cfg.moe is not None:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        layer["moe"] = {
            "router": _init(next(keys), (Ln, d, E), s_embed, jnp.float32),
            "wi": _init(next(keys), (Ln, E, d, Fe), s_embed, dt),
            "wg": _init(next(keys), (Ln, E, d, Fe), s_embed, dt),
            "wo": _init(next(keys), (Ln, E, Fe, d), 1.0 / np.sqrt(Fe), dt),
        }
        if cfg.moe.n_shared_experts:
            layer["shared_mlp"] = {
                "wi": _init(next(keys), (Ln, d, F), s_embed, dt),
                "wg": _init(next(keys), (Ln, d, F), s_embed, dt),
                "wo": _init(next(keys), (Ln, F, d), 1.0 / np.sqrt(F), dt),
            }
    elif F > 0:  # F == 0: no FFN sub-block (pure-Mamba archs)
        mlp = {
            "wi": _init(next(keys), (Ln, d, F), s_embed, dt),
            "wo": _init(next(keys), (Ln, F, d), 1.0 / np.sqrt(F), dt),
        }
        if cfg.act in ("swiglu", "geglu"):
            mlp["wg"] = _init(next(keys), (Ln, d, F), s_embed, dt)
        layer["mlp"] = mlp
    params["layers"] = layer
    return params


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of n_experts)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    expert = sum(
        int(np.prod(shapes["layers"]["moe"][k].shape))
        for k in ("wi", "wg", "wo")
    )
    active_frac = cfg.moe.top_k / cfg.moe.n_experts
    return total - expert + int(expert * active_frac)


# ---------------------------------------------------------------------------
# layer body (shared by train/prefill and decode)
# ---------------------------------------------------------------------------
def _attn_branch(lp, x, cfg: ModelConfig, positions, window):
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
    if cfg.qkv_bias:
        q = q + lp["attn"]["bq"]
        k = k + lp["attn"]["bk"]
        v = v + lp["attn"]["bv"]
    if cfg.use_rope:
        cos, sin = L.rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    o = L.chunked_attention(q, k, v, window=window)
    o = constrain(o, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"]), (k, v)


def _gather_fsdp(lp, cfg: ModelConfig):
    """ZeRO-3-style weight gathering: constrain this layer's weights to their
    TP-only sharding (drop the FSDP 'data' axis) right before use, so XLA
    all-gathers the (small) weights once instead of all-reducing the (large)
    partially-contracted activations."""
    from repro.models.sharding import param_logical_axes, serve_overlay

    axes = serve_overlay(param_logical_axes(cfg))["layers"]

    def fix(leaf, ax):
        return constrain(leaf, *ax[1:])  # strip the scanned 'layers' axis

    return jax.tree.map(
        fix,
        lp,
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _layer_fwd(lp, x, cfg: ModelConfig, positions):
    """One decoder layer (train/prefill).  Returns (y, aux_loss)."""
    if cfg.gather_weights:
        lp = _gather_fsdp(lp, cfg)
    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window
    h = L.apply_norm(cfg.norm, x, lp["ln1"])
    if cfg.layer_kind == "attn":
        attn_out, _ = _attn_branch(lp, h, cfg, positions, window)
        mix = attn_out
    elif cfg.layer_kind == "mamba":
        mix = L.mamba_block(lp["ssm"], h, cfg)
    else:  # hybrid: parallel attention + SSM heads (Hymba)
        attn_out, _ = _attn_branch(lp, h, cfg, positions, window)
        ssm_out = L.mamba_block(lp["ssm"], h, cfg)
        mix = 0.5 * (attn_out + ssm_out)

    if cfg.parallel_block:
        # command-r style: MLP on the same normalized input, single residual
        ff, aux = _ffn(lp, h, cfg)
        return x + mix + ff, aux
    x = x + mix
    h2 = L.apply_norm(cfg.norm, x, lp["ln2"])
    ff, aux = _ffn(lp, h2, cfg)
    return x + ff, aux


def _ffn(lp, h, cfg: ModelConfig):
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        out, aux = L.moe_layer(lp["moe"], h, cfg)
        if cfg.moe.n_shared_experts:
            out = out + L.mlp(lp["shared_mlp"], h, cfg.act)
    elif "mlp" in lp:
        out = L.mlp(lp["mlp"], h, cfg.act)
    else:  # no FFN sub-block (pure-Mamba archs)
        out = jnp.zeros_like(h)
    return out, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """tokens and/or precomputed frontend embeddings -> (B, S, d)."""
    parts = []
    if "frontend_embeds" in batch:  # vlm/audio stub: modality frontend output
        parts.append(batch["frontend_embeds"].astype(cfg.dtype))
    if "tokens" in batch:
        tok = params["embed"]["tokens"][batch["tokens"]]
        parts.append(tok)
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return x


def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    remat: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, V), aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", "seq", "embed")
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    body = partial(_layer_fwd, cfg=cfg, positions=positions)
    if remat and cfg.remat_policy != "none":
        policy = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        }[cfg.remat_policy]
        body = jax.checkpoint(body, policy=policy)

    residual_axes = (
        ("batch", "seq_shard", "embed")
        if cfg.seq_shard_residual
        else ("batch", "seq", "embed")
    )

    def scan_fn(carry, lp):
        y, aux = body(lp, carry)
        return constrain(y, *residual_axes), aux

    x, auxes = jax.lax.scan(
        scan_fn, x, params["layers"],
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    unembed = (
        params["embed"]["tokens"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, unembed)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, auxes.sum()


def train_loss(params, cfg: ModelConfig, batch: dict, remat: bool = True):
    logits, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    V = logits.shape[-1]
    # frontend positions carry no labels: mask with -1
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    # align: logits for positions [0, S_txt) predicting labels
    S_lab = labels.shape[1]
    token_logp = jnp.take_along_axis(
        logp[:, -S_lab:], safe[..., None], axis=-1
    )[..., 0]
    nll = -(token_logp * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# prefill (serve) path: forward + cache construction
# ---------------------------------------------------------------------------
def _ring_align(x: jnp.ndarray, S: int, C: int, axis: int) -> jnp.ndarray:
    """Trim the last C of S positions and rotate so position p sits at ring
    slot p % C (matches decode's ``slot = pos % C``)."""
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(S - C, S)
    trimmed = x[tuple(idx)]
    return jnp.roll(trimmed, (S - C) % C, axis=axis)


def prefill_step(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
) -> tuple[jnp.ndarray, dict]:
    """Run the full prompt, return (last-token logits (B, V), KV cache)."""
    x = embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", "seq", "embed")
    B, S, d = x.shape
    C = kv_cache_len(cfg, S)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    residual_axes = (
        ("batch", "seq_shard", "embed")
        if cfg.seq_shard_residual
        else ("batch", "seq", "embed")
    )

    def scan_fn(carry, lp):
        x = carry
        if cfg.gather_weights:
            lp = _gather_fsdp(lp, cfg)
        h = L.apply_norm(cfg.norm, x, lp["ln1"])
        entries = {}
        if cfg.layer_kind in ("attn", "hybrid"):
            attn_out, (k, v) = _attn_branch(lp, h, cfg, positions, cfg.sliding_window)
            entries["k"] = _ring_align(k, S, C, axis=1)
            entries["v"] = _ring_align(v, S, C, axis=1)
            entries["cache_pos"] = _ring_align(
                jnp.arange(S, dtype=jnp.int32), S, C, axis=0
            )
        if cfg.layer_kind in ("mamba", "hybrid"):
            ssm_out, conv_tail, h_last = L.mamba_block_with_state(lp["ssm"], h, cfg)
            entries["conv"] = conv_tail
            entries["h"] = h_last
        if cfg.layer_kind == "attn":
            mix = attn_out
        elif cfg.layer_kind == "mamba":
            mix = ssm_out
        else:
            mix = 0.5 * (attn_out + ssm_out)
        if cfg.parallel_block:
            ff, _ = _ffn(lp, h, cfg)
            y = x + mix + ff
        else:
            x2 = x + mix
            h2 = L.apply_norm(cfg.norm, x2, lp["ln2"])
            ff, _ = _ffn(lp, h2, cfg)
            y = x2 + ff
        return constrain(y, *residual_axes), entries

    x, layer_cache = jax.lax.scan(
        scan_fn, x, params["layers"],
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    x = L.apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    unembed = (
        params["embed"]["tokens"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = constrain(jnp.einsum("bsd,dv->bsv", x, unembed), "batch", "seq", "vocab")[:, 0]
    cache = {"pos": jnp.asarray(S, jnp.int32), **layer_cache}
    return logits, cache


# ---------------------------------------------------------------------------
# decode (serve) path
# ---------------------------------------------------------------------------
def kv_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(cfg.sliding_window, seq_len) if cfg.sliding_window else seq_len


def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Cache pytree.  Attention: ring-buffer K/V (window-capped).  SSM:
    (conv_state, h).  Hybrid: both."""
    dt = jnp.dtype(dtype or cfg.dtype)
    C = kv_cache_len(cfg, seq_len)
    Ln = cfg.n_layers
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.layer_kind in ("attn", "hybrid"):
        cache["k"] = jnp.zeros((Ln, batch, C, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["v"] = jnp.zeros((Ln, batch, C, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["cache_pos"] = jnp.full((Ln, C), -1, jnp.int32)
    if cfg.layer_kind in ("mamba", "hybrid"):
        ssm = cfg.ssm
        Kc = ssm.d_conv if ssm else 4
        N = ssm.d_state if ssm else 16
        cache["conv"] = jnp.zeros((Ln, batch, Kc - 1, cfg.d_inner), dt)
        cache["h"] = jnp.zeros((Ln, batch, cfg.d_inner, N), jnp.float32)
    return cache


def _layer_decode(lp, x, cache_slice, cfg: ModelConfig, pos):
    """One layer, one token.  cache_slice holds this layer's cache entries."""
    window = cfg.sliding_window
    h = L.apply_norm(cfg.norm, x, lp["ln1"])
    new_cache = dict(cache_slice)
    C = cache_slice["k"].shape[1] if "k" in cache_slice else 0
    kv_axes = {
        "none": None,
        "batch": ("batch", None, "kv_heads", "head_dim"),
        "seq": ("batch", "kv_seq", "kv_heads", "head_dim"),
    }[cfg.kv_shard_mode]

    def pin(c):
        return constrain(c, *kv_axes) if kv_axes else c

    def attn_out(h):
        B = h.shape[0]
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        if cfg.qkv_bias:
            q = q + lp["attn"]["bq"]
            k = k + lp["attn"]["bk"]
            v = v + lp["attn"]["bv"]
        if cfg.use_rope:
            p = jnp.broadcast_to(pos[None, None], (B, 1))
            cos, sin = L.rope_freqs(cfg.head_dim, cfg.rope_theta, p)
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        slot = pos % C
        k_cache = pin(pin(new_cache["k"]).at[:, slot].set(k[:, 0]))
        v_cache = pin(pin(new_cache["v"]).at[:, slot].set(v[:, 0]))
        cache_pos = new_cache["cache_pos"].at[slot].set(pos)
        new_cache.update(k=k_cache, v=v_cache, cache_pos=cache_pos)
        o = L.decode_attention(q, k_cache, v_cache, cache_pos, pos, window)
        return jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])

    if cfg.layer_kind == "attn":
        mix = attn_out(h)
    elif cfg.layer_kind == "mamba":
        mix, conv, hst = L.mamba_decode_step(
            lp["ssm"], h, cache_slice["conv"], cache_slice["h"], cfg
        )
        new_cache.update(conv=conv, h=hst)
    else:
        a = attn_out(h)
        m, conv, hst = L.mamba_decode_step(
            lp["ssm"], h, cache_slice["conv"], cache_slice["h"], cfg
        )
        new_cache.update(conv=conv, h=hst)
        mix = 0.5 * (a + m)

    if cfg.parallel_block:
        ff, _ = _ffn(lp, h, cfg)
        return x + mix + ff, new_cache
    x = x + mix
    h2 = L.apply_norm(cfg.norm, x, lp["ln2"])
    ff, _ = _ffn(lp, h2, cfg)
    return x + ff, new_cache


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache: dict,
    tokens: jnp.ndarray,  # (B, 1) current token ids
) -> tuple[jnp.ndarray, dict]:
    """One serve step: returns (logits (B, V), new cache)."""
    x = params["embed"]["tokens"][tokens]
    pos = cache["pos"]

    per_layer_keys = [k for k in cache if k not in ("pos",)]

    def scan_fn(carry, inp):
        x = carry
        lp, cache_slice = inp
        y, new_slice = _layer_decode(lp, x, cache_slice, cfg, pos)
        return y, new_slice

    layer_cache = {k: cache[k] for k in per_layer_keys}
    x, new_layer_cache = jax.lax.scan(
        scan_fn, x, (params["layers"], layer_cache),
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    unembed = (
        params["embed"]["tokens"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = constrain(jnp.einsum("bsd,dv->bsv", x, unembed), "batch", "seq", "vocab")[:, 0]
    new_cache = {"pos": pos + 1, **new_layer_cache}
    return logits, new_cache
