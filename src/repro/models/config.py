"""Model configuration.

One ``ModelConfig`` describes any architecture in the assigned pool: dense
GQA transformers, MoE, pure-SSM (Mamba-1), hybrid attention+SSM, and stubbed
modality frontends (VLM / audio: the backbone consumes precomputed
frame/patch embeddings through ``input_specs``).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    router_aux_coef: float = 0.01
    # expert permutation from the hypergraph comm planner (beyond-paper);
    # None = identity placement
    expert_placement: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2  # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    layer_kind: Literal["attn", "mamba", "hybrid"] = "attn"
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm: Literal["rms", "layer"] = "rms"
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    parallel_block: bool = False  # attn & MLP in parallel (command-r style)
    tie_embeddings: bool = False
    sliding_window: int = 0  # 0 = full attention
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    frontend: Literal["none", "vision", "audio"] = "none"
    # sub-quadratic? (drives long_500k applicability)
    dtype: str = "bfloat16"
    # dry-run only: unroll the layer scan so cost_analysis / the collective
    # census see every layer (XLA counts while-loop bodies once)
    scan_unroll: bool = False
    # perf knobs (EXPERIMENTS.md §Perf): activation-checkpoint policy and
    # sequence-parallel residual stream (saved activations sharded over
    # 'model' between layers)
    remat_policy: str = "nothing"  # "nothing" | "dots" | "none"
    seq_shard_residual: bool = False
    # gather FSDP-sharded weights at use point (ZeRO-3 semantics) instead of
    # letting XLA all-reduce partially-computed activations
    gather_weights: bool = False
    # KV-cache sharding inside decode: "none" (baseline: XLA free to regather)
    # | "batch" (pin batch sharding) | "seq" (cache length over 'model' —
    # distributed flash-decode; softmax stats reduced across columns)
    kv_shard_mode: str = "batch"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        return self.layer_kind == "mamba" or (
            self.layer_kind == "hybrid" and self.sliding_window > 0
        )

    @property
    def d_inner(self) -> int:
        ssm = self.ssm or SSMConfig()
        return ssm.expand * self.d_model

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        base = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab=256,
            d_head=16,
            layer_kind=self.layer_kind,
            act=self.act,
            norm=self.norm,
            rope_theta=self.rope_theta,
            use_rope=self.use_rope,
            qkv_bias=self.qkv_bias,
            mlp_bias=self.mlp_bias,
            parallel_block=self.parallel_block,
            tie_embeddings=self.tie_embeddings,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            moe=(
                MoEConfig(
                    n_experts=4,
                    top_k=min(self.moe.top_k, 2),
                    d_ff_expert=64,
                    capacity_factor=self.moe.capacity_factor,
                    n_shared_experts=self.moe.n_shared_experts,
                )
                if self.moe
                else None
            ),
            ssm=SSMConfig(d_state=8, d_conv=4, expand=2) if self.ssm else None,
            frontend=self.frontend,
            dtype="float32",
        )
        base.update(overrides)
        return ModelConfig(**base)
