"""Sharding rules: logical parameter/activation axes -> mesh axes.

Megatron-style TP on the ``model`` axis, FSDP-style parameter/optimizer
sharding on the ``data`` axis, pure DP on the ``pod`` axis (multi-pod).
Experts (MoE) ride the ``model`` axis (expert parallelism).
"""
from __future__ import annotations

import numpy as np
import jax

from repro import compat
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis name -> mesh axis (None = replicated)
LOGICAL_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "model",  # sequence-parallel regions (MoE entry)
    "embed": None,  # activations' feature axis
    "embed_fsdp": "data",  # weights' feature axis (FSDP)
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",  # sequence-sharded KV cache (distributed flash-decode)
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_ff": None,
    "vocab": "model",
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
}


def spec_for(*logical_axes: str | None, mesh: Mesh) -> P:
    """Translate logical axes to a PartitionSpec valid for ``mesh`` (axes the
    mesh lacks — e.g. 'pod' on the single-pod mesh — are dropped)."""
    out = []
    for ax in logical_axes:
        if ax is None:
            out.append(None)
            continue
        phys = LOGICAL_RULES.get(ax, None)
        if phys is None:
            out.append(None)
        elif isinstance(phys, tuple):
            present = tuple(a for a in phys if a in mesh.axis_names)
            out.append(present if len(present) > 1 else (present[0] if present else None))
        else:
            out.append(phys if phys in mesh.axis_names else None)
    return P(*out)


def named(mesh: Mesh, *logical_axes: str | None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(*logical_axes, mesh=mesh))


# ---------------------------------------------------------------------------
# parameter logical-axis trees (mirror the params pytree structure)
# ---------------------------------------------------------------------------
def serve_overlay(axes_tree):
    """Serving shardings: drop the FSDP ('data') axis from weights — decode
    steps must not all-gather parameters every token.  Weights end up
    TP-sharded over 'model' and replicated over 'data'/'pod'."""

    def fix(ax):
        return tuple(None if a == "embed_fsdp" else a for a in ax)

    return jax.tree.map(
        fix,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def param_logical_axes(cfg) -> dict:
    """Logical axes per parameter; structure mirrors ``init_params``."""
    L = ("layers",)
    axes: dict = {
        "embed": {"tokens": ("vocab", "embed_fsdp")},
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("embed_fsdp", "vocab")
    layer: dict = {
        "ln1": L + ("embed",),
        "ln2": L + ("embed",),
    }
    if cfg.layer_kind in ("attn", "hybrid"):
        layer["attn"] = {
            "wq": L + ("embed_fsdp", "heads", "head_dim"),
            "wk": L + ("embed_fsdp", "kv_heads", "head_dim"),
            "wv": L + ("embed_fsdp", "kv_heads", "head_dim"),
            "wo": L + ("heads", "head_dim", "embed_fsdp"),
        }
        if cfg.qkv_bias:
            layer["attn"]["bq"] = L + ("heads", "head_dim")
            layer["attn"]["bk"] = L + ("kv_heads", "head_dim")
            layer["attn"]["bv"] = L + ("kv_heads", "head_dim")
    if cfg.layer_kind in ("mamba", "hybrid"):
        layer["ssm"] = {
            "in_proj": L + ("embed_fsdp", "ssm_inner"),
            "gate_proj": L + ("embed_fsdp", "ssm_inner"),
            "conv_w": L + ("conv", "ssm_inner"),
            "x_proj_b": L + ("ssm_inner", "ssm_state"),
            "x_proj_c": L + ("ssm_inner", "ssm_state"),
            "dt_proj": L + ("ssm_inner",),
            "a_log": L + ("ssm_inner", "ssm_state"),
            "d_skip": L + ("ssm_inner",),
            "out_proj": L + ("ssm_inner", "embed_fsdp"),
        }
    if cfg.moe is not None:
        layer["moe"] = {
            "router": L + ("embed", "experts"),
            "wi": L + ("experts", "embed_fsdp", "expert_ff"),
            "wg": L + ("experts", "embed_fsdp", "expert_ff"),
            "wo": L + ("experts", "expert_ff", "embed_fsdp"),
        }
        if cfg.moe.n_shared_experts:
            layer["shared_mlp"] = {
                "wi": L + ("embed_fsdp", "ff"),
                "wg": L + ("embed_fsdp", "ff"),
                "wo": L + ("ff", "embed_fsdp"),
            }
    elif cfg.d_ff > 0:  # d_ff == 0: no FFN sub-block (pure-Mamba archs)
        layer["mlp"] = {
            "wi": L + ("embed_fsdp", "ff"),
            "wo": L + ("ff", "embed_fsdp"),
        }
        if cfg.act in ("swiglu", "geglu"):
            layer["mlp"]["wg"] = L + ("embed_fsdp", "ff")
    axes["layers"] = layer
    return axes


def _fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (e.g. 4 KV
    heads on a 16-way model axis, vocab 32001): replicate instead."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept: list[str] = []
        size = 1
        for a in axes:
            if dim % (size * mesh.shape[a]) == 0:
                kept.append(a)
                size *= mesh.shape[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def fit_sharding_tree(shapes_tree, axes_tree, mesh: Mesh):
    """NamedSharding pytree: logical axes resolved against actual shapes."""
    return jax.tree.map(
        lambda shp, ax: NamedSharding(
            mesh, _fit_spec(spec_for(*ax, mesh=mesh), shp.shape, mesh)
        ),
        shapes_tree,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def param_shardings(cfg, mesh: Mesh, serve: bool = False):
    """NamedSharding pytree matching params (shape-aware)."""
    from functools import partial
    from repro.models.transformer import init_params

    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    axes = param_logical_axes(cfg)
    if serve:
        axes = serve_overlay(axes)
    return jax.tree.map(
        lambda shp, ax: NamedSharding(
            mesh, _fit_spec(spec_for(*ax, mesh=mesh), shp.shape, mesh)
        ),
        shapes,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes; no-op outside a mesh context
    (CPU smoke tests).  Divisibility-checked against the ambient mesh."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    spec = _fit_spec(spec_for(*logical_axes, mesh=mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def batch_sharding(mesh: Mesh, batch_size: int, ndim: int) -> NamedSharding:
    """Shard the leading (batch) dim over as much of (pod, data) as divides."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    kept: list[str] = []
    size = 1
    for a in axes:
        if batch_size % (size * mesh.shape[a]) == 0:
            kept.append(a)
            size *= mesh.shape[a]
    first = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)
    return NamedSharding(mesh, P(first, *([None] * (ndim - 1))))
