"""Layer primitives: norms, RoPE, chunked causal attention (GQA + sliding
window), SwiGLU/GeLU MLP, expert-parallel MoE, Mamba-1 selective SSM.

Functional style: each layer is (params, x, ...) -> y; parameters live in
plain dict pytrees created by ``transformer.init_params``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * w


def apply_norm(kind: str, x, w):
    return rms_norm(x, w) if kind == "rms" else layer_norm(x, w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float, positions: jnp.ndarray) -> tuple:
    """positions: (...,) -> cos/sin of shape (..., d_head//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, Dh); cos/sin: (B?, S, Dh//2) broadcastable."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]  # (B, S, 1, Dh//2)
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked causal attention (flash-style online softmax, pure JAX)
# ---------------------------------------------------------------------------
def chunked_attention(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, S, KVH, Dh)
    v: jnp.ndarray,  # (B, S, KVH, Dh)
    window: int = 0,  # 0 = full causal
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    B, S, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH  # query groups per kv head
    scale = 1.0 / np.sqrt(Dh)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nk = S // q_chunk, S // kv_chunk
    if S % q_chunk or S % kv_chunk:
        raise ValueError(f"S={S} not divisible by chunks {q_chunk}/{kv_chunk}")

    # (B, nq, qc, KVH, G, Dh)
    qr = q.reshape(B, nq, q_chunk, KVH, G, Dh)
    kr = k.reshape(B, nk, kv_chunk, KVH, Dh)
    vr = v.reshape(B, nk, kv_chunk, KVH, Dh)

    def per_q_chunk(qi, q_blk):
        # online softmax over kv chunks
        def step(carry, ki):
            m, l, acc = carry
            k_blk = kr[:, ki]  # (B, kc, KVH, Dh)
            v_blk = vr[:, ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            # causal / sliding-window mask between absolute positions
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), 0.0, corr)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, q_chunk), -jnp.inf, dtype=jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), dtype=jnp.float32)
        acc0 = jnp.zeros((B, KVH, G, q_chunk, Dh), dtype=jnp.float32)
        # only kv chunks that can be visible to this q chunk
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out  # (B, KVH, G, qc, Dh)

    outs = jax.lax.map(lambda qi: per_q_chunk(qi, qr[:, qi]), jnp.arange(nq))
    # (nq, B, KVH, G, qc, Dh) -> (B, S, H, Dh)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    return out.reshape(B, KVH * G, S, Dh).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, Dh)
    k_cache: jnp.ndarray,  # (B, C, KVH, Dh)
    v_cache: jnp.ndarray,  # (B, C, KVH, Dh)
    cache_pos: jnp.ndarray,  # (C,) absolute positions, -1 = empty slot
    cur_pos: jnp.ndarray,  # () current absolute position
    window: int = 0,
) -> jnp.ndarray:
    B, _, H, Dh = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / np.sqrt(Dh)
    qr = q.reshape(B, KVH, G, Dh)
    s = jnp.einsum("bhgd,bchd->bhgc", qr, k_cache).astype(jnp.float32) * scale
    valid = (cache_pos >= 0) & (cache_pos <= cur_pos)
    if window:
        valid &= cur_pos - cache_pos < window
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, Dh)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ params["wi"]
    if act in ("swiglu", "geglu"):
        g = x @ params["wg"]
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = h * gate
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ff")
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity + expert-parallel grouped GEMM
# ---------------------------------------------------------------------------
def _moe_dispatch_combine(xt, fe, ft, fg, wi, wg, wo, n_experts, cap, act_dtype):
    """Shared dispatch -> grouped GEMM -> combine on sorted (expert, token,
    gate) pair lists.  fe must be sorted ascending; fe == n_experts marks
    dropped/foreign pairs."""
    T, d = xt.shape
    pos_in_e = jnp.arange(len(fe)) - jnp.searchsorted(fe, fe, side="left")
    keep = (pos_in_e < cap) & (fe < n_experts)
    slot = jnp.where(keep, fe * cap + pos_in_e, n_experts * cap)
    buf = jnp.zeros((n_experts * cap + 1, d), act_dtype).at[slot].add(
        (xt[ft] * keep[:, None]).astype(act_dtype)
    )
    expert_in = buf[:-1].reshape(n_experts, cap, d)

    h = jnp.einsum("ecd,edf->ecf", expert_in, wi)
    g = jnp.einsum("ecd,edf->ecf", expert_in, wg)
    h = h * jax.nn.silu(g)
    expert_out = jnp.einsum("ecf,efd->ecd", h, wo)  # (E, cap, d)

    flat_out = expert_out.reshape(n_experts * cap, d)
    contrib = flat_out[jnp.minimum(slot, n_experts * cap - 1)] * (fg * keep)[:, None]
    return jnp.zeros((T, d), act_dtype).at[ft].add(contrib.astype(act_dtype))


def _sorted_pairs(gate_idx, gate_vals, T, K):
    flat_expert = gate_idx.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    return flat_expert[order], flat_token[order], flat_gate[order]


def moe_layer(
    params: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).

    Token-dropping capacity MoE with sort-based dispatch (no (T, E, C)
    one-hot tensor).  Two execution paths:

    - expert-parallel shard_map (default under a mesh with a 'model' axis
      that divides n_experts): tokens stay batch-sharded and replicated
      across the model axis; each model column selects the pairs routed to
      its local experts, runs the grouped GEMM, and the combine is one psum
      over 'model'.  This is the dispatch schedule the hypergraph comm
      planner models (monochrome-B coarsening = expert ownership).
    - plain GSPMD fallback (no mesh / indivisible): correct everywhere, but
      XLA materializes and reduces the global dispatch buffer — the measured
      naive baseline in EXPERIMENTS.md §Perf.
    """
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    xt = x.reshape(T, d)

    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros(E, probs.dtype).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * moe.router_aux_coef

    # expert placement permutation (hypergraph comm planner, beyond-paper):
    # decides which experts co-reside on a model column
    if moe.expert_placement is not None:
        perm = jnp.asarray(np.asarray(moe.expert_placement))
        gate_idx = perm[gate_idx]

    mesh = compat.get_abstract_mesh()
    ep_ok = (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and E % mesh.shape["model"] == 0
    )
    if ep_ok:
        out = _moe_ep(xt, gate_idx, gate_vals, params, cfg, mesh)
    else:
        cap = int(np.ceil(T * K / E * moe.capacity_factor))
        fe, ft, fg = _sorted_pairs(gate_idx, gate_vals, T, K)
        out = _moe_dispatch_combine(
            xt, fe, ft, fg, params["wi"], params["wg"], params["wo"], E, cap, xt.dtype
        )
    return out.reshape(B, S, d), aux


def _moe_ep(xt, gate_idx, gate_vals, params, cfg, mesh):
    """Expert-parallel dispatch via shard_map (see moe_layer docstring)."""
    from jax.sharding import PartitionSpec as P

    moe = cfg.moe
    T, d = xt.shape
    E, K = moe.n_experts, moe.top_k
    tp = mesh.shape["model"]
    E_loc = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    T_loc = T // n_batch if T % n_batch == 0 else T
    tok_spec = P(bspec, None) if T % n_batch == 0 else P(None, None)
    cap = int(np.ceil(max(T_loc, 1) * K / E * moe.capacity_factor))

    d_fsdp = (
        "data" in mesh.axis_names
        and d % mesh.shape["data"] == 0
        and mesh.shape["data"] > 1
    )
    wi_spec = P("model", "data" if d_fsdp else None, None)
    wo_spec = P("model", None, "data" if d_fsdp else None)

    def body(xt_loc, gi_loc, gv_loc, wi_loc, wg_loc, wo_loc):
        # weights at rest are FSDP-sharded on d; gather d before compute
        if d_fsdp:
            wi_full = jax.lax.all_gather(wi_loc, "data", axis=1, tiled=True)
            wg_full = jax.lax.all_gather(wg_loc, "data", axis=1, tiled=True)
            wo_full = jax.lax.all_gather(wo_loc, "data", axis=2, tiled=True)
        else:
            wi_full, wg_full, wo_full = wi_loc, wg_loc, wo_loc
        col = jax.lax.axis_index("model")
        local_e = gi_loc - col * E_loc
        mine = (local_e >= 0) & (local_e < E_loc)
        t_loc = xt_loc.shape[0]
        fe_all = jnp.where(mine, local_e, E_loc).reshape(-1)
        order = jnp.argsort(fe_all)
        fe = fe_all[order]
        ft = jnp.repeat(jnp.arange(t_loc), K)[order]
        fg = gv_loc.reshape(-1)[order]
        out = _moe_dispatch_combine(
            xt_loc, fe, ft, fg, wi_full, wg_full, wo_full, E_loc, cap, xt_loc.dtype
        )
        # combine across expert columns: one psum over 'model'
        out = jax.lax.psum(out.astype(jnp.float32), "model")
        return out.astype(xt_loc.dtype)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, wi_spec, wi_spec, wo_spec),
        out_specs=tok_spec,
    )(xt, gate_idx, gate_vals, params["wi"], params["wg"], params["wo"])


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------
def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, Di); w: (Kc, Di) depthwise causal conv, as a sum of shifted
    copies (Kc is tiny — 4)."""
    Kc = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(Kc):
        shift = Kc - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[i]
    return out


def mamba_scan(
    a: jnp.ndarray,  # (B, S, Di, N) decay = exp(dt * A)
    bx: jnp.ndarray,  # (B, S, Di, N) input contribution dt * B_t * x_t
    h0: jnp.ndarray,  # (B, Di, N)
    chunk: int = 256,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked linear recurrence h_t = a_t * h_{t-1} + bx_t.

    lax.scan over chunks (sequential carry), associative_scan within chunks
    (parallel): compile-friendly and TPU-parallel.  Returns (h_all, h_last).
    """
    B, S, Di, N = a.shape
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk
    ar = a.reshape(B, nc, chunk, Di, N)
    br = bx.reshape(B, nc, chunk, Di, N)

    def combine(u, v):
        (ua, ub), (va, vb) = u, v
        return ua * va, ub * va + vb

    def chunk_step(h, inp):
        ac, bc = inp  # (B, chunk, Di, N)
        pa, pb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = pa * h[:, None] + pb  # (B, chunk, Di, N)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(
        chunk_step, h0, (jnp.moveaxis(ar, 1, 0), jnp.moveaxis(br, 1, 0))
    )
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(B, S, Di, N)
    return h_all, h_last


def mamba_block_with_state(
    params: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    chunk: int = 256,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Mamba-1 block.  Returns (y, conv_tail (B, Kc-1, Di), h_last)."""
    xz = constrain(x @ params["in_proj"], "batch", "seq", "ssm_inner")
    z = x @ params["gate_proj"]  # (B, S, Di)
    xc = _causal_conv(xz, params["conv_w"])
    xc = constrain(jax.nn.silu(xc), "batch", "seq", "ssm_inner")
    # data-dependent SSM parameters
    bt = xc @ params["x_proj_b"]  # (B, S, N)
    ct = xc @ params["x_proj_c"]  # (B, S, N)
    dt = jax.nn.softplus(xc * params["dt_proj"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (Di, N)
    # the linear recurrence runs in fp32 (SSM stability + uniform scan dtypes)
    decay = jnp.exp(dt[..., None] * a)  # (B, S, Di, N) fp32
    bx = (dt * xc.astype(jnp.float32))[..., None] * bt.astype(jnp.float32)[
        ..., None, :
    ]
    h0 = jnp.zeros((x.shape[0], decay.shape[2], decay.shape[3]), jnp.float32)
    h_all, h_last = mamba_scan(decay, bx, h0, chunk=chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, ct.astype(jnp.float32)).astype(
        x.dtype
    ) + xc * params["d_skip"]
    y = y * jax.nn.silu(z)
    Kc = params["conv_w"].shape[0]
    conv_tail = xz[:, -(Kc - 1) :, :]
    return (y @ params["out_proj"]).astype(x.dtype), conv_tail, h_last


def mamba_block(params: dict, x: jnp.ndarray, cfg, chunk: int = 256) -> jnp.ndarray:
    y, _, _ = mamba_block_with_state(params, x, cfg, chunk=chunk)
    return y


def mamba_decode_step(
    params: dict,
    x: jnp.ndarray,  # (B, 1, d)
    conv_state: jnp.ndarray,  # (B, Kc-1, Di)
    h: jnp.ndarray,  # (B, Di, N)
    cfg,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token Mamba step with carried (conv_state, h)."""
    xz = x @ params["in_proj"]  # (B, 1, Di)
    z = x @ params["gate_proj"]
    w = params["conv_w"]  # (Kc, Di)
    Kc = w.shape[0]
    full = jnp.concatenate([conv_state, xz], axis=1)  # (B, Kc, Di)
    xc = jax.nn.silu((full * w[None]).sum(axis=1, keepdims=True))  # (B,1,Di)
    new_conv = full[:, 1:]
    bt = xc @ params["x_proj_b"]
    ct = xc @ params["x_proj_c"]
    dt = jax.nn.softplus(xc * params["dt_proj"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt[:, 0, :, None] * a)  # (B, Di, N) fp32
    bx = (dt * xc.astype(jnp.float32))[:, 0, :, None] * bt.astype(jnp.float32)[
        :, 0, None, :
    ]
    h_new = decay * h + bx  # h carried in fp32
    y = jnp.einsum("bdn,bn->bd", h_new, ct[:, 0].astype(jnp.float32)).astype(
        x.dtype
    )[:, None] + xc * params["d_skip"]
    y = y * jax.nn.silu(z)
    return (y @ params["out_proj"]).astype(x.dtype), new_conv, h_new
