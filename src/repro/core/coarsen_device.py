"""Device-side multilevel coarsening: clustering + contraction in jax.

This is the other half of ``partition(engine="device")`` (DESIGN.md §6).
``refine_device.py`` moved per-level refinement onto the device in PR 6 but
the V-cycle's *descend* — heavy-connectivity clustering and hypergraph
contraction — stayed host scipy and came to dominate the device profile.
This module keeps the whole descend on device: one jitted *clustering*
kernel proposes and grants weight-capped merges, and one jitted
*contraction* kernel rebuilds the coarse level's padded CSR arrays, so the
only per-level host traffic is two scalars (surviving vertex / pin counts,
needed to pick the next level's static shape buckets).

Design constraints are the same as the refinement kernel, plus one: XLA's
CPU backend has no fast scatter *or* argsort, so the usual "sort pins by
cluster id, unique, rebuild" contraction is out.  What works (measured):
cumsum ~0.6 ms and gathers ~0.1 ms per 112k pins, one value-only sort
~6 ms, one scatter ~5 ms.  The kernels are built around that budget:

- **Leader-based clustering, no similarity matrix.**  Each round every
  live cluster representative draws two incident nets (counter-based hash,
  no RNG state) and keeps the better score ``c(n)/(|n|-1)`` — the exact
  per-net term of the host's heavy-connectivity similarity; a
  two-choice sample replaces the row argmax.  The net's *anchor* (its
  first pin's vertex) is the merge target.  A per-round role hash splits
  vertices into proposers and acceptors, so merges are one-sided and
  deterministic; an anchor only accepts while it is itself an unabsorbed
  acceptor, which keeps cluster weights exact.
- **Weight-capped grants via segmented prefix sums.**  Proposals toward a
  net are granted in pin order while the anchor's running cluster weight
  stays under the cap: an inclusive prefix over the net-CSR gives each
  proposal's committed weight, a second prefix over the anchor's
  vertex-CSR orders its *nets*, and the statically-known inverse pin
  permutation transports the per-net budget back to pin slots.  No
  scatters, no sorts, exact in pin order — the device analogue of the
  host's sorted greedy grant loop.
- **Labels stay in the fine index space** during the rounds (pointer
  jumping resolves chains at the end), and contraction re-ranks the
  surviving representatives by a prefix sum.  Nets whose pins collapse
  into one cluster are *dead*: their pins are dropped and their cost
  zeroed (the device analogue of the host ``_coarsen`` singleton filter).
  Nets only ever shrink, so the finest level's big-net filter
  (``MAX_DEVICE_NET``, applied in ``_pad_level``) holds at every level.
- **Within-net duplicate pins are dropped, and contraction is
  scatter-free.**  The clustering kernel ends with one packed value sort
  (``coarse_pin * pin_bucket + slot``): surviving pins ordered by coarse
  vertex then slot, which makes same-net duplicates (two fine pins of one
  net landing in one cluster) adjacent, so a roll-compare mask removes
  them.  That dedup is what actually shrinks the pin count — and its
  shape bucket — down the hierarchy; without it ER-style instances keep
  finest-sized pin arrays at every level and the resident V-cycle loses
  to the host.  Contraction then compacts the sorted stream with
  cumsum-searchsorted selects (the vertex view falls out directly), pays
  one more pin-sized packed sort (``slot * vertex_bucket + coarse``) for
  the net view, and recovers both pin permutations by searchsorted into
  the streams — no scatter at all.  Exact coarse cluster weights come
  from a vertex-sized packed sort (duplicate coarse pins make the
  in-round running weights conservative, never under).

Compile-once bucketing, the LRU kernel cache and ``trace_count()`` follow
``refine_device.py`` exactly; zero retraces across same-bucket partitions.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import Hypergraph
from repro.core import refine_device as _rd
from repro.core.refine_device import _hash_u32

__all__ = [
    "CLUSTER_ROUNDS",
    "MAX_LEVELS",
    "DeviceLevel",
    "finest_level",
    "coarsen_level",
    "trace_count",
]

CLUSTER_ROUNDS = 5  # merge rounds per level (one jitted call)
MAX_LEVELS = 12  # hard stop on V-cycle depth
STALL_FRACTION = 0.8  # stop descending when a level keeps >= this many vertices
_INT31 = 1 << 31  # int32 packing bound for the vertex-CSR sort key


def _bucket_fine(x: int) -> int:
    """Coarse-level shape bucket: ceil to a 512 multiple instead of the
    finest level's ×1.5 geometric ladder.  Coarse shapes are deterministic
    per (instance, seed), so repeated partitions of the same hypergraph
    still hit the kernel caches — the wide ladder's cross-size reuse buys
    nothing below the finest level, while its padding (up to 50%) inflates
    the pin- and vertex-sized ops that dominate V-cycle wall time.  The
    quantum keeps waste under 1% at realistic coarse sizes and still caps
    the number of distinct compiled shapes per instance family."""
    return max(_rd._BUCKET_MIN, -(-x // 512) * 512)

# -- retrace accounting (same contract as refine_device.py) ------------------
_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times a coarsening kernel body has been traced.  Stable
    across repeated same-bucket partitions — the compile-once test hook."""
    return _TRACE_COUNT


def _mark_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


@dataclass
class DeviceLevel:
    """One V-cycle level resident on device: the 13-array padded layout of
    ``refine_device._pad_level`` (consumable by ``refine_args`` directly)
    plus the inverse pin permutation the clustering kernel needs."""

    nb: int  # vertex bucket (includes 1 phantom vertex)
    mb: int  # net bucket (kept constant down the hierarchy; dead nets empty)
    pb: int  # pin bucket
    n_vertices: int  # live vertices (unpadded)
    args: tuple  # (pin_nets, net_pins, cost, w, vptr, vnets, vperm,
    #              hi, lo, lz, vhi, vlo, vlz)
    vinv: object  # (pb,) vertex-order position of each net-order pin slot


def finest_level(hg: Hypergraph) -> DeviceLevel:
    """Wrap the (cached) finest padded view as the root device level.

    Padded with the tight quantizer, not the refiner's ×1.5 ladder: the
    finest level hosts the single most expensive kernels of the whole
    V-cycle (first cluster + contract), and at realistic sizes the ladder
    wastes 30–50% of every pin- and vertex-sized op there."""
    pl = _rd._pad_level(hg, bucket=_bucket_fine)
    return DeviceLevel(
        nb=pl.nb,
        mb=pl.mb,
        pb=pl.pb,
        n_vertices=hg.n_vertices,
        args=pl.args,
        vinv=pl.vinv,
    )


# -- clustering kernel --------------------------------------------------------
def _make_clusterer(nb: int, mb: int, pb: int, rounds: int):
    def _cluster(pin_nets, net_pins, cost, w, vptr, vnets, vperm, hi, lo,
                 lo_zero, vhi, vlo, vlo_zero, vinv, n_real, cap, salt):
        _mark_trace()  # Python body: executes at trace time only
        iota = jnp.arange(nb, dtype=jnp.int32)
        vids = jnp.arange(nb, dtype=jnp.uint32)
        vdeg = (vptr[1:] - vptr[:-1]).astype(jnp.uint32)
        net_lo = jnp.where(lo_zero, 0, lo + 1)  # per-net first pin slot
        ndeg = hi + 1 - net_lo
        alive = iota < n_real
        anchor = net_pins[net_lo]  # (mb,) each net's merge target vertex
        # the exact per-net term of the host similarity: c(n) / (|n| - 1)
        nscore = jnp.where(
            ndeg >= 2,
            cost / jnp.maximum(ndeg.astype(jnp.float32) - 1.0, 1.0),
            -1.0,
        )
        owner = net_pins[vperm]  # (pb,) vertex owning each vertex-CSR position
        is_lead = vperm == net_lo[vnets]  # j anchors net vnets[j]

        def body(r, carry):
            labels, cw = carry
            ri = jnp.uint32(r)
            root = labels == iota
            prop_role = (
                _hash_u32(vids, salt ^ (ri * jnp.uint32(0x9E3779B9))) & 1
            ) == 1
            # a net is open iff its anchor is a live, unabsorbed acceptor —
            # only then does "grant toward the anchor" have exact weights
            can_accept = alive & root & ~prop_role
            open_net = can_accept[anchor] & (ndeg >= 2)
            # proposers: two-choice sample among incident nets by score
            h1 = _hash_u32(vids, salt ^ (ri * jnp.uint32(0x85EBCA77)))
            h2 = _hash_u32(h1, salt ^ jnp.uint32(0xC2B2AE35))
            safe_deg = jnp.maximum(vdeg, 1)
            i1 = vptr[:nb] + (h1 % safe_deg).astype(jnp.int32)
            i2 = vptr[:nb] + (h2 % safe_deg).astype(jnp.int32)
            e1 = vnets[i1]
            e2 = vnets[i2]
            s1 = jnp.where(open_net[e1] & (anchor[e1] != iota), nscore[e1], -1.0)
            s2 = jnp.where(open_net[e2] & (anchor[e2] != iota), nscore[e2], -1.0)
            use2 = s2 > s1
            e = jnp.where(use2, e2, e1)
            jslot = vperm[jnp.where(use2, i2, i1)]  # v's own pin slot in e
            propose = (
                alive & root & prop_role & (vdeg > 0) & (jnp.maximum(s1, s2) > 0)
            )
            # net-side: each proposal rides its own pin; inclusive prefix =
            # weight committed up to and including it, in pin order
            via = propose[net_pins] & (e[net_pins] == pin_nets)
            wprop = jnp.where(via, cw[net_pins], 0.0)
            csn = jnp.cumsum(wprop)
            base = jnp.where(lo_zero, 0.0, csn[lo])
            tot = csn[hi] - base
            # anchor-side: an acceptor grants its nets in CSR order; the
            # budget already committed before net vnets[j] is its own weight
            # plus the totals of its earlier nets
            led_t = jnp.where(is_lead, tot[vnets], 0.0)
            csl = jnp.cumsum(led_t)
            base_v = jnp.where(vlo_zero[owner], 0.0, csl[vlo[owner]])
            start_v = cw[owner] + (csl - led_t) - base_v
            start_net = start_v[vinv][net_lo]  # transported to the net axis
            # the grant cutoff is monotone in csn, so granted pins are a
            # prefix of each net's via pins: one searchsorted per net replaces
            # two more pin-sized cumsums, and a proposer reads its own grant
            # decision straight off its pin slot (each vertex pins a net at
            # most once — duplicates are deduped between levels)
            cut = jnp.minimum(
                jnp.searchsorted(
                    csn, cap - start_net + base, side="right"
                ).astype(jnp.int32)
                - 1,
                hi,
            )
            g_raw = jnp.where(cut >= 0, csn[jnp.maximum(cut, 0)], 0.0)
            g_net = jnp.maximum(g_raw - base, 0.0)
            got = propose & (start_net[e] + (csn[jslot] - base[e]) <= cap)
            # anchors absorb the granted inflow
            led_g = jnp.where(is_lead, g_net[vnets], 0.0)
            csgl = jnp.cumsum(led_g)
            inflow = csgl[vhi] - jnp.where(vlo_zero, 0.0, csgl[vlo])
            return jnp.where(got, anchor[e], labels), cw + inflow

        labels, cw = jax.lax.fori_loop(
            0, rounds, body, (iota, w.astype(jnp.float32))
        )
        # chains grow by at most one link per round; jump to the roots
        for _ in range(max(2, int(rounds).bit_length())):
            labels = labels[labels]
        root = (labels == iota) & alive
        rank = jnp.cumsum(root.astype(jnp.int32)) - 1  # root -> coarse id
        n_alive = jnp.sum(root.astype(jnp.int32))
        coarse_pin = rank[labels][net_pins]  # (pb,) coarse pin ids
        # dead nets: every pin in one cluster (covers singleton and phantom
        # nets) — the device analogue of the host singleton filter
        diff = (coarse_pin != coarse_pin[net_lo][pin_nets]).astype(jnp.int32)
        csd = jnp.cumsum(diff)
        dead = (csd[hi] - jnp.where(lo_zero, 0, csd[lo])) == 0
        keep = ~dead[pin_nets]
        # the level's one packed sort orders surviving pins by
        # (coarse vertex, slot); within a group slots ascend, so pins of the
        # same net are adjacent and duplicates (two fine pins of one net
        # falling into one cluster) drop with an adjacent-equality mask —
        # this is what actually shrinks the pin count (and its bucket) down
        # the hierarchy.  Dropped/pad entries sort to the tail as INT32_MAX.
        slot = jnp.arange(pb, dtype=jnp.int32)
        sk = jnp.sort(
            jnp.where(keep, coarse_pin * pb + slot, jnp.int32(_INT31 - 1))
        )
        valid = sk != _INT31 - 1
        scp = sk // pb
        snet = pin_nets[sk % pb]
        dup = (
            valid
            & (jnp.arange(pb) > 0)
            & (scp == jnp.roll(scp, 1))
            & (snet == jnp.roll(snet, 1))
        )
        surv = valid & ~dup
        n_pins2 = jnp.sum(surv.astype(jnp.int32))
        return labels, rank, dead, sk, surv, n_alive, n_pins2

    return jax.jit(_cluster)


# -- contraction kernel -------------------------------------------------------
def _make_contractor(nb: int, mb: int, pb: int, nbb: int, pbb: int):
    def _contract(pin_nets, cost, w, labels, rank, dead, sk, surv,
                  n_real, n_pins2):
        _mark_trace()
        dd = jnp.arange(pbb, dtype=jnp.int32)
        # order-preserving select of the surviving sorted stream (prefix sum
        # + searchsorted): position j is already coarse-vertex order
        css = jnp.cumsum(surv.astype(jnp.int32))
        srcp = jnp.searchsorted(css, dd + 1, side="left").astype(jnp.int32)
        validj = dd < n_pins2
        skj = sk[jnp.where(validj, srcp, pb - 1)]
        sortv = jnp.where(validj, skj // pb, nbb - 1).astype(jnp.int32)
        oldslot = jnp.where(validj, skj % pb, pb - 1).astype(jnp.int32)
        vnets2 = jnp.where(validj, pin_nets[oldslot], mb - 1).astype(jnp.int32)
        vedges = jnp.searchsorted(
            sortv, jnp.arange(nbb + 1, dtype=jnp.int32), side="left"
        )
        vptr2 = vedges.astype(jnp.int32)
        vl, vr = vedges[:-1], vedges[1:]
        vempty = vl == vr
        vhi2 = jnp.where(vempty, pbb - 1, vr - 1).astype(jnp.int32)
        vlo2 = jnp.where(vempty, pbb - 1, vl - 1).astype(jnp.int32)
        vlz2 = jnp.where(vempty, False, vl == 0)
        # net view: the second pin-sized packed sort restores slot order
        # (slots unique -> nets ascend again), carrying the coarse id along
        key3 = jnp.where(
            validj, oldslot * nbb + sortv, jnp.int32(_INT31 - 1)
        )
        sk3 = jnp.sort(key3)
        validd = dd < n_pins2
        oslot = jnp.where(validd, sk3 // nbb, pb - 1).astype(jnp.int32)
        np2 = jnp.where(validd, sk3 % nbb, nbb - 1).astype(jnp.int32)
        pn2 = jnp.where(validd, pin_nets[oslot], mb - 1).astype(jnp.int32)
        edges = jnp.searchsorted(
            pn2, jnp.arange(mb + 1, dtype=jnp.int32), side="left"
        )
        left, right = edges[:-1], edges[1:]
        empty = left == right
        hi2 = jnp.where(empty, pbb - 1, right - 1).astype(jnp.int32)
        lo2 = jnp.where(empty, pbb - 1, left - 1).astype(jnp.int32)
        lz2 = jnp.where(empty, False, left == 0)
        cost2 = jnp.where(dead, 0.0, cost).astype(jnp.float32)
        # both permutations fall out of searchsorted into the two ascending
        # streams (slots are unique, so each query hits its own entry)
        vperm2 = jnp.clip(
            jnp.searchsorted(oslot, oldslot, side="left"), 0, pbb - 1
        ).astype(jnp.int32)
        selkey = jnp.where(validj, sortv * pb + oldslot, jnp.int32(_INT31 - 1))
        vinv2 = jnp.clip(
            jnp.searchsorted(selkey, np2 * pb + oslot, side="left"),
            0,
            pbb - 1,
        ).astype(jnp.int32)
        # exact coarse weights: group fine vertices by coarse id with a
        # vertex-sized packed sort (in-round cw is conservative, not exact,
        # when coarse nets carry duplicate pins); the driver guarantees
        # nbb * nb fits int32 (x64 stays off — compat.py contract)
        iota = jnp.arange(nb, dtype=jnp.int32)
        cmap = jnp.where(iota < n_real, rank[labels], nbb - 1)
        kv = cmap * nb + iota
        skv = jnp.sort(kv)
        csw = jnp.cumsum(w[skv % nb])
        scv = skv // nb
        wedges = jnp.searchsorted(
            scv, jnp.arange(nbb + 1, dtype=jnp.int32), side="left"
        )
        wl, wr = wedges[:-1], wedges[1:]
        seg = jnp.where(
            wr > wl,
            csw[jnp.maximum(wr - 1, 0)] - jnp.where(wl > 0, csw[wl - 1], 0.0),
            0.0,
        )
        w2 = jnp.where(
            jnp.arange(nbb, dtype=jnp.int32) == nbb - 1, 0.0, seg
        ).astype(jnp.float32)
        return (pn2, np2, cost2, w2, vptr2, vnets2, vperm2, hi2, lo2, lz2,
                vhi2, vlo2, vlz2, vinv2, cmap)

    return jax.jit(_contract)


_CLUSTERERS: OrderedDict[tuple, object] = OrderedDict()
_CONTRACTORS: OrderedDict[tuple, object] = OrderedDict()


def _get_cached(cache: OrderedDict, key: tuple, make):
    fn = cache.get(key)
    if fn is None:
        fn = make()
        cache[key] = fn
        while len(cache) > _rd.CACHE_SIZE:
            cache.popitem(last=False)
    else:
        cache.move_to_end(key)
    return fn


# -- public entry point -------------------------------------------------------
def coarsen_level(
    level: DeviceLevel, cluster_cap: float, seed: int, index: int
) -> tuple[DeviceLevel, object, int] | None:
    """Coarsen one level on device.  Returns ``(coarse_level, cmap,
    n_coarse)`` where ``cmap`` is a device ``(nb,)`` map from this level's
    padded vertex ids to the coarse level's (so ``batch[:, cmap]`` is the
    uncoarsening expansion), or ``None`` when clustering stalled or the
    coarse shapes would overflow the int32 sort-key packing — the driver
    then stops descending (or falls back to host coarsening entirely)."""
    nb, mb, pb = level.nb, level.mb, level.pb
    if nb * pb >= _INT31 - 1:  # the clustering tail's packed sort key
        return None
    fn = _get_cached(
        _CLUSTERERS,
        (nb, mb, pb, CLUSTER_ROUNDS),
        lambda: _make_clusterer(nb, mb, pb, CLUSTER_ROUNDS),
    )
    salt = np.uint32(
        ((seed * 0x9E3779B9) ^ ((index + 1) * 0x85EBCA77)) & 0xFFFFFFFF
    )
    labels, rank, dead, sk, surv, n_alive, n_pins2 = fn(
        *level.args,
        level.vinv,
        jnp.int32(level.n_vertices),
        jnp.float32(cluster_cap),
        salt,
    )
    n_alive = int(n_alive)
    n_pins2 = int(n_pins2)
    if n_alive >= level.n_vertices * STALL_FRACTION:
        return None
    nbb = _bucket_fine(n_alive + 1)
    pbb = _bucket_fine(max(n_pins2, 1))
    if nbb * pb >= _INT31 - 1 or nbb * nb >= _INT31:
        return None
    cfn = _get_cached(
        _CONTRACTORS,
        (nb, mb, pb, nbb, pbb),
        lambda: _make_contractor(nb, mb, pb, nbb, pbb),
    )
    out = cfn(
        level.args[0],
        level.args[2],
        level.args[3],
        labels,
        rank,
        dead,
        sk,
        surv,
        jnp.int32(level.n_vertices),
        jnp.int32(n_pins2),
    )
    args2, vinv2, cmap = tuple(out[:13]), out[13], out[14]
    coarse = DeviceLevel(
        nb=nbb, mb=mb, pb=pbb, n_vertices=n_alive, args=args2, vinv=vinv2
    )
    return coarse, cmap, n_alive
