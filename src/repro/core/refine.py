"""Flat-CSR refinement engine for the multilevel partitioner (DESIGN.md §6).

Everything here operates on the Hypergraph's flat CSR arrays (``net_ptr`` /
``net_pins`` and the cached vertex→nets transpose): a move touches only
index arithmetic over those arrays — no per-net Python list building inside
the move loops.  Three pieces:

- ``fm_refine``: boundary FM bisection refinement.  Best-move selection is
  O(1) amortized through gain buckets (one list of candidates per distinct
  integer gain + a lazy max-key heap); delta-gain updates are O(deg) flat
  gathers with stale bucket entries invalidated on pop.  The (net, side)
  pin-count table is maintained incrementally across moves, rollbacks and
  passes instead of being recomputed per pass.
- ``initial_bisect``: vectorized frontier growth — whole BFS levels at a
  time with a weight-prefix cut inside the level that crosses the target.
- ``kway_refine``: direct K-way greedy boundary label propagation over all
  p parts, run after recursive bisection.  Every applied move is
  re-validated against the current pin counts, so each one strictly
  decreases sum_n c(n)·(lambda(n)-1) and respects the Def. 4.4 balance cap:
  the pass is monotone in both objective and feasibility.

``fm_refine`` is behaviour-compatible with the retained executable
specification ``partition._fm_refine_loop`` (same gain rules 1–4, same
BIG_NET / DEG_CAP screens, per-pass rollback to the best prefix); it is not
move-for-move identical — the bucket order visits candidates differently —
so the engine is gated on measured connectivity, not byte equality
(tests/test_partition_invariants.py).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.hypergraph import Hypergraph

BIG_NET = 96  # pins; nets above this are skipped in clustering/gain updates
DEG_CAP = 2500  # vertices in more nets than this are not FM move candidates
MAX_PASSES = 2
STALL_MOVES = 100  # hill-descent cutoff: stop after this many non-improving moves


def gather_pins(
    net_ptr: np.ndarray, net_pins: np.ndarray, nets: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Concatenated pins of ``nets`` as one flat gather (CSR index
    arithmetic, no Python per-net loop).  Returns (pins, per_net_counts)."""
    rep = net_ptr[nets + 1] - net_ptr[nets]
    total = int(rep.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), rep
    off = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(rep) - rep, rep)
    pins = net_pins[np.repeat(net_ptr[nets], rep) + off]
    return pins, rep


def compute_counts(hg: Hypergraph, side: np.ndarray) -> np.ndarray:
    """(n_nets, 2) per-side pin counts (one bincount over the pin list)."""
    cnt = np.empty((hg.n_nets, 2), dtype=np.int64)
    cnt[:, 1] = np.bincount(
        hg.pin_nets(), weights=side[hg.net_pins], minlength=hg.n_nets
    )
    cnt[:, 0] = hg.net_sizes() - cnt[:, 1]
    return cnt


def gains_for_all(hg: Hypergraph, side: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    """Vectorized FM gains for all vertices via two fused sparse matvecs:
    gain(v) = sum_{n in v} c(n)[cnt(n, side(v)) == 1] - c(n)[cnt(n, other) == 0]."""
    inc = hg.incidence()
    cost = hg.net_cost
    # per-net gain contribution, assuming the vertex sits on side 0 resp. 1
    as0 = inc.T @ (cost * ((cnt[:, 0] == 1).astype(np.int64) - (cnt[:, 1] == 0)))
    as1 = inc.T @ (cost * ((cnt[:, 1] == 1).astype(np.int64) - (cnt[:, 0] == 0)))
    return np.where(side.astype(bool), as1, as0).astype(np.int64)


def fm_refine(
    hg: Hypergraph,
    side: np.ndarray,
    max_w: tuple[float, float],
    max_passes: int = MAX_PASSES,
    cand_cap: int = 1200,
) -> np.ndarray:
    """Boundary FM with gain buckets and an incrementally maintained count
    table.

    Pass setup (counts, gains, boundary detection) is vectorized; the move
    loop itself runs over flat pre-sliced adjacency lists so a move costs
    O(deg) scalar work with no numpy-call overhead.  Gain-increase updates
    push eagerly; decreases are re-keyed lazily when the stale bucket entry
    surfaces.  Deterministic: ties break by bucket LIFO order, which is
    fixed by the candidate enumeration order."""
    n = hg.n_vertices
    if n == 0 or hg.n_nets == 0:
        return side.astype(np.int8)
    vptr, vnets = hg.vertex_to_nets()
    net_ptr = hg.net_ptr
    net_pins = hg.net_pins
    small = hg.net_sizes() <= BIG_NET
    wf = hg.w_comp.astype(np.float64)
    side = side.astype(np.int8).copy()
    cnt = compute_counts(hg, side)
    deg = np.diff(vptr)
    pin_nets = hg.pin_nets()

    # flat adjacency as plain lists, sliced lazily per touched vertex/net
    vl = vnets.tolist()
    vp = vptr.tolist()
    pl = net_pins.tolist()
    npt = net_ptr.tolist()
    small_l = small.tolist()
    cost_l = hg.net_cost.tolist()
    wf_l = wf.tolist()
    cnt0 = cnt[:, 0].tolist()
    cnt1 = cnt[:, 1].tolist()
    side_l = side.tolist()
    side_w = [float(wf[side == 0].sum()), float(wf[side == 1].sum())]
    caps = (float(max_w[0]), float(max_w[1]))

    for _pass in range(max_passes):
        cnt = np.stack(
            [np.asarray(cnt0, dtype=np.int64), np.asarray(cnt1, dtype=np.int64)], axis=1
        )
        side = np.asarray(side_l, dtype=np.int8)
        cut = (cnt[:, 0] > 0) & (cnt[:, 1] > 0)
        if not cut.any():
            break
        boundary = np.zeros(n, dtype=bool)
        boundary[net_pins[cut[pin_nets]]] = True
        cand = np.flatnonzero(boundary & (deg <= DEG_CAP))
        if len(cand) == 0:
            break
        gains = gains_for_all(hg, side, cnt)
        if len(cand) > cand_cap:
            top = np.argsort(-gains[cand], kind="stable")[:cand_cap]
            cand = cand[top]
        g_l = gains.tolist()
        in_cand = bytearray(n)
        locked = bytearray(n)
        for u in cand.tolist():
            in_cand[u] = 1

        # gain buckets: candidates listed per distinct integer gain, plus a
        # lazy max-key heap over bucket keys.  push is O(1); pop-max is O(1)
        # amortized (stale keys and entries are discarded lazily on pop).
        buckets: dict[int, list[int]] = {}
        keyheap: list[int] = []
        heappush, heappop = heapq.heappush, heapq.heappop

        def push(u: int, gu: int) -> None:
            b = buckets.get(gu)
            if b is None:
                buckets[gu] = [u]
                heappush(keyheap, -gu)
            else:
                b.append(u)

        for u in cand.tolist():
            push(u, g_l[u])
        deferred: tuple[list[int], list[int]] = ([], [])
        low_water = [float("inf"), float("inf")]

        history: list[int] = []
        cum = best_cum = 0
        best_idx = -1
        while True:
            # --- O(1) amortized best feasible move ---------------------
            v = -1
            while keyheap:
                key = -keyheap[0]
                b = buckets.get(key)
                if not b:
                    heappop(keyheap)
                    if b is not None:
                        del buckets[key]
                    continue
                u = b.pop()
                if locked[u]:
                    continue
                gu = g_l[u]
                if gu != key:
                    if gu < key:
                        push(u, gu)  # lazily re-key a decreased gain
                    continue  # an eager push already covers increases
                t = 1 - side_l[u]
                if side_w[t] + wf_l[u] > caps[t]:
                    # parked until side t has strictly more headroom than
                    # at any deferral since the last flush
                    deferred[t].append(u)
                    if side_w[t] < low_water[t]:
                        low_water[t] = side_w[t]
                    continue
                v = u
                break
            if v < 0:
                break
            s = side_l[v]
            t = 1 - s
            # --- apply move: O(deg) flat scalar delta-gain updates -----
            src, dst = (cnt0, cnt1) if s == 0 else (cnt1, cnt0)
            for nid in vl[vp[v] : vp[v + 1]]:
                cs = src[nid]
                ct = dst[nid]
                src[nid] = cs - 1
                dst[nid] = ct + 1
                if not small_l[nid]:
                    continue
                c = cost_l[nid]
                # rule 1: t-count was 0 -> every other pin gains +c
                # rule 2: t-count was 1 -> the lone t-side pin gains -c
                # rule 3: s-count now 0 -> every other pin gains -c
                # rule 4: s-count now 1 -> the lone s-side pin gains +c
                d_all = (c if ct == 0 else 0) - (c if cs == 1 else 0)
                d_s = c if cs == 2 else 0
                d_t = -c if ct == 1 else 0
                if d_all or d_s or d_t:
                    for u in pl[npt[nid] : npt[nid + 1]]:
                        if u == v or locked[u] or not in_cand[u]:
                            continue
                        d = d_all + (d_s if side_l[u] == s else d_t)
                        if d:
                            gu = g_l[u] + d
                            g_l[u] = gu
                            if d > 0:
                                push(u, gu)
            side_l[v] = t
            side_w[s] -= wf_l[v]
            side_w[t] += wf_l[v]
            locked[v] = 1
            if deferred[s] and side_w[s] < low_water[s]:
                for u in deferred[s]:
                    push(u, g_l[u])
                deferred[s].clear()
                low_water[s] = float("inf")
            history.append(v)
            cum += key
            if cum > best_cum:
                best_cum, best_idx = cum, len(history) - 1
            elif key < 0 and len(history) - 1 - best_idx > STALL_MOVES:
                break
        # --- rollback to best prefix, keeping counts consistent --------
        for v in reversed(history[best_idx + 1 :]):
            t = side_l[v]
            s = 1 - t
            src, dst = (cnt0, cnt1) if t == 0 else (cnt1, cnt0)
            for nid in vl[vp[v] : vp[v + 1]]:
                src[nid] -= 1
                dst[nid] += 1
            side_l[v] = s
            side_w[t] -= wf_l[v]
            side_w[s] += wf_l[v]
        if best_cum <= 0:
            break
    return np.asarray(side_l, dtype=np.int8)


def initial_bisect(
    hg: Hypergraph,
    target0: float,
    rng: np.random.Generator,
    min0: float = 0.0,
) -> np.ndarray:
    """Greedy net-BFS growth of side 0 up to ~``target0`` compute weight,
    one whole frontier level per step (vectorized).  The level that crosses
    the target is cut at the weight prefix.

    ``min0`` is the feasibility floor: below it heavy crossing vertices are
    taken even past the 5% slack, so side 1 (which gets the complement)
    cannot be left over its balance cap by an under-grown side 0."""
    n = hg.n_vertices
    side = np.ones(n, dtype=np.int8)
    if n == 0 or target0 <= 0:
        return side
    vptr, vnets = hg.vertex_to_nets()
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    w = hg.w_comp.astype(np.float64)
    seen = np.zeros(n, dtype=bool)
    net_seen = np.zeros(hg.n_nets, dtype=bool)
    frontier = np.array([int(rng.integers(n))], dtype=np.int64)
    seen[frontier] = True
    total0 = 0.0
    while total0 < target0:
        if len(frontier) == 0:
            rest = np.flatnonzero(~seen)
            if len(rest) == 0:
                break
            frontier = np.array([int(rest[rng.integers(len(rest))])], dtype=np.int64)
            seen[frontier] = True
        cw = np.cumsum(w[frontier])
        k = int(np.searchsorted(cw, target0 - total0, side="right"))
        if k:
            side[frontier[:k]] = 0
            total0 += float(cw[k - 1])
        if k < len(frontier):
            # crossing vertex: take it only within the 5% slack (matching
            # the loop reference) — or unconditionally while still under
            # the feasibility floor — then keep scanning the level
            v0 = int(frontier[k])
            if (
                total0 == 0.0
                or total0 < min0
                or total0 + w[v0] <= target0 * 1.05
            ):
                side[v0] = 0
                total0 += w[v0]
            frontier = frontier[k + 1 :]
            continue
        # level exhausted below target: expand unvisited nets, unseen pins
        nets, _ = gather_pins(vptr, vnets, frontier)
        nets = nets[~net_seen[nets]]
        if len(nets):
            nets = np.unique(nets)
            net_seen[nets] = True
        pins, _ = gather_pins(net_ptr, net_pins, nets)
        pins = pins[~seen[pins]]
        pins = np.unique(pins)
        seen[pins] = True
        frontier = pins
    return side


def kway_refine(
    hg: Hypergraph,
    parts: np.ndarray,
    p: int,
    part_cap: float,
    max_rounds: int = 5,
    dense_cell_cap: int = 25_000_000,
) -> np.ndarray:
    """Direct K-way refinement: greedy boundary label propagation over all
    p parts minimizing sum_n c(n)·(lambda(n)-1) under the Def. 4.4 cap.

    Each round scores every vertex's best target part with two vectorized
    passes (leave-gain via a bincount over pins, arrival penalty via one
    sparse·dense matvec), then applies candidate moves in descending-gain
    order, re-validating each against the live count table — so applied
    moves are individually improving and balance-feasible.

    When the dense (n_nets, p) count table would exceed ``dense_cell_cap``
    cells (paper-scale fine models at large p), refinement switches to
    ``_kway_refine_restricted``, which tracks only the round's cut nets and
    scores only boundary vertices — exact at round start and conservative
    within a round, so monotonicity still holds.
    """
    if p <= 1 or hg.n_nets == 0 or hg.n_vertices == 0 or hg.n_pins == 0:
        return parts
    if hg.n_nets * p > dense_cell_cap:
        return _kway_refine_restricted(hg, parts, p, part_cap, max_rounds)
    parts = parts.astype(np.int64).copy()
    n = hg.n_vertices
    net_pins = hg.net_pins
    pin_nets = hg.pin_nets()
    vptr, vnets = hg.vertex_to_nets()
    cost = hg.net_cost
    wf = hg.w_comp.astype(np.float64)
    part_w = np.bincount(parts, weights=wf, minlength=p)
    # int32 counts / float32 costs keep the dense table and each round's
    # arrival temp at 4 bytes per cell near the dense_cell_cap boundary
    cnt = (
        np.bincount(pin_nets * p + parts[net_pins], minlength=hg.n_nets * p)
        .reshape(hg.n_nets, p)
        .astype(np.int32)
    )
    cost32 = cost.astype(np.float32)
    inc = hg.incidence()
    rows = np.arange(n)
    first_improved = None
    # flat scalar mirrors for the apply loop (kept in sync with cnt/parts)
    cnt_l = cnt.tolist()
    parts_l = parts.tolist()
    part_w_l = part_w.tolist()
    wf_l = wf.tolist()
    cost_l = cost.tolist()
    vl = vnets.tolist()
    vp = vptr.tolist()
    for _round in range(max_rounds):
        at_own = cnt[pin_nets, parts[net_pins]]
        g_leave = np.bincount(
            net_pins, weights=cost[pin_nets] * (at_own == 1), minlength=n
        )
        arrive = inc.T @ (cost32[:, None] * (cnt == 0))  # (n, p) float32
        gain = g_leave.astype(np.float32)[:, None] - arrive
        gain[part_w[None, :] + wf[:, None] > part_cap] = -np.inf
        gain[rows, parts] = -np.inf
        best_t = np.argmax(gain, axis=1)
        best_g = gain[rows, best_t]
        movers = np.flatnonzero(best_g > 0)
        # drain mode: vertices of parts over the cap may move at zero or
        # negative gain (least damage first) until their part fits again —
        # this restores eps-feasibility lost to lumpy coarse vertices
        over = part_w > part_cap
        if over.any():
            drains = np.flatnonzero(
                over[parts] & np.isfinite(best_g) & (best_g <= 0)
            )
            movers = np.concatenate([movers, drains])
        if len(movers) == 0:
            break
        order = movers[np.argsort(-best_g[movers], kind="stable")]
        improved = 0
        applied: list[int] = []
        applied_s: list[int] = []
        applied_t: list[int] = []
        for v, t in zip(order.tolist(), best_t[order].tolist()):
            s = parts_l[v]
            wv = wf_l[v]
            if part_w_l[t] + wv > part_cap:
                continue
            nets = vl[vp[v] : vp[v + 1]]
            g_exact = 0
            for nid in nets:  # re-validate against the live count table
                row = cnt_l[nid]
                if row[s] == 1:
                    g_exact += cost_l[nid]
                if row[t] == 0:
                    g_exact -= cost_l[nid]
            if g_exact <= 0 and part_w_l[s] <= part_cap:
                continue  # negative-gain moves only drain overfull parts
            for nid in nets:
                row = cnt_l[nid]
                row[s] -= 1
                row[t] += 1
            parts_l[v] = t
            part_w_l[s] -= wv
            part_w_l[t] += wv
            improved += g_exact
            applied.append(v)
            applied_s.append(s)
            applied_t.append(t)
        if not applied:
            break
        if first_improved is None:
            first_improved = max(improved, 1)
        # resync the numpy mirrors from the applied-move log (vectorized)
        mv = np.array(applied, dtype=np.int64)
        mv_t = np.array(applied_t, dtype=np.int64)
        parts[mv] = mv_t
        nets_cat, rep = gather_pins(vptr, vnets, mv)
        # flat bincount deltas instead of np.add.at: add.at is numpy's
        # slowest scatter idiom (unbuffered per-element dispatch), while one
        # bincount over linearized (net, part) indices is a single C pass
        flat = nets_cat * p
        dec = np.bincount(
            flat + np.repeat(np.array(applied_s, dtype=np.int64), rep),
            minlength=hg.n_nets * p,
        )
        inc2 = np.bincount(flat + np.repeat(mv_t, rep), minlength=hg.n_nets * p)
        cnt += (inc2 - dec).reshape(hg.n_nets, p).astype(np.int32)
        part_w = np.asarray(part_w_l)
        if improved < 0.05 * first_improved and not (part_w > part_cap).any():
            break  # converged: late rounds buy <5% of the first round's gain
    return parts


def _kway_refine_restricted(
    hg: Hypergraph,
    parts: np.ndarray,
    p: int,
    part_cap: float,
    max_rounds: int,
) -> np.ndarray:
    """K-way refinement for instances where the dense (n_nets, p) table
    would not fit: per round, only the currently *cut* nets get a count
    table and only boundary vertices are scored.

    A vertex's untracked nets were internal to its own part at round start,
    so they contribute no leave-gain and a flat arrival penalty of their
    summed cost — exact at round start.  Within a round the untracked terms
    can only underestimate a move's true gain (another mover may have made
    the net cut, or populated the target side), so every applied
    positive-gain move is still a true improvement: monotone, like the
    dense mode.  Drains (negative-gain moves out of over-cap parts) only
    consider boundary vertices here.
    """
    import scipy.sparse as sp

    parts = parts.astype(np.int64).copy()
    n = hg.n_vertices
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    pin_nets = hg.pin_nets()
    vptr, vnets = hg.vertex_to_nets()
    cost = hg.net_cost
    wf = hg.w_comp.astype(np.float64)
    part_w = np.bincount(parts, weights=wf, minlength=p)
    s_all = np.asarray(hg.incidence().T @ cost).ravel()  # static incident cost
    vl = vnets.tolist()
    vp = vptr.tolist()
    wf_l = wf.tolist()
    cost_l = cost.tolist()
    first_improved = None
    seg = np.minimum(net_ptr[:-1], max(hg.n_pins - 1, 0))  # guard empty nets
    for _round in range(max_rounds):
        pin_parts = parts[net_pins]
        cut = np.maximum.reduceat(pin_parts, seg) != np.minimum.reduceat(
            pin_parts, seg
        )
        cut_ids = np.flatnonzero(cut)
        m = len(cut_ids)
        if m == 0:
            break
        tid = np.full(hg.n_nets, -1, dtype=np.int64)
        tid[cut_ids] = np.arange(m)
        tmask = cut[pin_nets]
        t_pins = net_pins[tmask]
        t_nets = tid[pin_nets[tmask]]
        cost_cut = cost[cut_ids]
        cnt = np.bincount(t_nets * p + parts[t_pins], minlength=m * p).reshape(m, p)
        bnd = np.unique(t_pins)
        posB = np.full(n, -1, dtype=np.int64)
        posB[bnd] = np.arange(len(bnd))
        at_own = cnt[t_nets, parts[t_pins]]
        g_leave = np.bincount(
            t_pins, weights=cost[pin_nets[tmask]] * (at_own == 1), minlength=n
        )[bnd]
        incB = sp.csr_matrix(
            (np.ones(len(t_pins), dtype=np.int8), (posB[t_pins], t_nets)),
            shape=(len(bnd), m),
        )
        arrive = incB @ (cost_cut[:, None] * (cnt == 0))
        pen_int = s_all[bnd] - incB @ cost_cut  # untracked = internal nets
        gain = g_leave[:, None] - arrive - pen_int[:, None]
        wb = wf[bnd]
        gain[part_w[None, :] + wb[:, None] > part_cap] = -np.inf
        brows = np.arange(len(bnd))
        gain[brows, parts[bnd]] = -np.inf
        best_t = np.argmax(gain, axis=1)
        best_g = gain[brows, best_t]
        movers = np.flatnonzero(best_g > 0)
        over = part_w > part_cap
        if over.any():
            drains = np.flatnonzero(
                over[parts[bnd]] & np.isfinite(best_g) & (best_g <= 0)
            )
            movers = np.concatenate([movers, drains])
        if len(movers) == 0:
            break
        order = movers[np.argsort(-best_g[movers], kind="stable")]
        tid_l = tid.tolist()
        cnt_l = cnt.tolist()
        parts_l: dict[int, int] = {}  # only moved vertices change
        part_w_l = part_w.tolist()
        improved = 0
        applied: list[int] = []
        applied_t: list[int] = []
        for b, t in zip(bnd[order].tolist(), best_t[order].tolist()):
            v = b
            s = parts_l.get(v, -1)
            if s < 0:
                s = int(parts[v])
            if s == t:
                continue
            wv = wf_l[v]
            if part_w_l[t] + wv > part_cap:
                continue
            nets = vl[vp[v] : vp[v + 1]]
            g_exact = 0
            for nid in nets:
                k = tid_l[nid]
                if k >= 0:
                    row = cnt_l[k]
                    if row[s] == 1:
                        g_exact += cost_l[nid]
                    if row[t] == 0:
                        g_exact -= cost_l[nid]
                else:
                    # untracked: internal to s at round start — no leave
                    # gain, conservative arrival penalty
                    g_exact -= cost_l[nid]
            if g_exact <= 0 and part_w_l[s] <= part_cap:
                continue
            for nid in nets:
                k = tid_l[nid]
                if k >= 0:
                    row = cnt_l[k]
                    row[s] -= 1
                    row[t] += 1
            parts_l[v] = t
            part_w_l[s] -= wv
            part_w_l[t] += wv
            improved += g_exact
            applied.append(v)
            applied_t.append(t)
        if not applied:
            break
        if first_improved is None:
            first_improved = max(improved, 1)
        parts[np.array(applied, dtype=np.int64)] = np.array(applied_t, dtype=np.int64)
        part_w = np.asarray(part_w_l)
        if improved < 0.05 * first_improved and not (part_w > part_cap).any():
            break
    return parts
