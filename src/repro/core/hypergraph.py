"""Hypergraph container (Sec. 3.1 terminology).

Vertices carry vector weights (w_comp, w_mem); nets carry costs.  Pins are
stored CSR-by-net; the transposed vertex->net CSR is built lazily.  All arrays
are numpy; partitioning and cost evaluation operate on these directly.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass
class Hypergraph:
    n_vertices: int
    net_ptr: np.ndarray  # (n_nets + 1,) int64
    net_pins: np.ndarray  # (n_pins,) int64 vertex ids, per net
    w_comp: np.ndarray  # (n_vertices,) int64
    w_mem: np.ndarray  # (n_vertices,) int64
    net_cost: np.ndarray  # (n_nets,) int64
    # optional metadata for interpreting vertices/nets (builders fill these)
    vertex_kind: np.ndarray | None = None  # int8: 0=mult, 1=A, 2=B, 3=C
    net_kind: np.ndarray | None = None  # int8: 1=A, 2=B, 3=C
    name: str = ""

    _vtx_ptr: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _vtx_nets: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _pin_nets: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _inc: "sp.csr_matrix | None" = dataclasses.field(default=None, repr=False)

    # -- properties --------------------------------------------------------
    @property
    def n_nets(self) -> int:
        return len(self.net_ptr) - 1

    @property
    def n_pins(self) -> int:
        return len(self.net_pins)

    def net_sizes(self) -> np.ndarray:
        return np.diff(self.net_ptr)

    def pins_of(self, net: int) -> np.ndarray:
        return self.net_pins[self.net_ptr[net] : self.net_ptr[net + 1]]

    # -- derived structures --------------------------------------------------
    def incidence(self) -> sp.csr_matrix:
        """(n_nets x n_vertices) 0/1 incidence matrix (Fig. 4); cached."""
        if self._inc is None:
            indptr = self.net_ptr.astype(np.int64)
            data = np.ones(self.n_pins, dtype=np.int8)
            self._inc = sp.csr_matrix(
                (data, self.net_pins, indptr), shape=(self.n_nets, self.n_vertices)
            )
        return self._inc

    def pin_nets(self) -> np.ndarray:
        """(n_pins,) net id of each pin entry — the expansion
        ``repeat(arange(n_nets), net_sizes())``, cached because every
        vectorized sweep over the pin list starts from it."""
        if self._pin_nets is None:
            self._pin_nets = np.repeat(
                np.arange(self.n_nets, dtype=np.int64), self.net_sizes()
            )
        return self._pin_nets

    def vertex_to_nets(self) -> tuple[np.ndarray, np.ndarray]:
        """CSR of nets incident to each vertex (built lazily, cached).
        Pure index arithmetic: one stable argsort of the pin list by vertex
        plus a bincount — no scipy transpose."""
        if self._vtx_ptr is None:
            order = np.argsort(self.net_pins, kind="stable")
            self._vtx_nets = self.pin_nets()[order]
            counts = np.bincount(self.net_pins, minlength=self.n_vertices)
            self._vtx_ptr = np.concatenate(
                [[0], np.cumsum(counts)]
            ).astype(np.int64)
        return self._vtx_ptr, self._vtx_nets

    def nets_of(self, vertex: int) -> np.ndarray:
        ptr, nets = self.vertex_to_nets()
        return nets[ptr[vertex] : ptr[vertex + 1]]

    # -- sanity -------------------------------------------------------------
    def validate(self) -> None:
        assert self.net_ptr[0] == 0 and self.net_ptr[-1] == self.n_pins
        assert (np.diff(self.net_ptr) >= 0).all()
        if self.n_pins:
            assert self.net_pins.min() >= 0
            assert self.net_pins.max() < self.n_vertices
        assert len(self.w_comp) == len(self.w_mem) == self.n_vertices
        assert len(self.net_cost) == self.n_nets

    def total_comp(self) -> int:
        return int(self.w_comp.sum())

    def total_mem(self) -> int:
        return int(self.w_mem.sum())

    def __repr__(self) -> str:  # compact, used in benchmark CSV "derived"
        return (
            f"Hypergraph({self.name!r}, V={self.n_vertices}, N={self.n_nets}, "
            f"pins={self.n_pins}, comp={self.total_comp()})"
        )


def build_hypergraph(
    nets: list[np.ndarray],
    n_vertices: int,
    w_comp: np.ndarray,
    w_mem: np.ndarray,
    net_cost: np.ndarray,
    **meta,
) -> Hypergraph:
    """Assemble from a list of per-net pin arrays."""
    sizes = np.array([len(n) for n in nets], dtype=np.int64)
    net_ptr = np.concatenate([[0], np.cumsum(sizes)])
    net_pins = (
        np.concatenate(nets).astype(np.int64)
        if nets
        else np.empty(0, dtype=np.int64)
    )
    hg = Hypergraph(
        n_vertices=n_vertices,
        net_ptr=net_ptr,
        net_pins=net_pins,
        w_comp=np.asarray(w_comp, dtype=np.int64),
        w_mem=np.asarray(w_mem, dtype=np.int64),
        net_cost=np.asarray(net_cost, dtype=np.int64),
        **meta,
    )
    hg.validate()
    return hg


def build_hypergraph_flat(
    net_ids: np.ndarray,
    pin_vertices: np.ndarray,
    n_nets: int,
    n_vertices: int,
    w_comp: np.ndarray,
    w_mem: np.ndarray,
    net_cost: np.ndarray,
    **meta,
) -> Hypergraph:
    """Assemble from flat (net_id, vertex) pin pairs — vectorized path used
    by the SpGEMM model builders."""
    net_ids = np.asarray(net_ids, dtype=np.int64)
    pin_vertices = np.asarray(pin_vertices, dtype=np.int64)
    order = np.argsort(net_ids, kind="stable")
    net_ids = net_ids[order]
    pins = pin_vertices[order]
    counts = np.bincount(net_ids, minlength=n_nets)
    net_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    hg = Hypergraph(
        n_vertices=n_vertices,
        net_ptr=net_ptr,
        net_pins=pins,
        w_comp=np.asarray(w_comp, dtype=np.int64),
        w_mem=np.asarray(w_mem, dtype=np.int64),
        net_cost=np.asarray(net_cost, dtype=np.int64),
        **meta,
    )
    hg.validate()
    return hg


def remove_singleton_nets(hg: Hypergraph) -> Hypergraph:
    """Singleton nets cannot be cut (Sec. 5.1) — drop them."""
    sizes = hg.net_sizes()
    keep = sizes > 1
    if keep.all():
        return hg
    nets = [hg.pins_of(n) for n in np.flatnonzero(keep)]
    return build_hypergraph(
        nets,
        hg.n_vertices,
        hg.w_comp,
        hg.w_mem,
        hg.net_cost[keep],
        vertex_kind=hg.vertex_kind,
        net_kind=hg.net_kind[keep] if hg.net_kind is not None else None,
        name=hg.name,
    )


def coalesce_identical_nets(hg: Hypergraph) -> Hypergraph:
    """Combine nets with identical pin sets; coarse cost = sum of costs
    (Sec. 5.1 'coalesced nets')."""
    keys: dict[bytes, int] = {}
    new_nets: list[np.ndarray] = []
    new_cost: list[int] = []
    new_kind: list[int] = []
    has_kind = hg.net_kind is not None
    for n in range(hg.n_nets):
        pins = np.sort(hg.pins_of(n))
        key = pins.tobytes()
        if key in keys:
            new_cost[keys[key]] += int(hg.net_cost[n])
        else:
            keys[key] = len(new_nets)
            new_nets.append(pins)
            new_cost.append(int(hg.net_cost[n]))
            if has_kind:
                new_kind.append(int(hg.net_kind[n]))
    return build_hypergraph(
        new_nets,
        hg.n_vertices,
        hg.w_comp,
        hg.w_mem,
        np.array(new_cost, dtype=np.int64),
        vertex_kind=hg.vertex_kind,
        net_kind=np.array(new_kind, dtype=np.int8) if has_kind else None,
        name=hg.name,
    )
