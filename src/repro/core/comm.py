"""Communication cost evaluation and lower bounds (Sec. 4, Sec. 6).

Given a hypergraph and a p-way partition (vertex -> part id):

- ``part_cut_costs``: per-part sum of boundary-net costs, i.e. the
  |Q_i|-weighted cost of Lemma 4.2 / Def. 4.1.  The paper's reported metric is
  ``max_i``; the per-part vector also yields total volume.
- ``connectivity_cost``: PaToH's objective, sum_n c(n) * (lambda(n) - 1).
- ``expand_fold_split``: volume attributed to A/B nets (expand phase) vs C
  nets (fold phase).
- eq. (1) baselines: memory-dependent and memory-independent lower bounds.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from repro.core.hypergraph import Hypergraph


def _net_part_counts(hg: Hypergraph, parts: np.ndarray, p: int) -> sp.csr_matrix:
    """(n_nets x p) matrix of per-net pin counts per part."""
    pin_parts = parts[hg.net_pins]
    net_ids = hg.pin_nets()  # cached on the hypergraph, like incidence()
    m = sp.coo_matrix(
        (np.ones(hg.n_pins, dtype=np.int64), (net_ids, pin_parts)),
        shape=(hg.n_nets, p),
    )
    return m.tocsr()


@dataclasses.dataclass(frozen=True)
class CommCosts:
    max_part_cost: int  # max_i sum_{n in Q_i} c(n)  (paper's reported metric)
    total_volume: int  # sum_n c(n) * lambda(n) over cut nets (send+recv words)
    connectivity: int  # sum_n c(n) * (lambda(n) - 1)   (PaToH objective)
    per_part: np.ndarray  # (p,) boundary cost per part
    expand: int  # connectivity volume on A/B nets
    fold: int  # connectivity volume on C nets
    comp_imbalance: float  # max_i w_comp(V_i) / (W/p) - 1
    mem_imbalance: float


def evaluate(hg: Hypergraph, parts: np.ndarray, p: int | None = None) -> CommCosts:
    parts = np.asarray(parts, dtype=np.int64)
    if p is None:
        p = int(parts.max()) + 1 if len(parts) else 1
    counts = _net_part_counts(hg, parts, p)
    lam = np.diff(counts.indptr)  # connectivity lambda(n)
    cut = lam > 1
    cost = hg.net_cost

    connectivity = int((cost * np.maximum(lam - 1, 0)).sum())
    total_volume = int((cost * np.where(cut, lam, 0)).sum())

    # per-part boundary cost: for each part q, sum of costs of nets that touch
    # q and at least one other part.
    cut_counts = counts[cut]
    cut_cost = cost[cut]
    incident = cut_counts.tocoo()
    per_part = np.bincount(
        incident.col, weights=cut_cost[incident.row], minlength=p
    ).astype(np.int64)

    if hg.net_kind is not None:
        is_c = hg.net_kind == 3
        fold = int((cost * np.maximum(lam - 1, 0))[cut & is_c].sum())
        expand = connectivity - fold
    else:
        expand = connectivity
        fold = 0

    wc = np.bincount(parts, weights=hg.w_comp, minlength=p)
    wm = np.bincount(parts, weights=hg.w_mem, minlength=p)
    tc, tm = hg.w_comp.sum(), hg.w_mem.sum()
    comp_imb = float(wc.max() / (tc / p) - 1.0) if tc else 0.0
    mem_imb = float(wm.max() / (tm / p) - 1.0) if tm else 0.0
    return CommCosts(
        max_part_cost=int(per_part.max()) if p else 0,
        total_volume=total_volume,
        connectivity=connectivity,
        per_part=per_part,
        expand=expand,
        fold=fold,
        comp_imbalance=comp_imb,
        mem_imbalance=mem_imb,
    )


# ---------------------------------------------------------------------------
# Classical lower bounds, eq. (1)
# ---------------------------------------------------------------------------
def memory_dependent_bound(n_mult: int, p: int, local_mem: float) -> float:
    """Omega(|V^m| / (p sqrt(M)) - alpha M), constants dropped (alpha = 0)."""
    return n_mult / (p * np.sqrt(local_mem))


def memory_independent_bound(n_mult: int, n_nz: int, p: int, beta: float = 1.0) -> float:
    """Omega(|V^m|^{2/3} / p^{2/3} - beta |V^nz| / p)."""
    return max(n_mult ** (2 / 3) / p ** (2 / 3) - beta * n_nz / p, 0.0)


def classical_bound(n_mult: int, n_nz: int, p: int, local_mem: float) -> float:
    return max(
        memory_dependent_bound(n_mult, p, local_mem),
        memory_independent_bound(n_mult, n_nz, p),
    )


# ---------------------------------------------------------------------------
# Sequential two-level I/O (Thm. 4.10 via a Lem. 4.9-style construction)
# ---------------------------------------------------------------------------
def sequential_io_estimate(hg: Hypergraph, fast_mem: int) -> dict:
    """Greedy S-partition construction with S = 2M.

    Produces h_greedy >= h_min parts each touching <= S distinct A, B and C
    nets, then reports:
      - ``lower_bound_proxy`` = M * (h_greedy - 1): an *estimate* of the
        Thm. 4.10 bound (exact only if the greedy h is minimum), and
      - ``upper_bound`` = the Lem. 4.9 algorithm cost 4 * m * g with
        m = floor(M/3), g <= h * ceil(S/m)^3 — a genuine attainable cost.
    """
    if hg.net_kind is None:
        raise ValueError("need net kinds to separate W^A/W^B/W^C")
    S = 2 * fast_mem
    ptr, vnets = hg.vertex_to_nets()
    kinds = hg.net_kind
    h = 0
    seen: dict[int, int] = {}
    counts = np.zeros(4, dtype=np.int64)  # per-kind distinct nets in open part
    open_nets: set[int] = set()
    # greedy sweep in vertex order (CSR order ~ row-major iteration space)
    for v in range(hg.n_vertices):
        nets = vnets[ptr[v] : ptr[v + 1]]
        new = [n for n in nets if n not in open_nets]
        new_per_kind = np.zeros(4, dtype=np.int64)
        for n in new:
            new_per_kind[kinds[n]] += 1
        if ((counts + new_per_kind)[1:] > S).any():
            h += 1  # close part, open a new one
            open_nets.clear()
            counts[:] = 0
            new = list(nets)
            new_per_kind[:] = 0
            for n in new:
                new_per_kind[kinds[n]] += 1
        open_nets.update(new)
        counts += new_per_kind
    h += 1 if hg.n_vertices else 0
    m = max(fast_mem // 3, 1)
    g = h * int(np.ceil(S / m)) ** 3
    return {
        "h": h,
        "lower_bound_proxy": fast_mem * max(h - 1, 0),
        "upper_bound": 4 * m * g,
    }
