"""MoE dispatch comm planner (the paper's technique applied to the LM stack).

The MoE dispatch is an SpGEMM: ``expert_in = D^T X`` with D the (tokens x
experts) routing structure.  Distributing experts over the 'model' axis is a
*monochrome-B / row-wise coarsening* of the dispatch SpGEMM hypergraph
(Sec. 5 of the paper): one vertex per expert (w_comp = its routed token
count), one net per token group (cost = group size x d_model words), cut =
token groups needed by more than one expert column, i.e. exactly the
all-to-all volume of an expert-parallel executor.

Partitioning this hypergraph (Thm. 4.5: min over balanced partitions of the
max per-part boundary cost) yields an expert -> column placement that
simultaneously
  (a) minimizes dispatch traffic for an all-to-all executor, and
  (b) balances routed load across columns (less capacity dropping for the
      replicated-token executor in ``repro.models.layers._moe_ep``).

Following the paper's own guidance (Sec. 7), planning is offline/amortized:
routing statistics come from profiling steps; the placement is then frozen
into ``MoEConfig.expert_placement``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm import evaluate
from repro.core.partition import partition
from repro.core.spgemm_models import SpGEMMInstance, build_model
from repro.sparse.structure import SparseStructure, from_coo


@dataclasses.dataclass
class PlacementPlan:
    placement: np.ndarray  # (E,) new expert id for expert e (permutation)
    column_of: np.ndarray  # (E,) expert column assignment
    comm_planned: int  # cut cost (token-group words crossing columns)
    comm_contiguous: int  # same metric for the naive [0..E) blocking
    load_imbalance_planned: float
    load_imbalance_contiguous: float


def routing_counts(gate_idx: np.ndarray, n_experts: int, n_groups: int) -> np.ndarray:
    """Aggregate observed top-k routing (T, K) into (n_groups, E) counts;
    groups are contiguous token spans (sequence locality ~ routing locality).
    """
    T = gate_idx.shape[0]
    group = (np.arange(T) * n_groups // T).astype(np.int64)
    counts = np.zeros((n_groups, n_experts), dtype=np.int64)
    np.add.at(counts, (group[:, None], gate_idx), 1)
    return counts


def dispatch_instance(counts: np.ndarray) -> SpGEMMInstance:
    """SpGEMM instance of the dispatch D^T X from grouped routing counts:
    A = D^T structure (E x G), B = X structure (G x 1, dense column)."""
    G, E = counts.shape
    g, e = np.nonzero(counts)
    a = from_coo(e, g, (E, G))  # D^T
    b = from_coo(np.arange(G), np.zeros(G, dtype=np.int64), (G, 1))
    return SpGEMMInstance(a, b, name="moe-dispatch")


def plan_expert_placement(
    counts: np.ndarray,
    n_columns: int,
    eps: float = 0.05,
    seed: int = 0,
) -> PlacementPlan:
    """Partition the dispatch hypergraph; experts co-routed with the same
    token groups land on the same column."""
    G, E = counts.shape
    if E % n_columns:
        raise ValueError(f"E={E} not divisible by columns={n_columns}")
    inst = dispatch_instance(counts)
    hg = build_model(inst, "rowwise")  # vertices = experts, nets = groups
    # weights: routed token counts (not just flop structure)
    hg.w_comp = counts.sum(axis=0).astype(np.int64)
    hg.net_cost = counts.sum(axis=1).astype(np.int64)  # words per group net

    res = partition(hg, n_columns, eps=eps, seed=seed)
    col = res.parts
    # contiguous baseline: expert e -> column e // (E / n_columns)
    e_loc = E // n_columns
    col_naive = np.arange(E) // e_loc

    planned = evaluate(hg, col, n_columns)
    naive = evaluate(hg, col_naive, n_columns)

    # build the permutation: experts sorted by column, stable within column
    order = np.lexsort((np.arange(E), col))
    # balance column sizes exactly (the executor needs E_loc per column):
    # round-robin spill of over-full columns
    placement = np.empty(E, dtype=np.int64)
    buckets: list[list[int]] = [[] for _ in range(n_columns)]
    for e in order:
        buckets[col[e]].append(int(e))
    overflow: list[int] = []
    for c in range(n_columns):
        while len(buckets[c]) > e_loc:
            overflow.append(buckets[c].pop())
    for c in range(n_columns):
        while len(buckets[c]) < e_loc:
            buckets[c].append(overflow.pop())
    col_final = np.empty(E, dtype=np.int64)
    for c in range(n_columns):
        for slot, e in enumerate(buckets[c]):
            placement[e] = c * e_loc + slot
            col_final[e] = c
    final = evaluate(hg, col_final, n_columns)

    load = counts.sum(axis=0).astype(np.float64)
    total = load.sum()

    def imb(assign):
        per_col = np.bincount(assign, weights=load, minlength=n_columns)
        return float(per_col.max() / (total / n_columns) - 1.0)

    return PlacementPlan(
        placement=placement,
        column_of=col_final,
        comm_planned=final.max_part_cost,
        comm_contiguous=naive.max_part_cost,
        load_imbalance_planned=imb(col_final),
        load_imbalance_contiguous=imb(col_naive),
    )
