"""Core paper library: SpGEMM hypergraph models, partitioning, comm bounds."""
from repro.core.hypergraph import (
    Hypergraph,
    build_hypergraph,
    build_hypergraph_flat,
    coalesce_identical_nets,
    remove_singleton_nets,
)
from repro.core.spgemm_models import (
    MODELS,
    MODELS_1D,
    MODELS_2D,
    SpGEMMInstance,
    build_model,
)
from repro.core.comm import (
    CommCosts,
    classical_bound,
    evaluate,
    memory_dependent_bound,
    memory_independent_bound,
    sequential_io_estimate,
)
from repro.core.partition import (
    PartitionResult,
    partition,
    partition_block,
    partition_random,
)

__all__ = [
    "Hypergraph",
    "build_hypergraph",
    "build_hypergraph_flat",
    "coalesce_identical_nets",
    "remove_singleton_nets",
    "MODELS",
    "MODELS_1D",
    "MODELS_2D",
    "SpGEMMInstance",
    "build_model",
    "CommCosts",
    "classical_bound",
    "evaluate",
    "memory_dependent_bound",
    "memory_independent_bound",
    "sequential_io_estimate",
    "PartitionResult",
    "partition",
    "partition_block",
    "partition_random",
]
