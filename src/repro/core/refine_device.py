"""Device-side K-way refinement: batched multi-seed label propagation in jax.

This is the jax half of ``partition(engine="device")`` (DESIGN.md §6).  The
host driver in ``core/partition.py`` still owns the V-cycle (clustering and
coarsening are scipy sparse products), but everything per-seed — initial
partition refinement, per-round gains, balance control, best-feasible
snapshotting and best-seed selection — runs inside ONE jitted kernel per
level, ``vmap``-ed over the whole multi-start batch.  Today's sequential
multi-start loop becomes one device call.

Why the kernel looks the way it does (measured on the CPU backend, which is
the floor this has to clear — an accelerator only widens the gap):

- **No scatters in the round body.**  XLA's scatter-add with computed
  indices runs ~20x slower than numpy's ``bincount`` on CPU (it is a
  serialized load-modify-store loop).  The per-round ``(n_nets, p)`` count
  table is instead computed by *lane-packed segmented cumsums*: parts are
  one-hot-encoded into 8-bit lanes of int32 words (4 parts per word), the
  words are cumsum-ed over the CSR-ordered pin list, and per-net counts drop
  out as boundary differences.  Integer wraparound keeps lane extraction
  exact as long as no net has more than 255 pins in one part — nets above
  ``LANE_NET_CAP`` pins are excluded from the device view (standard big-net
  filtering; their connectivity is near-saturated anyway and the host
  polish pass still sees them).
- **Sampled-candidate moves, exact gains.**  Evaluating gains toward all p
  targets costs O(pins · p) per round; instead each vertex draws one
  candidate label per round by walking vertex → random incident net →
  random pin → its part (counter-based hashing, no RNG state), and the
  *exact* connectivity delta for that single move is computed in O(pins)
  with two gathers and one segmented cumsum over the vertex-CSR ordering.
  This is the size-constrained label propagation used by scalable graph
  partitioners, with the hypergraph connectivity objective.
- **Balance as stochastic headroom thinning.**  Simultaneous moves toward
  one part are thinned with acceptance probability headroom/inflow, and
  vertices of over-cap parts may move at a loss (drain).  A per-round
  best-feasible snapshot ((connectivity, cap-feasibility) score) makes the
  returned partition monotone even though individual rounds oscillate.
- **Compile once per (shape-bucket, p).**  All arrays are padded to
  geometric size buckets (×1.5) with a phantom vertex (weight 0) and
  phantom net (cost 0) absorbing the tail, so the whole fixed-round
  refinement loop traces once per (bucket key, p, rounds, n_seeds) and
  every subsequent partition call with the same bucketed shape reuses the
  executable.  ``trace_count()`` exposes the retrace counter for tests,
  exactly like ``distributed/runtime.py``.

The driver applies the kernel at every V-cycle level (many rounds at the
coarsest level where pins are fewest, tapering toward the finest), then
hands the best seed to one host ``kway_refine`` polish pass — the host FM
remains the authority on the exact objective (it also sees the filtered-out
big nets), while the device batch does the multi-start exploration that
used to cost a full partition call per seed.
"""
from __future__ import annotations

import heapq
import os
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import Hypergraph

__all__ = [
    "DEVICE_STARTS",
    "ROUNDS_COARSE",
    "ROUNDS_MID",
    "ROUNDS_FINE",
    "initial_partitions",
    "initial_partitions_raw",
    "refine_args",
    "refine_batch",
    "trace_count",
]

DEVICE_STARTS = 8  # multi-seed batch width (the vmap axis)
ROUNDS_COARSE = 8  # LP rounds at the coarsest level (cheapest pins)
ROUNDS_MID = 4  # rounds at intermediate levels
ROUNDS_FINE = 2  # rounds at the finest level (the host polish follows)
MAX_DEVICE_NET = 64  # nets bigger than this are excluded from the device view
LANE_NET_CAP = 255  # 8-bit lane capacity: hard exactness bound on net size
_BUCKET_MIN = 256  # smallest pad bucket; buckets grow ×1.5

# -- retrace accounting (same contract as distributed/runtime.py) ------------
_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times a refinement kernel body has been traced.  Stable
    across repeated ``refine_batch`` calls with same-bucket shapes — the
    test hook for the compile-once-per-(shape-bucket, p) claim."""
    return _TRACE_COUNT


def _mark_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def _bucket(x: int) -> int:
    b = _BUCKET_MIN
    while b < x:
        b = int(b * 1.5) + 1
    return b


def _hash_u32(x, salt):
    """Counter-based avalanche hash (splitmix-style): deterministic per-round
    per-vertex randomness with no carried RNG state."""
    x = (x ^ salt) * jnp.uint32(0x9E3779B1)
    x = (x ^ (x >> 15)) * jnp.uint32(0x85EBCA77)
    return x ^ (x >> 13)


# -- padded flat-CSR level view ----------------------------------------------
@dataclass
class _PaddedLevel:
    nb: int  # vertex bucket (includes 1 phantom vertex)
    mb: int  # net bucket (includes 1 phantom net)
    pb: int  # pin bucket
    args: tuple  # device arrays handed to the kernel
    vinv: object = None  # (pb,) inverse of vperm (used by coarsen_device)


def _pad_level(
    hg: Hypergraph, max_net: int = MAX_DEVICE_NET, bucket=None
) -> _PaddedLevel:
    """Big-net-filtered, bucket-padded device view of one level.

    ``bucket`` overrides the shape-bucket function (default: the ×1.5
    ladder ``_bucket``; the device-resident V-cycle passes its tighter
    quantizer so the finest level — the largest pad by far — stops paying
    up to 50% shape waste in every cluster/contract kernel).

    Cached on the hypergraph object per bucket function: repeated partition
    calls on the same level skip the rebuild (the V-cycle's coarse levels
    are fresh objects per call, but the finest level is the caller's)."""
    key = (max_net, getattr(bucket, "__name__", "_bucket"))
    cache = getattr(hg, "_device_pad", None)
    if cache is not None and key in cache:
        return cache[key]
    if bucket is None:
        bucket = _bucket
    sizes = hg.net_sizes()
    keep = (sizes >= 1) & (sizes <= min(max_net, LANE_NET_CAP))
    kn = np.flatnonzero(keep)
    kept_sizes = sizes[kn]
    net_ptr = np.concatenate([[0], np.cumsum(kept_sizes)]).astype(np.int64)
    net_pins_f = hg.net_pins[np.repeat(keep, sizes)]
    npins_f = len(net_pins_f)
    n, m = hg.n_vertices + 1, len(kn) + 1  # + phantom vertex / net
    nb, mb, pb = bucket(n), bucket(m), bucket(max(npins_f, 1))
    pin_nets_f = np.repeat(np.arange(len(kn), dtype=np.int64), kept_sizes)

    pin_nets = np.full(pb, mb - 1, np.int32)
    pin_nets[:npins_f] = pin_nets_f
    net_pins = np.full(pb, nb - 1, np.int32)
    net_pins[:npins_f] = net_pins_f
    cost = np.zeros(mb, np.float32)
    cost[: len(kn)] = hg.net_cost[kn]
    w = np.zeros(nb, np.float32)
    w[: hg.n_vertices] = hg.w_comp

    # per-net pin-range boundaries over the padded pin axis; phantom nets
    # collapse to an empty [pb-1, pb-1] range (segment sum 0)
    hi = np.full(mb, pb - 1, np.int64)
    lo = np.full(mb, pb - 1, np.int64)
    lz = np.zeros(mb, bool)
    hi[: len(kn)] = net_ptr[1:] - 1
    lo[: len(kn)] = net_ptr[:-1] - 1
    lz[: len(kn)] = net_ptr[:-1] == 0

    # vertex-CSR over the SAME filtered pin list: a static permutation maps
    # net-ordered per-pin values into vertex order for the gain segment sums
    order = np.argsort(net_pins_f, kind="stable")
    vperm = np.arange(pb, dtype=np.int64)
    vperm[:npins_f] = order
    vdeg_np = np.bincount(net_pins_f, minlength=n)
    vp = np.concatenate([[0], np.cumsum(vdeg_np)]).astype(np.int64)
    vhi = np.full(nb, pb - 1, np.int64)
    vlo = np.full(nb, pb - 1, np.int64)
    vlz = np.zeros(nb, bool)
    vhi[:n] = vp[1:] - 1
    vlo[:n] = vp[:-1] - 1
    vlz[:n] = vp[:-1] == 0
    vptr = np.zeros(nb + 1, np.int64)
    vptr[: n + 1] = vp
    vptr[n + 1 :] = vp[-1]
    vnets = np.full(pb, mb - 1, np.int32)
    vnets[:npins_f] = pin_nets_f[order]
    # inverse of vperm: vertex-order position of each net-order slot; the
    # coarsening kernel uses it to transport per-leader budgets to net slots
    vinv = np.empty(pb, np.int32)
    vinv[vperm] = np.arange(pb, dtype=np.int32)

    J = jnp.asarray
    pl = _PaddedLevel(
        nb=nb,
        mb=mb,
        pb=pb,
        vinv=J(vinv),
        args=(
            J(pin_nets),
            J(net_pins),
            J(cost),
            J(w),
            J(vptr.astype(np.int32)),
            J(vnets),
            J(vperm.astype(np.int32)),
            J(hi.astype(np.int32)),
            J(lo.astype(np.int32)),
            J(lz),
            J(vhi.astype(np.int32)),
            J(vlo.astype(np.int32)),
            J(vlz),
        ),
    )
    try:
        if cache is None:
            hg._device_pad = cache = {}
        cache[key] = pl
    except AttributeError:  # exotic containers without a __dict__
        pass
    return pl


# -- the kernel ---------------------------------------------------------------
def _make_refiner(nb: int, mb: int, pb: int, p: int, rounds: int):
    lanes = (p + 3) // 4  # 4 parts per int32 word, 8-bit lanes

    def _refine(parts0_b, pin_nets, net_pins, cost, w, vptr, vnets, vperm,
                hi, lo, lo_zero, vhi, vlo, vlo_zero, cap, salts):
        _mark_trace()  # Python body: executes at trace time only
        cost_pin = cost[pin_nets]
        vdeg = (vptr[1:] - vptr[:-1]).astype(jnp.uint32)
        vids = jnp.arange(nb, dtype=jnp.uint32)
        net_lo = jnp.where(lo_zero, 0, lo + 1)  # per-net first pin slot
        ndeg = (hi + 1 - net_lo).astype(jnp.uint32)
        targets = jnp.arange(p, dtype=jnp.int32)[None, :]

        def one_seed(parts0, salt):
            def counts(parts):
                """(mb, p) per-net per-part pin counts, scatter-free: 8-bit
                lanes packed 4-per-int32, segmented by cumsum + boundary
                diff (wraparound-exact while net sizes stay <= 255)."""
                pp = parts[net_pins]
                val = jnp.int32(1) << ((pp & 3) * jnp.int32(8))
                cols = []
                for g in range(lanes):
                    cs = jnp.cumsum(jnp.where((pp >> 2) == g, val, 0))
                    seg = cs[hi] - jnp.where(lo_zero, 0, cs[lo])
                    for t in range(4):
                        if 4 * g + t < p:
                            cols.append(((seg >> (8 * t)) & 255).astype(jnp.int32))
                return jnp.stack(cols, 1)

            def part_weights(parts):
                onehot = parts[:, None] == targets
                return jnp.where(onehot, w[:, None], 0.0).sum(0)

            def score_of(cnt, part_w):
                lam = (cnt > 0).sum(1)
                conn = (cost * jnp.maximum(lam - 1, 0).astype(jnp.float32)).sum()
                # any over-cap part makes the score worse than every feasible
                # one — the snapshot then prefers feasibility over cut
                return conn + jnp.float32(1e12) * (part_w.max() > cap)

            def body(i, carry):
                parts, part_w, best_parts, best_sc = carry
                ri = jnp.uint32(i)
                cnt = counts(parts)
                sc = score_of(cnt, part_w)
                better = sc < best_sc
                best_parts = jnp.where(better, parts, best_parts)
                best_sc = jnp.where(better, sc, best_sc)
                # candidate label: vertex -> random incident net -> random
                # pin of that net -> its current part (degree-biased, like
                # classic label propagation's most-common-neighbor pull)
                h1 = _hash_u32(vids, salt ^ (ri * jnp.uint32(0x85EBCA77)))
                slot = vptr[:nb] + (h1 % jnp.maximum(vdeg, 1)).astype(jnp.int32)
                e = vnets[slot]
                h2 = _hash_u32(h1, salt ^ jnp.uint32(0xC2B2AE35))
                u = net_pins[net_lo[e] + (h2 % jnp.maximum(ndeg[e], 1)).astype(jnp.int32)]
                cand = jnp.where(vdeg > 0, parts[u], parts)
                # exact connectivity delta of each single move v -> cand(v):
                # per-pin leave/arrive terms, segment-summed in vertex order
                cnt_flat = cnt.reshape(-1)
                own_pin = parts[net_pins]
                cand_pin = cand[net_pins]
                leave = cost_pin * (cnt_flat[pin_nets * p + own_pin] == 1)
                arrive = cost_pin * (cnt_flat[pin_nets * p + cand_pin] == 0)
                csv = jnp.cumsum((leave - arrive)[vperm])
                gain = csv[vhi] - jnp.where(vlo_zero, 0.0, csv[vlo])
                over = part_w > cap
                want = (cand != parts) & ((gain > 0) | over[parts])
                # balance: thin simultaneous arrivals to the headroom
                cand_onehot = cand[:, None] == targets
                inflow = jnp.where(cand_onehot & want[:, None], w[:, None], 0.0).sum(0)
                headroom = jnp.maximum(cap - part_w, 0.0)
                acc = jnp.minimum(headroom[cand] / jnp.maximum(inflow[cand], 1e-9), 1.0)
                u01 = (
                    _hash_u32(vids, salt ^ jnp.uint32(0x165667B1) ^ ri) >> 8
                ).astype(jnp.float32) / jnp.float32(1 << 24)
                accept = want & (u01 < acc)
                # exact capacity guard: the thinning only matches *expected*
                # inflow to headroom, so without it some part overshoots the
                # cap almost every round and the feasible snapshot can
                # starve (fatal at coarse levels, where one cluster can
                # outweigh the whole headroom).  A per-target running prefix
                # admits arrivals greedily in vertex order and keeps every
                # round feasible by construction.
                pre = jnp.cumsum(
                    jnp.where(cand_onehot & accept[:, None], w[:, None], 0.0),
                    axis=0,
                )
                pre_v = jnp.take_along_axis(pre, cand[:, None], 1)[:, 0]
                accept = accept & (pre_v <= headroom[cand])
                parts = jnp.where(accept, cand, parts)
                return (parts, part_weights(parts), best_parts, best_sc)

            part_w0 = part_weights(parts0)
            parts, part_w, bp, bs = jax.lax.fori_loop(
                0, rounds, body, (parts0, part_w0, parts0, jnp.float32(1e30))
            )
            sc = score_of(counts(parts), part_w)
            better = sc < bs
            return jnp.where(better, parts, bp), jnp.where(better, sc, bs)

        return jax.vmap(one_seed)(parts0_b, salts)

    return jax.jit(_refine)


CACHE_SIZE = int(os.environ.get("REPRO_DEVICE_REFINER_CACHE", "32"))
_REFINERS: OrderedDict[tuple, object] = OrderedDict()


def _get_refiner(nb: int, mb: int, pb: int, p: int, rounds: int):
    key = (nb, mb, pb, p, rounds)
    fn = _REFINERS.get(key)
    if fn is None:
        fn = _make_refiner(nb, mb, pb, p, rounds)
        _REFINERS[key] = fn
        while len(_REFINERS) > CACHE_SIZE:
            _REFINERS.popitem(last=False)
    else:
        _REFINERS.move_to_end(key)
    return fn


# -- public entry points ------------------------------------------------------
def initial_partitions_raw(
    w: np.ndarray, p: int, seed: int, starts: int = DEVICE_STARTS
) -> np.ndarray:
    """(starts, len(w)) int32 balanced random partitions over raw vertex
    weights — the weight-only core of ``initial_partitions``, usable on a
    coarse device level without materializing a host ``Hypergraph``.

    Placement is longest-processing-time greedy (heaviest remaining vertex
    into the lightest part) rather than shuffled prefix chunking: at a
    coarse level single clusters weigh a sizeable fraction of a part, and
    chunked binning overshoots the balance cap at almost every boundary —
    an infeasible start the capped device refiner can never repair (its
    best-feasible snapshot never fires and the whole ascent freezes).  LPT
    keeps the max part within one small item of perfect balance.  Start
    diversity comes from a per-seed multiplicative jitter on the ordering
    weights, so each start descends in a different near-LPT order.

    The lightest-part pick runs on a 16-ish-entry heap of ``(weight, part)``
    tuples: heap order (min weight, then min part id) matches ``argmin``'s
    first-minimum tie-break exactly, so placements are identical to the
    naive scan at a fraction of the per-vertex cost."""
    w = np.asarray(w, dtype=np.float64)
    n = len(w)
    batch = np.zeros((starts, n), np.int32)
    wl = w.tolist()
    for s in range(starts):
        rng = np.random.default_rng((seed, s))
        order = np.argsort(-(w * (1.0 + 0.25 * rng.random(n))), kind="stable")
        heap = [(0.0, t) for t in range(p)]
        row = batch[s]
        for v in order.tolist():
            wt, t = heap[0]
            row[v] = t
            heapq.heapreplace(heap, (wt + wl[v], t))
    return batch


def initial_partitions(
    hg: Hypergraph, p: int, seed: int, starts: int = DEVICE_STARTS
) -> np.ndarray:
    """(starts, n_vertices) int32 balanced random partitions — the batch of
    independent starts the kernel refines side by side."""
    return initial_partitions_raw(hg.w_comp, p, seed, starts)


def refine_args(
    nb: int,
    mb: int,
    pb: int,
    args: tuple,
    parts_b,
    p: int,
    part_cap: float,
    rounds: int,
    seed: int = 0,
    salt: int = 0,
):
    """Device-resident refinement on a padded level's raw arrays.

    ``args`` is the 13-array padded-level layout of ``_pad_level`` (or a
    coarse level contracted on device by ``coarsen_device``); ``parts_b`` is
    an already-padded ``(starts, nb)`` batch (numpy or device array).  The
    returned ``(batch, scores)`` stay on device — no host round trip between
    V-cycle levels."""
    starts = parts_b.shape[0]
    fn = _get_refiner(nb, mb, pb, p, rounds)
    mix = ((seed * 0x85EBCA77) ^ (salt * 0xC2B2AE35)) & 0xFFFFFFFF
    salts = (
        jnp.arange(starts, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
    ) ^ jnp.uint32(mix)
    return fn(jnp.asarray(parts_b), *args, jnp.float32(part_cap), salts)


def refine_batch(
    hg: Hypergraph,
    parts_batch: np.ndarray,
    p: int,
    part_cap: float,
    rounds: int,
    seed: int = 0,
    salt: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Refine a (starts, n_vertices) batch of partitions on ``hg`` for a
    fixed number of LP rounds.  Returns (batch, scores): per-seed
    best-feasible partitions and their device scores (filtered-net
    connectivity + a large penalty when over the balance cap) — comparable
    across seeds, so ``argmin`` picks the winner."""
    pl = _pad_level(hg)
    starts = parts_batch.shape[0]
    padded = np.zeros((starts, pl.nb), np.int32)
    padded[:, : hg.n_vertices] = parts_batch
    bp, bs = refine_args(
        pl.nb, pl.mb, pl.pb, pl.args, padded, p, part_cap, rounds, seed, salt
    )
    return np.asarray(bp)[:, : hg.n_vertices], np.asarray(bs)
