"""Multilevel K-way hypergraph partitioner.

PaToH stand-in: recursive bisection with
  (1) heavy-connectivity vertex clustering for coarsening (vectorized
      through a scipy sparse similarity product),
  (2) greedy BFS-style initial bisection under a compute-balance constraint,
  (3) boundary FM refinement with classic delta-gain updates, minimizing the
      connectivity metric sum_n c(n) * (lambda(n) - 1) (what PaToH minimizes,
      Sec. 6; for a bisection this equals the weighted cut),
  (4) a direct K-way boundary label-propagation pass after recursive
      bisection that recovers cut lost at bisection boundaries,
subject to w_comp(V_i) <= (1 + eps) * W / p (Def. 4.4 with delta = p - 1,
matching the paper's experiments).

Three engines share this driver (DESIGN.md §6):

- ``engine="flat"`` (default): the flat-CSR refinement engine in
  ``core/refine.py`` — gain-bucket FM, vectorized frontier growth, star
  clustering with a vectorized similarity argmax, plus the K-way pass.
- ``engine="loop"``: the original per-move implementation, retained as the
  executable specification (``_fm_refine_loop`` / ``_initial_bisect_loop`` /
  ``_match_vertices_loop``, matching the ``build_rowwise_plan_loop``
  convention).  ``benchmarks/bench_partition.py`` measures the speedup and
  ``tests/test_partition_invariants.py`` gates the flat engine on
  equal-or-better connectivity at equal balance feasibility.
- ``engine="device"``: the batched jax label-propagation engine in
  ``core/refine_device.py``.  The host still owns the V-cycle; the
  per-level refinement and the whole multi-start batch run in one jitted
  device call per level, then the best seed gets one host ``kway_refine``
  polish.  Below ``DEVICE_MIN_VERTICES`` the host quality path stays
  authoritative; with jax unavailable the driver falls back to ``"flat"``
  (planning imports stay jax-free — PR 5's lazy-import contract).

Engineering notes (documented, standard heuristics):
- nets larger than ``BIG_NET`` pins are ignored during clustering and their
  delta-gain propagation is skipped (their contribution to gains is still
  counted when a vertex's gain is first computed); at the sizes we run,
  such nets are almost never uncuttable anyway.
- FM candidate set = vertices on cut nets (capped per pass in the loop
  engine).
"""
from __future__ import annotations

import dataclasses
import importlib
import time
import warnings
from collections import deque

import numpy as np
import scipy.sparse as sp

from repro.core.hypergraph import Hypergraph, build_hypergraph_flat
from repro.core.refine import (
    BIG_NET,
    DEG_CAP,
    fm_refine,
    initial_bisect,
    kway_refine,
)

MAX_MOVES_PER_PASS = 1200  # loop-engine FM candidate cap
SMALL_DIRECT = 4096  # below this, the flat engine runs full per-bisection
# multilevel (quality path); above it, one shared V-cycle (speed path)
SMALL_STARTS = 4  # independent starts on the quality path (best kept)
DEVICE_MIN_VERTICES = SMALL_DIRECT  # below this the device engine defers to
# the host quality path (kernel launch + padding overheads dominate there);
# tests monkeypatch this to 0 to exercise the kernel on small instances


@dataclasses.dataclass
class PartitionResult:
    parts: np.ndarray  # (n_vertices,) int64 part ids
    p: int
    connectivity: int  # final objective value
    warm: bool = False  # produced by the warm-start path (label reuse)
    phases: dict | None = None  # per-phase seconds (device engines):
    # {"coarsen_s", "refine_s", "polish_s"}


# device-engine fallback reasons already warned about (warn once per reason
# per process, not once per call — a drifting-structure session replans many
# times and must not spam); tests clear this to re-arm the warning
_FALLBACK_WARNED: set[str] = set()


def _warn_fallback(reason: str, message: str) -> None:
    if reason in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(reason)
    warnings.warn(message, RuntimeWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# coarsening
# ---------------------------------------------------------------------------
def _similarity(hg: Hypergraph, dtype=np.float64) -> sp.spmatrix:
    """sim(u, v) = sum over shared (small) nets of c(n)/(|n| - 1), with the
    diagonal kept (callers mask it entry-wise).  The result is symmetric, so
    callers may read its compressed-axis structure as rows whether scipy
    hands back CSR or CSC.

    Builds the weighted incidence directly in CSR form — nets are already
    pin-sorted, so filtering is a mask over the cached ``pin_nets()``
    expansion (hoisted, one gather) and the indptr a prefix sum over the
    filtered sizes.  The old per-level COO round trip paid a full
    sort-by-row in ``tocsr()`` for structure the level already had."""
    sizes = hg.net_sizes()
    ok = (sizes > 1) & (sizes <= BIG_NET)
    wfac = np.zeros(hg.n_nets, dtype=dtype)
    wfac[ok] = np.sqrt(
        hg.net_cost[ok].astype(dtype) / np.maximum(sizes[ok] - 1, 1).astype(dtype)
    )
    net_ids = hg.pin_nets()  # cached expansion, hoisted out of the filter
    keep = ok[net_ids]
    indptr = np.concatenate([[0], np.cumsum(np.where(ok, sizes, 0))])
    W = sp.csr_matrix(
        (wfac[net_ids[keep]], hg.net_pins[keep], indptr),
        shape=(hg.n_nets, hg.n_vertices),
    )
    S = W.T @ W
    if S.format not in ("csr", "csc"):
        S = S.tocsr()
    return S


def _best_partners(S: sp.spmatrix) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise (argmax, max) of a symmetric similarity matrix excluding the
    diagonal, fully vectorized via one segmented ``maximum.reduceat`` — the
    diagonal is masked entry-wise, which sidesteps the scipy-1.14 ``setdiag``
    corruption the old per-row loop worked around with a COO rebuild.  ``S``
    may be CSR or CSC; symmetry makes the compressed axis a row either way."""
    n = S.shape[0]
    best = np.full(n, -1, dtype=np.int64)
    score = np.full(n, -1.0)
    lens = np.diff(S.indptr)
    nzr = np.flatnonzero(lens)
    if len(nzr) == 0:
        return best, score
    rows_rep = np.repeat(np.arange(n, dtype=np.int64), lens)
    data = np.where(S.indices == rows_rep, -1.0, S.data)
    rowmax = np.maximum.reduceat(data, S.indptr[nzr])
    hit = np.flatnonzero(data == np.repeat(rowmax, lens[nzr]))
    urow, first = np.unique(rows_rep[hit], return_index=True)
    best[urow] = S.indices[hit[first]]
    score[urow] = data[hit[first]]
    return best, score


def _cluster_vertices(
    hg: Hypergraph, max_weight: float, stars: bool = True
) -> np.ndarray:
    """Agglomerative clustering: each vertex proposes its best partner
    (vectorized row argmax of the similarity product); proposals are granted
    in descending-score order.  With ``stars=True`` later vertices may join
    an existing cluster while its weight stays under ``max_weight`` —
    multi-vertex clusters shrink the hypergraph ~3x per level, so the
    V-cycle is shorter.  With ``stars=False`` only pairs form (the quality
    path keeps more levels, like the loop reference's pairwise matching)."""
    n = hg.n_vertices
    best, score = _best_partners(_similarity(hg, dtype=np.float32))
    order = np.argsort(-score, kind="stable")
    cl = np.full(n, -1, dtype=np.int64)
    cl_w: list[float] = []
    wc = hg.w_comp.astype(np.float64)
    best_l = best.tolist()
    score_l = score.tolist()
    cl_l = cl.tolist()  # python list: the grant loop is scalar
    for v in order.tolist():
        if score_l[v] <= 0:
            break
        if cl_l[v] >= 0:
            continue
        u = best_l[v]
        cu = cl_l[u]
        if cu < 0:
            if wc[u] + wc[v] <= max_weight:
                cl_l[v] = cl_l[u] = len(cl_w)
                cl_w.append(wc[u] + wc[v])
        elif stars and cl_w[cu] + wc[v] <= max_weight:
            cl_l[v] = cu
            cl_w[cu] += wc[v]
    cl = np.array(cl_l, dtype=np.int64)
    singles = np.flatnonzero(cl < 0)
    cl[singles] = len(cl_w) + np.arange(len(singles))
    return cl


def _match_vertices_loop(
    hg: Hypergraph, rng: np.random.Generator, max_weight: float
) -> np.ndarray:
    """Loop-engine matcher (executable specification): pairwise
    heavy-connectivity matching with a per-row argmax loop; proposals are
    granted greedily in descending-score order."""
    S = _similarity(hg).tocoo()
    # drop the diagonal via an explicit COO filter: csr.setdiag(0) in scipy
    # 1.14 corrupts neighbouring entries when nearly the whole diagonal is
    # stored (stale offsets after _insert_many), leaving self-similarities
    # that make vertices match themselves
    off_diag = S.row != S.col
    S = sp.csr_matrix(
        (S.data[off_diag], (S.row[off_diag], S.col[off_diag])), shape=S.shape
    )
    n = hg.n_vertices
    best = np.full(n, -1, dtype=np.int64)
    score = np.zeros(n, dtype=np.float64)
    indptr, indices, data = S.indptr, S.indices, S.data
    nz_rows = np.flatnonzero(np.diff(indptr) > 0)
    for v in nz_rows:
        lo, hi = indptr[v], indptr[v + 1]
        j = lo + np.argmax(data[lo:hi])
        best[v] = indices[j]
        score[v] = data[j]
    order = np.argsort(-score, kind="stable")
    match = np.full(n, -1, dtype=np.int64)
    wc = hg.w_comp
    for v in order:
        u = best[v]
        if u < 0 or score[v] <= 0:
            break
        if match[v] < 0 and match[u] < 0 and wc[u] + wc[v] <= max_weight:
            match[v] = u
            match[u] = v
    coarse = np.full(n, -1, dtype=np.int64)
    # matched pairs get one id, singletons keep their own
    pair_lo = np.flatnonzero(match > np.arange(n))
    coarse[pair_lo] = np.arange(len(pair_lo))
    coarse[match[pair_lo]] = coarse[pair_lo]
    singles = np.flatnonzero(match < 0)
    coarse[singles] = len(pair_lo) + np.arange(len(singles))
    return coarse


def _coarsen(
    hg: Hypergraph, coarse: np.ndarray, big_net_cap: int | None = None
) -> tuple[Hypergraph, int]:
    """Contract vertices by ``coarse``; drop singletons (Sec. 5.1).

    ``big_net_cap``: additionally drop coarse nets with more pins than the
    cap (the flat engine passes ``BIG_NET``).  Contracted nets grow toward
    |V| pins, are excluded from similarity clustering and FM gain updates
    anyway, and are next to uncuttable — but still dominate the coarse
    graphs' pin counts if kept.  The loop reference keeps every net.

    Identical nets are NOT coalesced inside the V-cycle: duplicate nets yield
    exactly the same connectivity objective and FM gains (their costs add),
    so coalescing is a pure speed tradeoff — and the hashing dominated the
    profile.  ``hypergraph.coalesce_identical_nets`` stays available for the
    modeling API (Sec. 5.3/5.4 builders use summed costs directly)."""
    n_coarse = int(coarse.max()) + 1
    w_comp = np.bincount(coarse, weights=hg.w_comp, minlength=n_coarse).astype(np.int64)
    w_mem = np.bincount(coarse, weights=hg.w_mem, minlength=n_coarse).astype(np.int64)

    net_ids = hg.pin_nets()
    pins = coarse[hg.net_pins]
    key = np.unique(net_ids * n_coarse + pins)
    net_ids, pins = key // n_coarse, key % n_coarse

    counts = np.bincount(net_ids, minlength=hg.n_nets)
    keep = (counts[net_ids] > 1) if big_net_cap is None else (
        (counts[net_ids] > 1) & (counts[net_ids] <= big_net_cap)
    )
    net_ids, pins = net_ids[keep], pins[keep]
    if len(net_ids) == 0:
        empty = np.empty(0, dtype=np.int64)
        return (
            build_hypergraph_flat(empty, empty, 0, n_coarse, w_comp, w_mem, empty),
            n_coarse,
        )
    uniq_nets, compact = np.unique(net_ids, return_inverse=True)
    return (
        build_hypergraph_flat(
            compact,
            pins,
            len(uniq_nets),
            n_coarse,
            w_comp,
            w_mem,
            hg.net_cost[uniq_nets],
        ),
        n_coarse,
    )


# ---------------------------------------------------------------------------
# loop-engine initial bisection + FM refinement (executable specification)
# ---------------------------------------------------------------------------
def _initial_bisect_loop(
    hg: Hypergraph, target0: float, rng: np.random.Generator
) -> np.ndarray:
    """Greedy net-BFS growth of side 0 up to ~target0 total compute weight."""
    n = hg.n_vertices
    side = np.ones(n, dtype=np.int8)
    ptr, vnets = hg.vertex_to_nets()
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    w = hg.w_comp.astype(np.float64)
    total0 = 0.0
    seed = int(rng.integers(n))
    frontier: deque[int] = deque([seed])
    seen = np.zeros(n, dtype=bool)
    seen[seed] = True
    while total0 < target0:
        if not frontier:
            rest = np.flatnonzero(~seen)
            if not len(rest):
                break
            s = int(rest[rng.integers(len(rest))])
            seen[s] = True
            frontier.append(s)
        v = frontier.popleft()
        if total0 + w[v] > target0 * 1.05 and total0 > 0:
            continue
        side[v] = 0
        total0 += w[v]
        for nid in vnets[ptr[v] : ptr[v + 1]]:
            pins = net_pins[net_ptr[nid] : net_ptr[nid + 1]]
            for u in pins:
                if not seen[u]:
                    seen[u] = True
                    frontier.append(u)
    return side


def _compute_counts(hg: Hypergraph, side: np.ndarray) -> np.ndarray:
    """(n_nets, 2) per-side pin counts."""
    net_ids = hg.pin_nets()
    pin_side = side[hg.net_pins]
    cnt = np.zeros((hg.n_nets, 2), dtype=np.int64)
    cnt[:, 1] = np.bincount(net_ids, weights=pin_side, minlength=hg.n_nets)
    cnt[:, 0] = hg.net_sizes() - cnt[:, 1]
    return cnt


def _gains_for_all(hg: Hypergraph, side: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    """Vectorized FM gains for all vertices via two sparse matvecs:
    gain(v) = sum_{n in v} c(n)[cnt(n, side(v)) == 1] - c(n)[cnt(n, other) == 0]."""
    inc = hg.incidence()  # (n_nets, n_vertices) cached on the hypergraph
    cost = hg.net_cost.astype(np.float64)
    only0 = cost * (cnt[:, 0] == 1)
    only1 = cost * (cnt[:, 1] == 1)
    empty0 = cost * (cnt[:, 0] == 0)
    empty1 = cost * (cnt[:, 1] == 0)
    # per-vertex sums of each net quantity
    s_only0 = inc.T @ only0
    s_only1 = inc.T @ only1
    s_empty0 = inc.T @ empty0
    s_empty1 = inc.T @ empty1
    side_b = side.astype(bool)
    gains = np.where(side_b, s_only1 - s_empty0, s_only0 - s_empty1)
    return gains


def _fm_refine_loop(
    hg: Hypergraph,
    side: np.ndarray,
    max_w: tuple[float, float],
    passes: int = 2,
) -> np.ndarray:
    """Boundary FM with classic delta-gain updates and per-pass rollback.

    Retained as the executable specification of ``refine.fm_refine`` —
    per-move ``np.argmax`` best-move selection and per-net pin gathers;
    ``benchmarks/bench_partition.py`` measures the flat engine against it."""
    ptr, vnets = hg.vertex_to_nets()
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    cost = hg.net_cost.astype(np.float64)
    sizes = hg.net_sizes()
    small = sizes <= BIG_NET
    w = hg.w_comp.astype(np.float64)
    side = side.astype(np.int8).copy()

    for _pass in range(passes):
        cnt = _compute_counts(hg, side)
        side_w = np.array([w[side == 0].sum(), w[side == 1].sum()])
        cut = (cnt[:, 0] > 0) & (cnt[:, 1] > 0)
        if not cut.any():
            break
        all_gains = _gains_for_all(hg, side, cnt)
        # candidates: boundary vertices, best gains first (vectorized via the
        # per-pin net-id expansion)
        boundary = np.zeros(hg.n_vertices, dtype=bool)
        pin_cut = np.repeat(cut, sizes)
        boundary[net_pins[pin_cut]] = True
        deg = np.diff(ptr)
        cand = np.flatnonzero(boundary & (deg <= DEG_CAP))
        if len(cand) == 0:
            break
        if len(cand) > MAX_MOVES_PER_PASS:
            top = np.argsort(-all_gains[cand], kind="stable")[:MAX_MOVES_PER_PASS]
            cand = cand[top]
        pos_of = np.full(hg.n_vertices, -1, dtype=np.int64)
        pos_of[cand] = np.arange(len(cand))
        gains = all_gains[cand]
        locked = np.zeros(len(cand), dtype=bool)

        history: list[int] = []
        cum, best_cum, best_idx = 0.0, 0.0, -1
        NEG = -1e30
        g_work = gains.copy()
        for _move in range(len(cand)):
            g_masked = np.where(locked, NEG, g_work)
            # balance feasibility
            vs = cand
            s_arr = side[vs]
            feasible = side_w[1 - s_arr] + w[vs] <= np.array(max_w)[1 - s_arr]
            g_masked = np.where(feasible, g_masked, NEG)
            bi = int(np.argmax(g_masked))
            if g_masked[bi] <= NEG / 2:
                break
            bg = g_work[bi]
            v = int(cand[bi])
            s = int(side[v])
            t = 1 - s
            # --- apply move with vectorized delta-gain updates ---
            nets = vnets[ptr[v] : ptr[v + 1]]
            snets = nets[small[nets]]
            ct_before = cnt[snets, t]
            # rule 1: t-count was 0 -> all other free pins gain +c
            # rule 2: t-count was 1 -> the lone t-side free pin gains -c
            r1 = snets[ct_before == 0]
            r2 = snets[ct_before == 1]
            cnt[nets, s] -= 1
            cnt[nets, t] += 1
            cs_after = cnt[snets, s]
            # rule 3: s-count now 0 -> all other free pins gain -c
            # rule 4: s-count now 1 -> the lone s-side free pin gains +c
            r3 = snets[cs_after == 0]
            r4 = snets[cs_after == 1]

            def _apply(rule_nets, sign, side_filter):
                if len(rule_nets) == 0:
                    return
                pins = np.concatenate(
                    [net_pins[net_ptr[n] : net_ptr[n + 1]] for n in rule_nets]
                )
                cs = np.repeat(cost[rule_nets],
                               net_ptr[rule_nets + 1] - net_ptr[rule_nets])
                pu = pos_of[pins]
                m = (pu >= 0) & (pins != v)
                if side_filter is not None:
                    m &= side[pins] == side_filter
                pu = pu[m]
                m2 = ~locked[pu]
                np.add.at(g_work, pu[m2], sign * cs[m][m2])

            _apply(r1, +1.0, None)
            _apply(r2, -1.0, t)
            _apply(r3, -1.0, None)
            _apply(r4, +1.0, s)
            side[v] = t
            side_w[s] -= w[v]
            side_w[t] += w[v]
            locked[bi] = True
            history.append(v)
            cum += bg
            if cum > best_cum + 1e-9:
                best_cum, best_idx = cum, len(history) - 1
            if bg < 0 and len(history) - 1 - best_idx > 50:
                break  # hill-descent cutoff
        # rollback to best prefix
        for v in history[best_idx + 1 :]:
            s = int(side[v])
            side[v] = 1 - s
            side_w[s] -= w[v]
            side_w[1 - s] += w[v]
        if best_cum <= 0:
            break
    return side


# ---------------------------------------------------------------------------
# multilevel bisection drivers
# ---------------------------------------------------------------------------
def _bisect(
    hg: Hypergraph,
    k0: int,
    k1: int,
    part_cap: float,
    rng: np.random.Generator,
    coarsen_to: int = 160,
    engine: str = "flat",
    multilevel: bool = True,
) -> np.ndarray:
    """Multilevel bisection into sides destined for k0 and k1 parts.

    ``part_cap`` is the GLOBAL maximum per-part weight (1+eps) * W_total / p;
    the side caps are k_side * part_cap so imbalance cannot compound down the
    recursion.

    With ``multilevel=False`` the flat engine skips per-bisection
    coarsening: ``partition`` already ran the shared global V-cycle, so this
    bisects what is effectively a coarse graph directly (initial growth +
    gain-bucket FM).  The loop engine always re-coarsens each subproblem
    with pairwise matching, as the original implementation did."""
    total = float(hg.w_comp.sum())
    frac0 = k0 / (k0 + k1)
    max_w = (k0 * part_cap, k1 * part_cap)
    levels: list[tuple[Hypergraph, np.ndarray]] = []
    cur = hg
    if engine == "loop" or multilevel:
        heaviest = float(cur.w_comp.max()) if cur.n_vertices else 0.0
        cluster_cap = max(total / 10, heaviest)
        while cur.n_vertices > coarsen_to:
            if engine == "flat":
                cmap = _cluster_vertices(cur, max_weight=cluster_cap)
                nxt, n_coarse = _coarsen(cur, cmap, big_net_cap=BIG_NET)
            else:
                cmap = _match_vertices_loop(cur, rng, max_weight=cluster_cap)
                nxt, n_coarse = _coarsen(cur, cmap)
            if n_coarse >= cur.n_vertices * 0.95:  # clustering stalled
                break
            levels.append((cur, cmap))
            cur = nxt

    if engine == "flat":
        # tiny graphs get extra passes — each pass rolls back to its best
        # prefix, so per-bisection passes are monotone and nearly free here
        passes = 4 if hg.n_vertices <= 512 else 2
        side = initial_bisect(
            cur,
            min(total * frac0, max_w[0]),
            rng,
            min0=total - max_w[1],  # side 1 must end under its own cap
        )
        side = fm_refine(cur, side, max_w, max_passes=passes)
        for fine, cmap in reversed(levels):
            side = side[cmap]
            side = fm_refine(fine, side, max_w, max_passes=passes)
    else:
        side = _initial_bisect_loop(cur, min(total * frac0, max_w[0]), rng)
        side = _fm_refine_loop(cur, side, max_w)
        for fine, cmap in reversed(levels):
            side = side[cmap]
            side = _fm_refine_loop(fine, side, max_w)
    return side


def _restrict(hg: Hypergraph, mask: np.ndarray) -> tuple[Hypergraph, np.ndarray]:
    """Sub-hypergraph induced on ``mask`` vertices (nets restricted, singletons
    dropped).  Returns (sub, original-ids-of-sub-vertices)."""
    ids = np.flatnonzero(mask)
    remap = np.full(hg.n_vertices, -1, dtype=np.int64)
    remap[ids] = np.arange(len(ids))
    net_ids = hg.pin_nets()
    keep = mask[hg.net_pins]
    net_ids = net_ids[keep]
    pins = remap[hg.net_pins[keep]]
    counts = np.bincount(net_ids, minlength=hg.n_nets)
    keep2 = counts[net_ids] > 1
    net_ids, pins = net_ids[keep2], pins[keep2]
    uniq, new_net = np.unique(net_ids, return_inverse=True)
    sub = build_hypergraph_flat(
        new_net,
        pins,
        len(uniq),
        len(ids),
        hg.w_comp[ids],
        hg.w_mem[ids],
        hg.net_cost[uniq],
    )
    return sub, ids


def _recursive_bisection(
    hg: Hypergraph,
    p: int,
    part_cap: float,
    rng: np.random.Generator,
    engine: str,
    multilevel: bool = True,
) -> np.ndarray:
    """K-way partition of ``hg`` via recursive bisection."""
    parts = np.zeros(hg.n_vertices, dtype=np.int64)
    stack: list[tuple[Hypergraph, np.ndarray, int, int]] = [
        (hg, np.arange(hg.n_vertices), 0, p)
    ]
    while stack:
        sub, ids, lo, hi = stack.pop()
        k = hi - lo
        if k == 1:
            parts[ids] = lo
            continue
        k0 = k // 2
        side = _bisect(
            sub, k0, k - k0, part_cap, rng, engine=engine, multilevel=multilevel
        )
        for s, plo, phi in ((0, lo, lo + k0), (1, lo + k0, hi)):
            mask = side == s
            if not mask.any():
                continue
            if phi - plo == 1:
                parts[ids[mask]] = plo
            else:
                ssub, sids = _restrict(sub, mask)
                stack.append((ssub, ids[mask], plo, phi))
    return parts


def _global_vcycle(
    hg: Hypergraph, p: int, part_cap: float
) -> tuple[list[tuple[Hypergraph, np.ndarray]], Hypergraph]:
    """The shared global V-cycle of the speed paths: cluster caps stay well
    under a part so the coarse initial partitions can still balance.  Returns
    (levels fine-to-coarse, coarsest hypergraph)."""
    total = float(hg.w_comp.sum())
    cluster_cap = max(min(total / 10, part_cap / 4), float(hg.w_comp.max()))
    glob_target = max(256, 16 * p)
    levels: list[tuple[Hypergraph, np.ndarray]] = []
    cur = hg
    while cur.n_vertices > glob_target:
        cmap = _cluster_vertices(cur, max_weight=cluster_cap)
        nxt, n_coarse = _coarsen(cur, cmap, big_net_cap=BIG_NET)
        # a nearly-stalled level buys no structure but costs a cluster +
        # K-way pass each; 0.8 keeps only useful levels
        if n_coarse >= cur.n_vertices * 0.8:
            break
        levels.append((cur, cmap))
        cur = nxt
    return levels, cur


# resident-path refinement schedule: the descend happens on device, so the
# ascent can winnow hard — a full multi-round sweep at the coarsest level
# picks the surviving start, intermediate levels get short touch-up passes,
# and one finest-level round settles the expansion before the host K-way
# polish (the exactness authority — it alone sees the big nets the device
# view filters out)
RESIDENT_MID_STARTS = 1  # starts surviving past the coarsest sweep.  The
# coarsest level's pin count is barely below the mid levels' (nets keep most
# of their distinct-cluster pins), so every surviving start pays near-full
# freight per mid round; measured on er10k/p16 the runner-up never overtakes
# the coarse winner during the short mid sweeps, so carrying it is pure cost
RESIDENT_MID_ROUNDS = 2  # LP rounds per intermediate level
RESIDENT_COARSE_STARTS = 3  # independent LPT starts at the coarsest level;
# all of them must finish the full coarse sweep before the winnow — cutting
# the sweep short picks survivors on scores that have not separated yet and
# costs several percent of final connectivity
RESIDENT_COARSE_ROUNDS = 4  # LP rounds at the coarsest level
RESIDENT_FINE_ROUNDS = 1  # winner-only LP rounds at the finest level: one
# start costs a fraction of a mid sweep even at the costliest pins, settles
# the expansion locally, and buys back the connectivity the shortened host
# polish below would otherwise leave on the table
RESIDENT_KWAY_ROUNDS = 4  # host polish rounds after the device V-cycle —
# the fine-level touch-up hands kway a start where the last round barely
# moves, so the polish budget drops below the host path's 5 without giving
# back the quality the cap enforces (small instances keep the full budget:
# their rounds are nearly free and the device sweep is their exploration)
RESIDENT_TARGET = 75  # stop descending near TARGET * p vertices (a shallow
# two-contraction hierarchy: deeper ones buy little quality once the host
# polish runs, but each extra level costs pin-sized kernels both ways)


def _partition_device(
    hg: Hypergraph, p: int, part_cap: float, seed: int, rd, coarsen: str = "auto"
) -> tuple[np.ndarray, dict]:
    """Device-engine dispatcher.  ``coarsen="auto"``/``"device"`` runs the
    fully device-resident V-cycle (``core/coarsen_device.py``); ``"host"``
    keeps the PR-6 host-scipy descend.  Device-coarsening import or runtime
    failure degrades to host coarsening with a once-per-process warning —
    the same contract as the engine-level jax fallback."""
    if coarsen != "host":
        cd = None
        try:
            cd = importlib.import_module("repro.core.coarsen_device")
        except ImportError:
            _warn_fallback(
                "coarsen_import",
                "device coarsening unavailable; falling back to host "
                "coarsening for engine='device'",
            )
        if cd is not None:
            try:
                return _partition_device_resident(hg, p, part_cap, seed, rd, cd)
            except Exception as exc:
                _warn_fallback(
                    "coarsen_runtime",
                    f"device coarsening failed ({exc!r}); falling back to "
                    "host coarsening for engine='device'",
                )
    return _partition_device_hostcoarsen(hg, p, part_cap, seed, rd)


def _partition_device_resident(
    hg: Hypergraph, p: int, part_cap: float, seed: int, rd, cd
) -> tuple[np.ndarray, dict]:
    """Fully device-resident V-cycle: descend (cluster + contract) and
    ascend (batched multi-seed refinement) both run as jitted kernels over
    bucket-padded device arrays; per level only two shape scalars cross to
    the host, and only the winning finest-level labels transfer back for
    the ``kway_refine`` polish."""
    jnp = rd.jnp  # partition.py itself must import without jax
    t0 = time.perf_counter()
    total = float(hg.w_comp.sum())
    cluster_cap = max(min(total / 10, part_cap / 4), float(hg.w_comp.max()))
    glob_target = max(256, RESIDENT_TARGET * p)
    levels = [cd.finest_level(hg)]
    cmaps = []
    while (
        levels[-1].n_vertices > glob_target and len(cmaps) < cd.MAX_LEVELS
    ):
        out = cd.coarsen_level(levels[-1], cluster_cap, seed, len(cmaps))
        if out is None:  # stalled or shape guard tripped: stop descending
            break
        coarse, cmap, _ = out
        levels.append(coarse)
        cmaps.append(cmap)
    t1 = time.perf_counter()

    cur = levels[-1]
    w_host = np.asarray(cur.args[3])[: cur.n_vertices]
    small = not cmaps or hg.n_vertices <= SMALL_DIRECT
    starts = rd.DEVICE_STARTS if small else RESIDENT_COARSE_STARTS
    init = np.zeros((starts, cur.nb), np.int32)
    init[:, : cur.n_vertices] = rd.initial_partitions_raw(w_host, p, seed, starts)
    rounds = 3 * rd.ROUNDS_COARSE if small else RESIDENT_COARSE_ROUNDS
    batch, scores = rd.refine_args(
        cur.nb, cur.mb, cur.pb, cur.args, init, p, part_cap, rounds, seed, 0,
    )
    # small instances keep the full-width ascent the host-coarsening path
    # gives them (every start, tripled rounds): their rounds are nearly
    # free, while the schedule constants are tuned at scale, where every
    # extra start pays near-full pin freight per level
    keep = starts if small else RESIDENT_MID_STARTS
    mid_rounds = 3 * rd.ROUNDS_MID if small else RESIDENT_MID_ROUNDS
    fine_rounds = 3 * rd.ROUNDS_FINE if small else RESIDENT_FINE_ROUNDS
    if cmaps:
        # winnow and expand without leaving the device: argsort over a
        # handful of per-start scores is free there, and skipping the host
        # round-trip per level keeps the ascent a single async dispatch
        # stream until the final winner transfer
        order = jnp.argsort(scores)
        batch = batch[order[:keep]]
        scores = scores[order[:keep]]
        for li in range(len(levels) - 2, 0, -1):
            lvl = levels[li]
            batch = batch[:, cmaps[li]]
            batch, scores = rd.refine_args(
                lvl.nb, lvl.mb, lvl.pb, lvl.args, batch, p, part_cap,
                mid_rounds, seed, li + 1,
            )
    winner = batch[jnp.argmin(scores)]
    if cmaps:
        winner = winner[cmaps[0]]
        if fine_rounds > 0:
            # a short winner-only touch-up at the finest level: at one start
            # it costs a fraction of a mid sweep and hands the host polish a
            # start that is already locally settled (salt: li never reaches
            # len(levels) in the mid loop, so the stream is fresh)
            lvl = levels[0]
            wb, _ = rd.refine_args(
                lvl.nb, lvl.mb, lvl.pb, lvl.args, winner[None], p, part_cap,
                fine_rounds, seed, len(levels),
            )
            winner = wb[0]
    parts = np.asarray(winner)[: hg.n_vertices].astype(np.int64)
    t2 = time.perf_counter()
    parts = kway_refine(
        hg, parts, p, part_cap,
        **({} if small else {"max_rounds": RESIDENT_KWAY_ROUNDS}),
    )
    t3 = time.perf_counter()
    return parts, {
        "coarsen_s": t1 - t0,
        "refine_s": t2 - t1,
        "polish_s": t3 - t2,
    }


def _partition_device_hostcoarsen(
    hg: Hypergraph, p: int, part_cap: float, seed: int, rd
) -> tuple[np.ndarray, dict]:
    """PR-6 device driver, retained as the device-coarsening fallback and
    the bench baseline: host scipy V-cycle + batched multi-seed device
    refinement at every level + best-seed host polish.

    The whole multi-start batch (``rd.DEVICE_STARTS`` seeds) moves through
    the V-cycle side by side: many LP rounds at the coarsest level where
    pins are fewest, tapering toward the finest.  Seeds are compared on the
    device score (filtered-net connectivity + infeasibility penalty) and
    only the winner pays the host ``kway_refine`` polish — which also
    restores exactness for the big nets the device view filters out."""
    t0 = time.perf_counter()
    levels, cur = _global_vcycle(hg, p, part_cap)
    t1 = time.perf_counter()
    batch = rd.initial_partitions(cur, p, seed)
    # sub-threshold instances only reach this path when tests force the
    # engine; rounds are nearly free at those sizes (and when the V-cycle
    # found no hierarchy, LP does all the work), so trade rounds for quality
    boost = 3 if (not levels or hg.n_vertices <= SMALL_DIRECT) else 1
    batch, scores = rd.refine_batch(
        cur, batch, p, part_cap, boost * rd.ROUNDS_COARSE, seed=seed, salt=0
    )
    n_lv = len(levels)
    for li, (fine, cmap) in enumerate(reversed(levels)):
        batch = batch[:, cmap]
        rounds = rd.ROUNDS_FINE if li == n_lv - 1 else rd.ROUNDS_MID
        batch, scores = rd.refine_batch(
            fine, batch, p, part_cap, boost * rounds, seed=seed, salt=li + 1
        )
    parts = batch[int(np.argmin(scores))].astype(np.int64)
    t2 = time.perf_counter()
    parts = kway_refine(hg, parts, p, part_cap)
    t3 = time.perf_counter()
    return parts, {
        "coarsen_s": t1 - t0,
        "refine_s": t2 - t1,
        "polish_s": t3 - t2,
    }


def _warm_partition(
    hg: Hypergraph, p: int, part_cap: float, labels: np.ndarray, drift_limit: float
) -> np.ndarray | None:
    """Warm-start K-way partition from a previous run's labels.

    ``labels`` is aligned to this hypergraph's vertices; entries outside
    ``[0, p)`` mark vertices the caller could not map from the old structure
    (new rows/mults after drift).  Unmapped vertices are placed
    heaviest-first onto the lightest part, then one ``kway_refine`` polish
    repairs the boundary the drift disturbed.  Returns ``None`` — caller
    falls back to cold partitioning — when drift exceeds ``drift_limit`` or
    the polished result is balance-infeasible (reusing labels would then
    cost more than it saves)."""
    labels = np.asarray(labels, dtype=np.int64).ravel()
    if labels.shape != (hg.n_vertices,):
        return None
    invalid = (labels < 0) | (labels >= p)
    if float(invalid.mean()) > drift_limit:
        return None
    parts = labels.copy()
    miss = np.flatnonzero(invalid)
    w = hg.w_comp.astype(np.float64)
    if len(miss):
        part_w = np.bincount(parts[~invalid], weights=w[~invalid], minlength=p)
        order = miss[np.argsort(-w[miss], kind="stable")]
        for v in order.tolist():
            t = int(np.argmin(part_w))
            parts[v] = t
            part_w[t] += w[v]
    parts = kway_refine(hg, parts, p, part_cap)
    part_w = np.bincount(parts, weights=w, minlength=p)
    if part_w.max() > part_cap + 1e-9:
        return None
    return parts


def partition(
    hg: Hypergraph,
    p: int,
    eps: float = 0.03,
    seed: int = 0,
    engine: str = "flat",
    warm_start: np.ndarray | None = None,
    warm_drift_limit: float = 0.5,
    coarsen: str = "auto",
) -> PartitionResult:
    """K-way partition via recursive bisection (+ a direct K-way pass).

    ``engine="flat"`` is the gain-bucket flat-CSR engine (``core/refine.py``).
    It shares one global V-cycle across the whole call: the fine hypergraph
    is clustered once, recursive bisection runs on the coarse graph (where
    its own inner cycles are nearly free), and each uncoarsening step is
    followed by the direct K-way boundary pass — so the per-move refinement
    never touches the finest graphs once per bisection the way the loop
    engine does.

    ``engine="loop"`` is the retained per-move reference implementation:
    recursive bisection directly on the fine hypergraph, re-coarsening each
    subproblem with pairwise matching.

    ``engine="device"`` keeps the whole V-cycle on device: coarsening
    (``core/coarsen_device.py``) and batched multi-start refinement
    (``core/refine_device.py``) run as jitted kernels per level, with only
    the final labels crossing back for the host polish.  ``coarsen``
    selects the descend: ``"auto"``/``"device"`` use the device kernels
    (degrading to host coarsening with a warning when unavailable),
    ``"host"`` forces the PR-6 host-scipy V-cycle.  Sizes at or below
    ``DEVICE_MIN_VERTICES`` use the flat quality path unchanged, and a
    missing (or failing) jax degrades to ``engine="flat"`` with a
    once-per-process warning.  Device results carry a ``phases`` dict
    (coarsen / refine / polish seconds).

    ``warm_start``: previous labels aligned to this hypergraph's vertices
    (entries outside ``[0, p)`` = unmapped after drift).  When reuse is
    viable (drift under ``warm_drift_limit`` and the polished result
    feasible) the returned result has ``warm=True`` and skipped the full
    multilevel search; otherwise cold partitioning runs with the requested
    engine.
    """
    from repro.core.comm import evaluate
    from repro.testing import faults

    faults.fire("partition")
    if engine not in ("flat", "loop", "device"):
        raise ValueError(f"unknown partition engine {engine!r}")
    if coarsen not in ("auto", "device", "host"):
        raise ValueError(f"unknown coarsen mode {coarsen!r}")
    if warm_start is not None and hg.n_vertices:
        if p == 1:
            parts = np.zeros(hg.n_vertices, dtype=np.int64)
            conn = evaluate(hg, parts, p).connectivity
            return PartitionResult(parts=parts, p=p, connectivity=conn, warm=True)
        total = float(hg.w_comp.sum())
        part_cap = max((1 + eps) * total / p, float(hg.w_comp.max()))
        parts = _warm_partition(hg, p, part_cap, warm_start, warm_drift_limit)
        if parts is not None:
            conn = evaluate(hg, parts, p).connectivity
            return PartitionResult(parts=parts, p=p, connectivity=conn, warm=True)
    if engine == "device":
        rd = None
        if hg.n_vertices > DEVICE_MIN_VERTICES and p > 1:
            try:
                rd = importlib.import_module("repro.core.refine_device")
            except ImportError:
                _warn_fallback(
                    "import",
                    "engine='device' needs jax; falling back to engine='flat'",
                )
        if rd is not None:
            total = float(hg.w_comp.sum())
            part_cap = max((1 + eps) * total / p, float(hg.w_comp.max()))
            try:
                parts, phases = _partition_device(
                    hg, p, part_cap, seed, rd, coarsen
                )
            except Exception as exc:
                # device-runtime failure (OOM, kernel error): the host flat
                # engine is the authoritative fallback, not a hard stop
                _warn_fallback(
                    "runtime",
                    f"engine='device' failed ({exc!r}); "
                    "falling back to engine='flat'",
                )
            else:
                conn = evaluate(hg, parts, p).connectivity
                return PartitionResult(
                    parts=parts, p=p, connectivity=conn, phases=phases
                )
        engine = "flat"
    rng = np.random.default_rng(seed)
    parts = np.zeros(hg.n_vertices, dtype=np.int64)
    if p > 1 and hg.n_vertices:
        # global per-part cap; heavy vertices can force violations (the paper
        # observes exactly this for 1D models on scale-free inputs, Sec. 6.3)
        total = float(hg.w_comp.sum())
        part_cap = max((1 + eps) * total / p, float(hg.w_comp.max()))
        if engine == "flat" and hg.n_vertices > SMALL_DIRECT:
            # speed path: one shared global V-cycle
            levels, cur = _global_vcycle(hg, p, part_cap)
            parts_cur = _recursive_bisection(
                cur, p, part_cap, rng, engine, multilevel=False
            )
            parts_cur = kway_refine(cur, parts_cur, p, part_cap)
            for fine, cmap in reversed(levels):
                parts_cur = parts_cur[cmap]
                parts_cur = kway_refine(fine, parts_cur, p, part_cap)
            parts = parts_cur
        elif engine == "flat":
            # quality path: full per-bisection multilevel + K-way pass, and
            # the engine is fast enough at this size to take the best of a
            # few independent starts (still deterministic for a fixed seed).
            # Starts rank by (balance feasibility, connectivity): a feasible
            # start always beats an infeasible one, however good its cut.
            best_key = None
            for _try in range(SMALL_STARTS):
                cand = _recursive_bisection(hg, p, part_cap, rng, engine)
                cand = kway_refine(hg, cand, p, part_cap, max_rounds=16)
                conn = evaluate(hg, cand, p).connectivity
                cand_w = np.bincount(cand, weights=hg.w_comp, minlength=p)
                infeasible = bool(cand_w.max() > part_cap + 1e-9)
                key = (infeasible, conn)
                if best_key is None or key < best_key:
                    best_key, parts = key, cand
        else:
            parts = _recursive_bisection(hg, p, part_cap, rng, engine)
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)


def partition_random(hg: Hypergraph, p: int, seed: int = 0) -> PartitionResult:
    """Balanced random partition (baseline)."""
    from repro.core.comm import evaluate

    rng = np.random.default_rng(seed)
    order = rng.permutation(hg.n_vertices)
    w = hg.w_comp[order].astype(np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if len(cum) else 1.0
    parts = np.empty(hg.n_vertices, dtype=np.int64)
    parts[order] = np.minimum((cum / total * p).astype(np.int64), p - 1)
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)


def partition_block(hg: Hypergraph, p: int) -> PartitionResult:
    """Contiguous block partition by vertex order balanced on w_comp (the
    'natural' ordering baseline)."""
    from repro.core.comm import evaluate

    w = hg.w_comp.astype(np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if len(cum) else 1.0
    parts = np.minimum((cum / total * p).astype(np.int64), p - 1)
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)
