"""Multilevel K-way hypergraph partitioner.

PaToH stand-in: recursive bisection with
  (1) heavy-connectivity vertex matching for coarsening (vectorized through a
      scipy sparse similarity product),
  (2) greedy BFS-style initial bisection under a compute-balance constraint,
  (3) boundary FM refinement with classic delta-gain updates, minimizing the
      connectivity metric sum_n c(n) * (lambda(n) - 1) (what PaToH minimizes,
      Sec. 6; for a bisection this equals the weighted cut),
subject to w_comp(V_i) <= (1 + eps) * W / p (Def. 4.4 with delta = p - 1,
matching the paper's experiments).

Engineering notes (documented, standard heuristics):
- nets larger than ``BIG_NET`` pins are ignored during matching and their
  delta-gain propagation is skipped (their contribution to gains is still
  counted when a vertex's gain is first computed); at the sizes we run,
  such nets are almost never uncuttable anyway.
- FM candidate set = vertices on cut nets, capped per pass.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np
import scipy.sparse as sp

from repro.core.hypergraph import Hypergraph, build_hypergraph_flat

BIG_NET = 96  # pins; nets above this are skipped in matching/gain updates
MAX_MOVES_PER_PASS = 1200
DEG_CAP = 2500  # vertices in more nets than this are not FM move candidates


@dataclasses.dataclass
class PartitionResult:
    parts: np.ndarray  # (n_vertices,) int64 part ids
    p: int
    connectivity: int  # final objective value


# ---------------------------------------------------------------------------
# coarsening
# ---------------------------------------------------------------------------
def _match_vertices(
    hg: Hypergraph, rng: np.random.Generator, max_weight: float
) -> np.ndarray:
    """Heavy-connectivity matching via a sparse similarity product:
    sim(u, v) = sum over shared (small) nets of c(n)/(|n| - 1).  Each vertex
    proposes its best partner (row argmax); proposals are granted greedily in
    descending-score order."""
    sizes = hg.net_sizes()
    ok = (sizes > 1) & (sizes <= BIG_NET)
    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), sizes)
    keep = ok[net_ids]
    rows, cols = net_ids[keep], hg.net_pins[keep]
    w = np.sqrt(hg.net_cost[rows].astype(np.float64) / np.maximum(sizes[rows] - 1, 1))
    W = sp.coo_matrix((w, (rows, cols)), shape=(hg.n_nets, hg.n_vertices)).tocsr()
    # drop the diagonal via an explicit COO filter: csr.setdiag(0) in scipy
    # 1.14 corrupts neighbouring entries when nearly the whole diagonal is
    # stored (stale offsets after _insert_many), leaving self-similarities
    # that make vertices match themselves
    S = (W.T @ W).tocoo()
    off_diag = S.row != S.col
    S = sp.csr_matrix(
        (S.data[off_diag], (S.row[off_diag], S.col[off_diag])), shape=S.shape
    )
    n = hg.n_vertices
    best = np.full(n, -1, dtype=np.int64)
    score = np.zeros(n, dtype=np.float64)
    indptr, indices, data = S.indptr, S.indices, S.data
    nz_rows = np.flatnonzero(np.diff(indptr) > 0)
    for v in nz_rows:
        lo, hi = indptr[v], indptr[v + 1]
        j = lo + np.argmax(data[lo:hi])
        best[v] = indices[j]
        score[v] = data[j]
    order = np.argsort(-score, kind="stable")
    match = np.full(n, -1, dtype=np.int64)
    wc = hg.w_comp
    for v in order:
        u = best[v]
        if u < 0 or score[v] <= 0:
            break
        if match[v] < 0 and match[u] < 0 and wc[u] + wc[v] <= max_weight:
            match[v] = u
            match[u] = v
    unmatched = match < 0
    coarse = np.full(n, -1, dtype=np.int64)
    # matched pairs get one id, singletons keep their own
    pair_lo = np.flatnonzero((match > np.arange(n)))
    k = 0
    coarse[pair_lo] = np.arange(len(pair_lo))
    coarse[match[pair_lo]] = coarse[pair_lo]
    k = len(pair_lo)
    singles = np.flatnonzero(unmatched)
    coarse[singles] = k + np.arange(len(singles))
    return coarse


def _coarsen(hg: Hypergraph, coarse: np.ndarray) -> tuple[Hypergraph, int]:
    """Contract vertices by ``coarse``; drop singletons (Sec. 5.1).

    Identical nets are NOT coalesced inside the V-cycle: duplicate nets yield
    exactly the same connectivity objective and FM gains (their costs add),
    so coalescing is a pure speed tradeoff — and the hashing dominated the
    profile.  ``hypergraph.coalesce_identical_nets`` stays available for the
    modeling API (Sec. 5.3/5.4 builders use summed costs directly)."""
    n_coarse = int(coarse.max()) + 1
    w_comp = np.bincount(coarse, weights=hg.w_comp, minlength=n_coarse).astype(np.int64)
    w_mem = np.bincount(coarse, weights=hg.w_mem, minlength=n_coarse).astype(np.int64)

    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), hg.net_sizes())
    pins = coarse[hg.net_pins]
    key = np.unique(net_ids * n_coarse + pins)
    net_ids, pins = key // n_coarse, key % n_coarse

    counts = np.bincount(net_ids, minlength=hg.n_nets)
    keep = counts[net_ids] > 1
    net_ids, pins = net_ids[keep], pins[keep]
    if len(net_ids) == 0:
        empty = np.empty(0, dtype=np.int64)
        return (
            build_hypergraph_flat(empty, empty, 0, n_coarse, w_comp, w_mem, empty),
            n_coarse,
        )
    uniq_nets, compact = np.unique(net_ids, return_inverse=True)
    return (
        build_hypergraph_flat(
            compact,
            pins,
            len(uniq_nets),
            n_coarse,
            w_comp,
            w_mem,
            hg.net_cost[uniq_nets],
        ),
        n_coarse,
    )


# ---------------------------------------------------------------------------
# initial bisection + FM refinement
# ---------------------------------------------------------------------------
def _initial_bisect(
    hg: Hypergraph, target0: float, rng: np.random.Generator
) -> np.ndarray:
    """Greedy net-BFS growth of side 0 up to ~target0 total compute weight."""
    n = hg.n_vertices
    side = np.ones(n, dtype=np.int8)
    ptr, vnets = hg.vertex_to_nets()
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    w = hg.w_comp.astype(np.float64)
    total0 = 0.0
    seed = int(rng.integers(n))
    frontier: deque[int] = deque([seed])
    seen = np.zeros(n, dtype=bool)
    seen[seed] = True
    n_seen = 1
    while total0 < target0:
        if not frontier:
            rest = np.flatnonzero(~seen)
            if not len(rest):
                break
            s = int(rest[rng.integers(len(rest))])
            seen[s] = True
            n_seen += 1
            frontier.append(s)
        v = frontier.popleft()
        if total0 + w[v] > target0 * 1.05 and total0 > 0:
            continue
        side[v] = 0
        total0 += w[v]
        for nid in vnets[ptr[v] : ptr[v + 1]]:
            pins = net_pins[net_ptr[nid] : net_ptr[nid + 1]]
            for u in pins:
                if not seen[u]:
                    seen[u] = True
                    n_seen += 1
                    frontier.append(u)
    return side


def _compute_counts(hg: Hypergraph, side: np.ndarray) -> np.ndarray:
    """(n_nets, 2) per-side pin counts."""
    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), hg.net_sizes())
    pin_side = side[hg.net_pins]
    cnt = np.zeros((hg.n_nets, 2), dtype=np.int64)
    cnt[:, 1] = np.bincount(net_ids, weights=pin_side, minlength=hg.n_nets)
    cnt[:, 0] = hg.net_sizes() - cnt[:, 1]
    return cnt


def _gains_for_all(hg: Hypergraph, side: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    """Vectorized FM gains for all vertices via two sparse matvecs:
    gain(v) = sum_{n in v} c(n)[cnt(n, side(v)) == 1] - c(n)[cnt(n, other) == 0]."""
    inc = hg.incidence()  # (n_nets, n_vertices) cached on the hypergraph
    cost = hg.net_cost.astype(np.float64)
    only0 = cost * (cnt[:, 0] == 1)
    only1 = cost * (cnt[:, 1] == 1)
    empty0 = cost * (cnt[:, 0] == 0)
    empty1 = cost * (cnt[:, 1] == 0)
    # per-vertex sums of each net quantity
    s_only0 = inc.T @ only0
    s_only1 = inc.T @ only1
    s_empty0 = inc.T @ empty0
    s_empty1 = inc.T @ empty1
    side_b = side.astype(bool)
    gains = np.where(side_b, s_only1 - s_empty0, s_only0 - s_empty1)
    return gains


def _fm_refine(
    hg: Hypergraph,
    side: np.ndarray,
    max_w: tuple[float, float],
    passes: int = 2,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Boundary FM with classic delta-gain updates and per-pass rollback."""
    rng = rng or np.random.default_rng(0)
    ptr, vnets = hg.vertex_to_nets()
    net_ptr, net_pins = hg.net_ptr, hg.net_pins
    cost = hg.net_cost.astype(np.float64)
    sizes = hg.net_sizes()
    small = sizes <= BIG_NET
    w = hg.w_comp.astype(np.float64)
    side = side.astype(np.int8).copy()

    for _pass in range(passes):
        cnt = _compute_counts(hg, side)
        side_w = np.array([w[side == 0].sum(), w[side == 1].sum()])
        cut = (cnt[:, 0] > 0) & (cnt[:, 1] > 0)
        if not cut.any():
            break
        all_gains = _gains_for_all(hg, side, cnt)
        # candidates: boundary vertices, best gains first (vectorized via the
        # per-pin net-id expansion)
        boundary = np.zeros(hg.n_vertices, dtype=bool)
        pin_cut = np.repeat(cut, sizes)
        boundary[net_pins[pin_cut]] = True
        deg = np.diff(ptr)
        cand = np.flatnonzero(boundary & (deg <= DEG_CAP))
        if len(cand) == 0:
            break
        if len(cand) > MAX_MOVES_PER_PASS:
            top = np.argsort(-all_gains[cand], kind="stable")[:MAX_MOVES_PER_PASS]
            cand = cand[top]
        pos_of = np.full(hg.n_vertices, -1, dtype=np.int64)
        pos_of[cand] = np.arange(len(cand))
        gains = all_gains[cand]
        locked = np.zeros(len(cand), dtype=bool)

        history: list[int] = []
        cum, best_cum, best_idx = 0.0, 0.0, -1
        NEG = -1e30
        g_work = gains.copy()
        for _move in range(len(cand)):
            g_masked = np.where(locked, NEG, g_work)
            # balance feasibility
            vs = cand
            s_arr = side[vs]
            feasible = side_w[1 - s_arr] + w[vs] <= np.array(max_w)[1 - s_arr]
            g_masked = np.where(feasible, g_masked, NEG)
            bi = int(np.argmax(g_masked))
            if g_masked[bi] <= NEG / 2:
                break
            bg = g_work[bi]
            v = int(cand[bi])
            s = int(side[v])
            t = 1 - s
            # --- apply move with vectorized delta-gain updates ---
            nets = vnets[ptr[v] : ptr[v + 1]]
            snets = nets[small[nets]]
            ct_before = cnt[snets, t]
            # rule 1: t-count was 0 -> all other free pins gain +c
            # rule 2: t-count was 1 -> the lone t-side free pin gains -c
            r1 = snets[ct_before == 0]
            r2 = snets[ct_before == 1]
            cnt[nets, s] -= 1
            cnt[nets, t] += 1
            cs_after = cnt[snets, s]
            # rule 3: s-count now 0 -> all other free pins gain -c
            # rule 4: s-count now 1 -> the lone s-side free pin gains +c
            r3 = snets[cs_after == 0]
            r4 = snets[cs_after == 1]

            def _apply(rule_nets, sign, side_filter):
                if len(rule_nets) == 0:
                    return
                pins = np.concatenate(
                    [net_pins[net_ptr[n] : net_ptr[n + 1]] for n in rule_nets]
                )
                cs = np.repeat(cost[rule_nets],
                               net_ptr[rule_nets + 1] - net_ptr[rule_nets])
                pu = pos_of[pins]
                m = (pu >= 0) & (pins != v)
                if side_filter is not None:
                    m &= side[pins] == side_filter
                pu = pu[m]
                m2 = ~locked[pu]
                np.add.at(g_work, pu[m2], sign * cs[m][m2])

            _apply(r1, +1.0, None)
            _apply(r2, -1.0, t)
            _apply(r3, -1.0, None)
            _apply(r4, +1.0, s)
            side[v] = t
            side_w[s] -= w[v]
            side_w[t] += w[v]
            locked[bi] = True
            history.append(v)
            cum += bg
            if cum > best_cum + 1e-9:
                best_cum, best_idx = cum, len(history) - 1
            if bg < 0 and len(history) - 1 - best_idx > 50:
                break  # hill-descent cutoff
        # rollback to best prefix
        for v in history[best_idx + 1 :]:
            s = int(side[v])
            side[v] = 1 - s
            side_w[s] -= w[v]
            side_w[1 - s] += w[v]
        if best_cum <= 0:
            break
    return side


def _bisect(
    hg: Hypergraph,
    k0: int,
    k1: int,
    part_cap: float,
    rng: np.random.Generator,
    coarsen_to: int = 160,
) -> np.ndarray:
    """Multilevel bisection into sides destined for k0 and k1 parts.

    ``part_cap`` is the GLOBAL maximum per-part weight (1+eps) * W_total / p;
    the side caps are k_side * part_cap so imbalance cannot compound down the
    recursion."""
    total = float(hg.w_comp.sum())
    frac0 = k0 / (k0 + k1)
    levels: list[tuple[Hypergraph, np.ndarray]] = []
    cur = hg
    heaviest = float(cur.w_comp.max()) if cur.n_vertices else 0.0
    while cur.n_vertices > coarsen_to:
        cmap = _match_vertices(cur, rng, max_weight=max(total / 10, heaviest))
        nxt, n_coarse = _coarsen(cur, cmap)
        if n_coarse >= cur.n_vertices * 0.95:  # matching stalled
            break
        levels.append((cur, cmap))
        cur = nxt

    max_w = (k0 * part_cap, k1 * part_cap)
    side = _initial_bisect(cur, min(total * frac0, max_w[0]), rng)
    side = _fm_refine(cur, side, max_w, rng=rng)
    for fine, cmap in reversed(levels):
        side = side[cmap]
        side = _fm_refine(fine, side, max_w, rng=rng)
    return side


def _restrict(hg: Hypergraph, mask: np.ndarray) -> tuple[Hypergraph, np.ndarray]:
    """Sub-hypergraph induced on ``mask`` vertices (nets restricted, singletons
    dropped).  Returns (sub, original-ids-of-sub-vertices)."""
    ids = np.flatnonzero(mask)
    remap = np.full(hg.n_vertices, -1, dtype=np.int64)
    remap[ids] = np.arange(len(ids))
    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), hg.net_sizes())
    keep = mask[hg.net_pins]
    net_ids = net_ids[keep]
    pins = remap[hg.net_pins[keep]]
    counts = np.bincount(net_ids, minlength=hg.n_nets)
    keep2 = counts[net_ids] > 1
    net_ids, pins = net_ids[keep2], pins[keep2]
    uniq, new_net = np.unique(net_ids, return_inverse=True)
    sub = build_hypergraph_flat(
        new_net,
        pins,
        len(uniq),
        len(ids),
        hg.w_comp[ids],
        hg.w_mem[ids],
        hg.net_cost[uniq],
    )
    return sub, ids


def partition(
    hg: Hypergraph,
    p: int,
    eps: float = 0.03,
    seed: int = 0,
) -> PartitionResult:
    """K-way partition via recursive bisection."""
    from repro.core.comm import evaluate

    rng = np.random.default_rng(seed)
    parts = np.zeros(hg.n_vertices, dtype=np.int64)
    if p > 1 and hg.n_vertices:
        # global per-part cap; heavy vertices can force violations (the paper
        # observes exactly this for 1D models on scale-free inputs, Sec. 6.3)
        part_cap = max(
            (1 + eps) * float(hg.w_comp.sum()) / p, float(hg.w_comp.max())
        )
        stack: list[tuple[Hypergraph, np.ndarray, int, int]] = [
            (hg, np.arange(hg.n_vertices), 0, p)
        ]
        while stack:
            sub, ids, lo, hi = stack.pop()
            k = hi - lo
            if k == 1:
                parts[ids] = lo
                continue
            k0 = k // 2
            side = _bisect(sub, k0, k - k0, part_cap, rng)
            for s, plo, phi in ((0, lo, lo + k0), (1, lo + k0, hi)):
                mask = side == s
                if not mask.any():
                    continue
                if phi - plo == 1:
                    parts[ids[mask]] = plo
                else:
                    ssub, sids = _restrict(sub, mask)
                    stack.append((ssub, ids[mask], plo, phi))
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)


def partition_random(hg: Hypergraph, p: int, seed: int = 0) -> PartitionResult:
    """Balanced random partition (baseline)."""
    from repro.core.comm import evaluate

    rng = np.random.default_rng(seed)
    order = rng.permutation(hg.n_vertices)
    w = hg.w_comp[order].astype(np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if len(cum) else 1.0
    parts = np.empty(hg.n_vertices, dtype=np.int64)
    parts[order] = np.minimum((cum / total * p).astype(np.int64), p - 1)
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)


def partition_block(hg: Hypergraph, p: int) -> PartitionResult:
    """Contiguous block partition by vertex order balanced on w_comp (the
    'natural' ordering baseline)."""
    from repro.core.comm import evaluate

    w = hg.w_comp.astype(np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if len(cum) else 1.0
    parts = np.minimum((cum / total * p).astype(np.int64), p - 1)
    conn = evaluate(hg, parts, p).connectivity
    return PartitionResult(parts=parts, p=p, connectivity=conn)
