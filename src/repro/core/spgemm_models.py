"""SpGEMM hypergraph model builders.

Implements Def. 3.1 (fine-grained) and the six coarsened models of Sec. 5:
row-wise (RrR, Ex. 5.1), column-wise, outer-product (CRf, Ex. 5.2),
monochrome-A (Frf, Ex. 5.3), monochrome-B, monochrome-C (ffF, Ex. 5.4).

``include_nz`` toggles the nonzero vertices V^nz.  The paper's experiments
(Sec. 6) set delta = p-1 (no memory balance) and omit V^nz; the lower-bound
machinery (Sec. 4) keeps them.  Net costs and computational weights follow the
Examples exactly.

Vertex kinds: 0 = multiplication/coarsened-mult, 1/2/3 = A/B/C nonzero vertex.
Net kinds: 1/2/3 = A/B/C nets.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.hypergraph import Hypergraph, build_hypergraph_flat
from repro.sparse.structure import (
    SparseStructure,
    nontrivial_multiplications,
    spgemm_symbolic,
)

MODELS = (
    "fine",
    "rowwise",
    "columnwise",
    "outer",
    "monoA",
    "monoB",
    "monoC",
)

# 1D models per the paper's classification (Sec. 5.2)
MODELS_1D = ("rowwise", "columnwise", "outer")
MODELS_2D = ("monoA", "monoB", "monoC")


def _lin_lookup(struct: SparseStructure, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Vectorized (row, col) -> CSR nonzero position lookup."""
    n_cols = struct.shape[1]
    r, c = struct.coo()
    lin_sorted = r * n_cols + c  # CSR order is sorted by (row, col)
    query = rows * n_cols + cols
    pos = np.searchsorted(lin_sorted, query)
    # out-of-range queries return len(lin_sorted); clip before the gather so
    # they fail the membership check below instead of raising IndexError
    safe = np.minimum(pos, max(len(lin_sorted) - 1, 0))
    if not len(lin_sorted) or not np.array_equal(lin_sorted[safe], query):
        if len(query):
            raise KeyError("query coordinates not all nonzero")
    return pos.astype(np.int64)


def _csc_to_csr_pos(struct: SparseStructure) -> tuple[np.ndarray, np.ndarray]:
    """Return (csc indptr, csr-position-per-csc-entry): lets the by-column
    iteration of the multiplication space recover CSR nonzero ids."""
    import scipy.sparse as sp

    csr = struct.csr
    tagged = sp.csr_matrix(
        (np.arange(csr.nnz, dtype=np.int64), csr.indices, csr.indptr),
        shape=csr.shape,
    )
    csc = tagged.tocsc()
    return csc.indptr.astype(np.int64), csc.data.astype(np.int64)


class SpGEMMInstance:
    """A (S_A, S_B) pair with the derived quantities every model needs."""

    def __init__(self, a: SparseStructure, b: SparseStructure, name: str = ""):
        if a.shape[1] != b.shape[0]:
            raise ValueError("inner dimensions disagree")
        self.a, self.b, self.name = a, b, name
        self.c = spgemm_symbolic(a, b)
        self.mult_i, self.mult_k, self.mult_j = nontrivial_multiplications(a, b)
        self.n_mult = len(self.mult_i)

    @classmethod
    def from_operands(cls, A, B, name: str = "") -> "SpGEMMInstance":
        """Build an instance from anything structure-shaped: dense arrays,
        scipy sparse matrices, or ``SparseStructure`` objects (values, if
        present, are ignored — the inspector is structure-only).  This is
        what ``repro.plan`` calls."""
        from repro.sparse.structure import as_structure

        return cls(as_structure(A), as_structure(B), name=name)

    @property
    def shape(self) -> tuple[int, int, int]:
        return self.a.shape[0], self.a.shape[1], self.b.shape[1]

    # -- plan-facing accessors (cached) ------------------------------------
    # The model builders and the plan IR both need the multiplication space
    # expressed in CSR nonzero ids and A in CSC form; cache them so the
    # inspector does each index computation once per instance.
    @functools.cached_property
    def a_csc(self):
        """A in CSC form (column-major iteration of the multiplication space)."""
        return self.a.tocsc()

    @functools.cached_property
    def mult_a_pos(self) -> np.ndarray:
        """CSR nonzero id of a_ik for every multiplication triple."""
        return _lin_lookup(self.a, self.mult_i, self.mult_k)

    @functools.cached_property
    def mult_b_pos(self) -> np.ndarray:
        """CSR nonzero id of b_kj for every multiplication triple."""
        return _lin_lookup(self.b, self.mult_k, self.mult_j)

    @functools.cached_property
    def mult_c_pos(self) -> np.ndarray:
        """CSR nonzero id of c_ij for every multiplication triple."""
        return _lin_lookup(self.c, self.mult_i, self.mult_j)

    def stats(self) -> dict:
        """Table II row."""
        I, K, J = self.shape
        return {
            "name": self.name,
            "I": I,
            "K": K,
            "J": J,
            "nnzA_per_row": self.a.nnz / I,
            "nnzB_per_row": self.b.nnz / K,
            "nnzC_per_row": self.c.nnz / I,
            "mult_per_C_nnz": self.n_mult / max(self.c.nnz, 1),
        }


def build_model(inst: SpGEMMInstance, model: str, include_nz: bool = False) -> Hypergraph:
    if model not in MODELS:
        raise ValueError(f"unknown model {model!r}; choose from {MODELS}")
    return globals()[f"_build_{model}"](inst, include_nz)


# ---------------------------------------------------------------------------
# Fine-grained (Def. 3.1)
# ---------------------------------------------------------------------------
def _build_fine(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    M = inst.n_mult
    nA, nB, nC = a.nnz, b.nnz, c.nnz

    # net ids: A nets [0, nA), B nets [nA, nA+nB), C nets [nA+nB, nA+nB+nC)
    a_pos = inst.mult_a_pos
    b_pos = inst.mult_b_pos
    c_pos = inst.mult_c_pos

    mult_ids = np.arange(M, dtype=np.int64)
    net_ids = [a_pos, nA + b_pos, nA + nB + c_pos]
    pin_vs = [mult_ids, mult_ids, mult_ids]

    n_vertices = M
    if include_nz:
        vA = M + np.arange(nA, dtype=np.int64)
        vB = M + nA + np.arange(nB, dtype=np.int64)
        vC = M + nA + nB + np.arange(nC, dtype=np.int64)
        net_ids += [
            np.arange(nA, dtype=np.int64),
            nA + np.arange(nB, dtype=np.int64),
            nA + nB + np.arange(nC, dtype=np.int64),
        ]
        pin_vs += [vA, vB, vC]
        n_vertices = M + nA + nB + nC

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    w_comp[:M] = 1
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    if include_nz:
        w_mem[M:] = 1

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[M : M + nA] = 1
        vertex_kind[M + nA : M + nA + nB] = 2
        vertex_kind[M + nA + nB :] = 3
    net_kind = np.concatenate(
        [
            np.full(nA, 1, dtype=np.int8),
            np.full(nB, 2, dtype=np.int8),
            np.full(nC, 3, dtype=np.int8),
        ]
    )
    return build_hypergraph_flat(
        np.concatenate(net_ids),
        np.concatenate(pin_vs),
        nA + nB + nC,
        n_vertices,
        w_comp,
        w_mem,
        np.ones(nA + nB + nC, dtype=np.int64),
        vertex_kind=vertex_kind,
        net_kind=net_kind,
        name=f"fine({inst.name})",
    )


# ---------------------------------------------------------------------------
# 1D: row-wise (RrR), Ex. 5.1
# ---------------------------------------------------------------------------
def _build_rowwise(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    b_row_nnz = b.row_counts()
    # vertices: v_i (i in [I]) [+ v^B_k]
    n_vertices = I + (K if include_nz else 0)
    # nets: n^B_k = {v_i : (i,k) in S_A} [+ {v^B_k}]; cost = nnz(B row k)
    acsc = inst.a_csc
    net_ids = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
    pin_vs = acsc.indices.astype(np.int64)
    if include_nz:
        net_ids = np.concatenate([net_ids, np.arange(K, dtype=np.int64)])
        pin_vs = np.concatenate([pin_vs, I + np.arange(K, dtype=np.int64)])

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    # flops of row i = sum_{k in A row i} nnz(B row k)
    row_flops = a.csr.astype(np.int64) @ b_row_nnz
    w_comp[:I] = row_flops
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    w_mem[:I] = a.row_counts() + c.row_counts()
    if include_nz:
        w_mem[I:] = b_row_nnz

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[I:] = 2
    return build_hypergraph_flat(
        net_ids,
        pin_vs,
        K,
        n_vertices,
        w_comp,
        w_mem,
        b_row_nnz.astype(np.int64),
        vertex_kind=vertex_kind,
        net_kind=np.full(K, 2, dtype=np.int8),
        name=f"rowwise({inst.name})",
    )


# ---------------------------------------------------------------------------
# 1D: column-wise (symmetric to row-wise via C^T = B^T A^T)
# ---------------------------------------------------------------------------
def _build_columnwise(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    a_col_nnz = a.col_counts()
    # vertices: v_j (j in [J]) [+ v^A_k (columns of A)]
    n_vertices = J + (K if include_nz else 0)
    # nets: n^A_k = {v_j : (k,j) in S_B} [+ {v^A_k}]; cost = nnz(A col k)
    bcsr = b.csr
    net_ids = np.repeat(np.arange(K, dtype=np.int64), np.diff(bcsr.indptr))
    pin_vs = bcsr.indices.astype(np.int64)
    if include_nz:
        net_ids = np.concatenate([net_ids, np.arange(K, dtype=np.int64)])
        pin_vs = np.concatenate([pin_vs, J + np.arange(K, dtype=np.int64)])

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    col_flops = b.csr.T.astype(np.int64) @ a_col_nnz  # per column j of B
    w_comp[:J] = np.asarray(col_flops).ravel()
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    w_mem[:J] = b.col_counts() + c.col_counts()
    if include_nz:
        w_mem[J:] = a_col_nnz

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[J:] = 1
    return build_hypergraph_flat(
        net_ids,
        pin_vs,
        K,
        n_vertices,
        w_comp,
        w_mem,
        a_col_nnz.astype(np.int64),
        vertex_kind=vertex_kind,
        net_kind=np.full(K, 1, dtype=np.int8),
        name=f"columnwise({inst.name})",
    )


# ---------------------------------------------------------------------------
# 1D: outer-product (CRf), Ex. 5.2
# ---------------------------------------------------------------------------
def _build_outer(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    nC = c.nnz
    # vertices: v_k [+ v^C_ij]
    n_vertices = K + (nC if include_nz else 0)
    # nets: n^C_ij = {v_k : contributes to (i,j)} [+ {v^C_ij}]; cost 1.
    c_pos = inst.mult_c_pos
    # dedupe (k contributes once per (i,j) even though pins derive from mults)
    pair = c_pos * K + inst.mult_k
    uniq = np.unique(pair)
    net_ids = uniq // K
    pin_vs = uniq % K
    if include_nz:
        net_ids = np.concatenate([net_ids, np.arange(nC, dtype=np.int64)])
        pin_vs = np.concatenate([pin_vs, K + np.arange(nC, dtype=np.int64)])

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    w_comp[:K] = a.col_counts() * b.row_counts()
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    w_mem[:K] = a.col_counts() + b.row_counts()
    if include_nz:
        w_mem[K:] = 1

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[K:] = 3
    return build_hypergraph_flat(
        net_ids,
        pin_vs,
        nC,
        n_vertices,
        w_comp,
        w_mem,
        np.ones(nC, dtype=np.int64),
        vertex_kind=vertex_kind,
        net_kind=np.full(nC, 3, dtype=np.int8),
        name=f"outer({inst.name})",
    )


# ---------------------------------------------------------------------------
# 2D: monochrome-A (Frf), Ex. 5.3
# ---------------------------------------------------------------------------
def _build_monoA(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    nA, nC = a.nnz, c.nnz
    b_row_nnz = b.row_counts()
    # vertices: v_ik ((i,k) in S_A) [+ v^B_k + v^C_ij]
    n_vertices = nA + ((K + nC) if include_nz else 0)

    # nets n^B_k = {v_ik : (i,k) in S_A}, cost nnz(B row k)
    csc_ptr, csr_pos = _csc_to_csr_pos(a)
    netB_ids = np.repeat(np.arange(K, dtype=np.int64), np.diff(csc_ptr))
    netB_pins = csr_pos
    # nets n^C_ij = {v_ik : k contributes to (i,j)}, cost 1 — from mult triples
    a_pos = inst.mult_a_pos
    c_pos = inst.mult_c_pos
    netC_ids = K + c_pos
    netC_pins = a_pos

    net_ids = [netB_ids, netC_ids]
    pin_vs = [netB_pins, netC_pins]
    if include_nz:
        net_ids += [np.arange(K, dtype=np.int64), K + np.arange(nC, dtype=np.int64)]
        pin_vs += [
            nA + np.arange(K, dtype=np.int64),
            nA + K + np.arange(nC, dtype=np.int64),
        ]

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    ar, ac = a.coo()
    w_comp[:nA] = b_row_nnz[ac]
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    w_mem[:nA] = 1
    if include_nz:
        w_mem[nA : nA + K] = b_row_nnz
        w_mem[nA + K :] = 1

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[nA : nA + K] = 2
        vertex_kind[nA + K :] = 3
    net_cost = np.concatenate([b_row_nnz.astype(np.int64), np.ones(nC, dtype=np.int64)])
    net_kind = np.concatenate([np.full(K, 2, dtype=np.int8), np.full(nC, 3, dtype=np.int8)])
    return build_hypergraph_flat(
        np.concatenate(net_ids),
        np.concatenate(pin_vs),
        K + nC,
        n_vertices,
        w_comp,
        w_mem,
        net_cost,
        vertex_kind=vertex_kind,
        net_kind=net_kind,
        name=f"monoA({inst.name})",
    )


# ---------------------------------------------------------------------------
# 2D: monochrome-B (symmetric to monochrome-A)
# ---------------------------------------------------------------------------
def _build_monoB(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    nB, nC = b.nnz, c.nnz
    a_col_nnz = a.col_counts()
    # vertices: v_kj ((k,j) in S_B) [+ v^A_k (col) + v^C_ij]
    n_vertices = nB + ((K + nC) if include_nz else 0)

    # nets n^A_k = {v_kj : (k,j) in S_B}, cost nnz(A col k) — rows of B
    bcsr = b.csr
    netA_ids = np.repeat(np.arange(K, dtype=np.int64), np.diff(bcsr.indptr))
    netA_pins = np.arange(nB, dtype=np.int64)  # CSR order groups by row k
    # nets n^C_ij = {v_kj : k contributes}, cost 1
    b_pos = inst.mult_b_pos
    c_pos = inst.mult_c_pos
    netC_ids = K + c_pos
    netC_pins = b_pos

    net_ids = [netA_ids, netC_ids]
    pin_vs = [netA_pins, netC_pins]
    if include_nz:
        net_ids += [np.arange(K, dtype=np.int64), K + np.arange(nC, dtype=np.int64)]
        pin_vs += [
            nB + np.arange(K, dtype=np.int64),
            nB + K + np.arange(nC, dtype=np.int64),
        ]

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    br, bc = b.coo()
    w_comp[:nB] = a_col_nnz[br]
    w_mem = np.zeros(n_vertices, dtype=np.int64)
    w_mem[:nB] = 1
    if include_nz:
        w_mem[nB : nB + K] = a_col_nnz
        w_mem[nB + K :] = 1

    vertex_kind = np.zeros(n_vertices, dtype=np.int8)
    if include_nz:
        vertex_kind[nB : nB + K] = 1
        vertex_kind[nB + K :] = 3
    net_cost = np.concatenate([a_col_nnz.astype(np.int64), np.ones(nC, dtype=np.int64)])
    net_kind = np.concatenate([np.full(K, 1, dtype=np.int8), np.full(nC, 3, dtype=np.int8)])
    return build_hypergraph_flat(
        np.concatenate(net_ids),
        np.concatenate(pin_vs),
        K + nC,
        n_vertices,
        w_comp,
        w_mem,
        net_cost,
        vertex_kind=vertex_kind,
        net_kind=net_kind,
        name=f"monoB({inst.name})",
    )


# ---------------------------------------------------------------------------
# 2D: monochrome-C (ffF), Ex. 5.4
# ---------------------------------------------------------------------------
def _build_monoC(inst: SpGEMMInstance, include_nz: bool) -> Hypergraph:
    a, b, c = inst.a, inst.b, inst.c
    I, K, J = inst.shape
    nA, nB, nC = a.nnz, b.nnz, c.nnz
    # vertices: v_ij ((i,j) in S_C) [+ v^A_ik + v^B_kj]
    n_vertices = nC + ((nA + nB) if include_nz else 0)

    a_pos = inst.mult_a_pos
    b_pos = inst.mult_b_pos
    c_pos = inst.mult_c_pos
    # nets n^A_ik = {v_ij : (k,j) in S_B}, cost 1 (dedupe per (ik, ij))
    pairA = np.unique(a_pos * nC + c_pos)
    netA_ids, netA_pins = pairA // nC, pairA % nC
    # nets n^B_kj = {v_ij : (i,k) in S_A}, cost 1
    pairB = np.unique(b_pos * nC + c_pos)
    netB_ids, netB_pins = pairB // nC, pairB % nC

    net_ids = [netA_ids, nA + netB_ids]
    pin_vs = [netA_pins, netB_pins]
    if include_nz:
        net_ids += [np.arange(nA, dtype=np.int64), nA + np.arange(nB, dtype=np.int64)]
        pin_vs += [
            nC + np.arange(nA, dtype=np.int64),
            nC + nA + np.arange(nB, dtype=np.int64),
        ]

    w_comp = np.zeros(n_vertices, dtype=np.int64)
    w_comp[:nC] = np.bincount(c_pos, minlength=nC)  # k-count per (i,j)
    w_mem = np.ones(n_vertices, dtype=np.int64) if include_nz else np.zeros(
        n_vertices, dtype=np.int64
    )
    if not include_nz:
        w_mem[:nC] = 1

    vertex_kind = np.full(n_vertices, 3, dtype=np.int8)
    vertex_kind[:nC] = 0  # coarsened mult+C vertices
    if include_nz:
        vertex_kind[nC : nC + nA] = 1
        vertex_kind[nC + nA :] = 2
    net_kind = np.concatenate([np.full(nA, 1, dtype=np.int8), np.full(nB, 2, dtype=np.int8)])
    return build_hypergraph_flat(
        np.concatenate(net_ids),
        np.concatenate(pin_vs),
        nA + nB,
        n_vertices,
        w_comp,
        w_mem,
        np.ones(nA + nB, dtype=np.int64),
        vertex_kind=vertex_kind,
        net_kind=net_kind,
        name=f"monoC({inst.name})",
    )
