"""Vertex coarsening (Sec. 5.1) and the derived special models.

- ``coarsen_vertices``: the generic monochrome-set contraction with net
  membership update, weight summation, net coalescing and singleton removal.
- SpMV specializations (Sec. 5.5): column-net (row-wise SpMV), row-net
  (column-wise SpMV), and the Çatalyürek–Aykanat fine-grain model.
- Generalizations (Sec. 5.6): symmetric-input coarsening and masked SpGEMM.
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph import Hypergraph, build_hypergraph_flat
from repro.core.spgemm_models import SpGEMMInstance, _build_fine, _lin_lookup
from repro.sparse.structure import SparseStructure, from_coo


def coarsen_vertices(
    hg: Hypergraph,
    coarse_of: np.ndarray,
    unit_mem: bool = False,
    unit_comp: bool = False,
    drop_singletons: bool = True,
) -> Hypergraph:
    """Contract vertices according to ``coarse_of`` (vertex -> coarse id).

    Weights sum by default (Sec. 5.1); ``unit_mem``/``unit_comp`` clamp
    coarse weights to min(w, 1) — the Sec. 5.6.1 variant where coarsening
    models *deduplication* (store/compute once) rather than co-location.
    Coalesced nets are combined (cost = summed, or kept if dedup semantics).
    """
    n_coarse = int(coarse_of.max()) + 1
    w_comp = np.bincount(coarse_of, weights=hg.w_comp, minlength=n_coarse).astype(
        np.int64
    )
    w_mem = np.bincount(coarse_of, weights=hg.w_mem, minlength=n_coarse).astype(
        np.int64
    )
    if unit_comp:
        w_comp = np.minimum(w_comp, 1)
    if unit_mem:
        w_mem = np.minimum(w_mem, 1)

    net_ids = np.repeat(np.arange(hg.n_nets, dtype=np.int64), hg.net_sizes())
    pins = coarse_of[hg.net_pins]
    key = np.unique(net_ids * n_coarse + pins)
    net_ids, pins = key // n_coarse, key % n_coarse

    if drop_singletons:
        counts = np.bincount(net_ids, minlength=hg.n_nets)
        keep = counts[net_ids] > 1
        net_ids, pins = net_ids[keep], pins[keep]

    # coalesce identical nets
    order = np.lexsort((pins, net_ids))
    net_ids, pins = net_ids[order], pins[order]
    uniq_nets, start = np.unique(net_ids, return_index=True)
    end = np.append(start[1:], len(net_ids))
    sig: dict[bytes, int] = {}
    out_cost: list[int] = []
    out_kind: list[int] = []
    out_ids: list[np.ndarray] = []
    out_pins: list[np.ndarray] = []
    has_kind = hg.net_kind is not None
    for idx in range(len(uniq_nets)):
        s, e = start[idx], end[idx]
        k = pins[s:e].tobytes()
        c = int(hg.net_cost[uniq_nets[idx]])
        if k in sig:
            out_cost[sig[k]] += 0 if (unit_mem or unit_comp) else c
            continue
        sig[k] = len(out_cost)
        out_cost.append(c)
        if has_kind:
            out_kind.append(int(hg.net_kind[uniq_nets[idx]]))
        out_ids.append(np.full(e - s, sig[k], dtype=np.int64))
        out_pins.append(pins[s:e])
    if not out_ids:
        empty = np.empty(0, dtype=np.int64)
        return build_hypergraph_flat(
            empty, empty, 0, n_coarse, w_comp, w_mem, empty, name=hg.name + "+coarse"
        )
    return build_hypergraph_flat(
        np.concatenate(out_ids),
        np.concatenate(out_pins),
        len(out_cost),
        n_coarse,
        w_comp,
        w_mem,
        np.array(out_cost, dtype=np.int64),
        net_kind=np.array(out_kind, dtype=np.int8) if has_kind else None,
        name=hg.name + "+coarse",
    )


# ---------------------------------------------------------------------------
# SpMV (Sec. 5.5)
# ---------------------------------------------------------------------------
def spmv_column_net(a: SparseStructure) -> Hypergraph:
    """Column-net model (row-wise SpMV): vertex per matrix row, net per
    column; identical to row-wise SpGEMM (Ex. 5.1) with a dense vector B,
    minus B-vertices and memory weights."""
    I, K = a.shape
    acsc = a.tocsc()
    net_ids = np.repeat(np.arange(K, dtype=np.int64), np.diff(acsc.indptr))
    return build_hypergraph_flat(
        net_ids,
        acsc.indices.astype(np.int64),
        K,
        I,
        a.row_counts().astype(np.int64),
        np.zeros(I, dtype=np.int64),
        np.ones(K, dtype=np.int64),
        name="spmv-colnet",
    )


def spmv_row_net(a: SparseStructure) -> Hypergraph:
    """Row-net model (column-wise SpMV): vertex per column, net per row."""
    I, K = a.shape
    net_ids = np.repeat(np.arange(I, dtype=np.int64), np.diff(a.csr.indptr))
    return build_hypergraph_flat(
        net_ids,
        a.indices.astype(np.int64),
        I,
        K,
        a.col_counts().astype(np.int64),
        np.zeros(K, dtype=np.int64),
        np.ones(I, dtype=np.int64),
        name="spmv-rownet",
    )


def spmv_fine_grain(a: SparseStructure) -> Hypergraph:
    """Çatalyürek–Aykanat fine-grain SpMV model (square A): one vertex per
    nonzero (+ dummy diagonal vertices), one net per row and per column,
    derived exactly as Sec. 5.5 prescribes: monochrome-A coarsening of the
    SpGEMM hypergraph with a dense vector, then diagonal symmetrization."""
    I, K = a.shape
    if I != K:
        raise ValueError("fine-grain SpMV model assumes square A")
    nA = a.nnz
    r, c = a.coo()
    has_diag = np.zeros(I, dtype=bool)
    diag_pos = np.full(I, -1, dtype=np.int64)
    on_diag = r == c
    has_diag[r[on_diag]] = True
    diag_pos[r[on_diag]] = np.flatnonzero(on_diag)
    n_dummy = int((~has_diag).sum())
    # vertex ids: nonzeros [0, nA), dummies for missing diagonals after that
    dummy_of = np.full(I, -1, dtype=np.int64)
    dummy_of[~has_diag] = nA + np.arange(n_dummy)
    vertex_of_diag = np.where(has_diag, diag_pos, dummy_of)
    n_vertices = nA + n_dummy

    # row nets (fold: output entries) and column nets (expand: input entries)
    row_net = np.repeat(np.arange(I, dtype=np.int64), a.row_counts())
    col_net = I + c
    # each diagonal-vertex also belongs to its row and column net
    net_ids = np.concatenate([row_net, col_net, np.arange(I), I + np.arange(I)])
    pin_vs = np.concatenate(
        [np.arange(nA), np.arange(nA), vertex_of_diag, vertex_of_diag]
    )
    # dedupe (diagonal nonzeros appear twice)
    key = np.unique(net_ids * n_vertices + pin_vs)
    net_ids, pin_vs = key // n_vertices, key % n_vertices

    w_comp = np.concatenate(
        [np.ones(nA, dtype=np.int64), np.zeros(n_dummy, dtype=np.int64)]
    )
    w_mem = np.ones(n_vertices, dtype=np.int64)
    w_mem[:nA] = 1
    w_mem[vertex_of_diag] += 2  # owns x_i and y_i  (w_mem 3 if diag nz else 2)
    w_mem[vertex_of_diag[~has_diag]] -= 1  # dummies: no matrix entry
    return build_hypergraph_flat(
        net_ids,
        pin_vs,
        2 * I,
        n_vertices,
        w_comp,
        w_mem,
        np.ones(2 * I, dtype=np.int64),
        name="spmv-finegrain",
    )


# ---------------------------------------------------------------------------
# Masked SpGEMM (Sec. 5.6.2)
# ---------------------------------------------------------------------------
def masked_fine_grained(inst: SpGEMMInstance, mask: SparseStructure) -> Hypergraph:
    """Fine-grained hypergraph restricted to C entries in ``mask``: removes
    masked C nets and their multiplication vertices, then drops A/B nets that
    became singletons (entries no longer used)."""
    keep_c = mask.csr.multiply(inst.c.csr)  # S = S_C ∩ S_M
    s = SparseStructure.wrap(keep_c)
    # which multiplications survive
    c_pos_all = _lin_lookup(inst.c, inst.mult_i, inst.mult_j)
    r, c = inst.c.coo()
    surviving_c = np.zeros(inst.c.nnz, dtype=bool)
    sr, sc = s.coo()
    lin_c = r * inst.c.shape[1] + c
    lin_s = sr * inst.c.shape[1] + sc
    surviving_c[np.searchsorted(lin_c, lin_s)] = True
    keep_mult = surviving_c[c_pos_all]

    sub = SpGEMMInstance.__new__(SpGEMMInstance)
    sub.a, sub.b, sub.name = inst.a, inst.b, inst.name + "+mask"
    sub.c = s
    sub.mult_i = inst.mult_i[keep_mult]
    sub.mult_k = inst.mult_k[keep_mult]
    sub.mult_j = inst.mult_j[keep_mult]
    sub.n_mult = int(keep_mult.sum())
    hg = _build_fine(sub, include_nz=True)
    from repro.core.hypergraph import remove_singleton_nets

    return remove_singleton_nets(hg)


# ---------------------------------------------------------------------------
# Symmetric-input coarsening (Sec. 5.6.1, equality relation A = A^T)
# ---------------------------------------------------------------------------
def symmetric_input_coarse_map(inst: SpGEMMInstance) -> np.ndarray:
    """For A = A^T: group each off-diagonal pair (v^A_ik, v^A_ki) into one
    coarse vertex (store one copy).  Returns a coarse map over the
    fine-grained hypergraph *with* nz vertices."""
    a = inst.a
    M = inst.n_mult
    nA, nB, nC = a.nnz, inst.b.nnz, inst.c.nnz
    n = M + nA + nB + nC
    coarse = np.arange(n, dtype=np.int64)
    r, c = a.coo()
    # pair (i,k) with (k,i): map the higher CSR position onto the lower
    upper = r < c
    rows_u, cols_u = r[upper], c[upper]
    pos_u = _lin_lookup(a, rows_u, cols_u)
    pos_l = _lin_lookup(a, cols_u, rows_u)
    coarse[M + pos_u] = M + pos_l
    # compact ids
    _, coarse = np.unique(coarse, return_inverse=True)
    return coarse
