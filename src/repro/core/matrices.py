"""Matrix generators for the paper's three applications (Sec. 6).

- AMG model problem (Sec. 6.1): 27-point stencil A1 on an N^3 grid plus a
  smoothed-aggregation prolongator P (3x3x3 aggregates, damped-Jacobi
  smoothing => structure of (I - w D^-1 A) P0 = structure of P0 + A@P0).
- SA-rhoAMGe-like (Sec. 6.1): ~35x coarsening with a polynomial (degree-2)
  smoother => denser P.
- LP normal equations (Sec. 6.2): staircase/multicommodity-flow-like
  constraint matrices A (I < K), SpGEMM is A @ A^T (D^2 is diagonal, no
  structural effect).
- MCL (Sec. 6.3): squaring adjacency structures — scale-free
  (Barabási–Albert, social/protein-like) and a road-network-like grid.

All generators are structure-only and deterministic given a seed.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from repro.sparse.structure import SparseStructure, from_coo, spgemm_symbolic
from repro.core.spgemm_models import SpGEMMInstance


# ---------------------------------------------------------------------------
# AMG (Sec. 6.1)
# ---------------------------------------------------------------------------
def stencil27(n: int) -> SparseStructure:
    """27-point stencil on an n x n x n grid (row per grid point)."""
    idx = np.arange(n**3).reshape(n, n, n)
    rows, cols = [], []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                src = idx[
                    max(0, -dx) : n - max(0, dx),
                    max(0, -dy) : n - max(0, dy),
                    max(0, -dz) : n - max(0, dz),
                ]
                dst = idx[
                    max(0, dx) : n - max(0, -dx),
                    max(0, dy) : n - max(0, -dy),
                    max(0, dz) : n - max(0, -dz),
                ]
                rows.append(src.ravel())
                cols.append(dst.ravel())
    return from_coo(np.concatenate(rows), np.concatenate(cols), (n**3, n**3))


def tentative_prolongator(n: int, agg: int = 3) -> SparseStructure:
    """P0: each agg^3 sub-cube aggregates to one coarse point."""
    if n % agg:
        raise ValueError(f"n={n} not divisible by agg={agg}")
    nc = n // agg
    fine = np.arange(n**3)
    x, y, z = np.unravel_index(fine, (n, n, n))
    coarse = (x // agg) * nc * nc + (y // agg) * nc + (z // agg)
    return from_coo(fine, coarse, (n**3, nc**3))


def smoothed_prolongator(
    a: SparseStructure, p0: SparseStructure, degree: int = 1
) -> SparseStructure:
    """Structure of (I - w D^-1 A)^degree @ P0 (smoothed aggregation)."""
    cur = p0
    for _ in range(degree):
        cur = SparseStructure.wrap(
            (a.csr.astype(np.int8) @ cur.csr.astype(np.int8)) + cur.csr.astype(np.int8)
        )
    return cur


def amg_instances(n: int, flavor: str = "model") -> tuple[SpGEMMInstance, SpGEMMInstance]:
    """The two SpGEMMs of one Galerkin triple product: A@P and P^T@(AP).

    flavor='model': 27-pt + degree-1 smoothing, 3x3x3 aggregates (27-AP rows
    of Tab. II).  flavor='sa_rho': degree-2 smoothing (denser, SA-rho-like).
    """
    a = stencil27(n)
    if flavor == "model":
        p = smoothed_prolongator(a, tentative_prolongator(n, 3), degree=1)
        tag = "27"
    elif flavor == "sa_rho":
        p = smoothed_prolongator(a, tentative_prolongator(n, 3), degree=2)
        tag = "SA"
    else:
        raise ValueError(flavor)
    ap = spgemm_symbolic(a, p)
    inst1 = SpGEMMInstance(a, p, name=f"{tag}-AP(n={n})")
    inst2 = SpGEMMInstance(p.transpose(), ap, name=f"{tag}-PTAP(n={n})")
    return inst1, inst2


def geometric_row_partition(n: int, p: int) -> np.ndarray:
    """Geometric partition of grid rows into p ~cubical subdomains (the
    'Geometric-row' baseline of Fig. 7).  p need not be a cube; we factor it
    into three near-equal factors."""
    f = _factor3(p)
    bounds = [np.linspace(0, n, fi + 1).astype(int) for fi in f]
    part_of = np.empty(n**3, dtype=np.int64)
    x, y, z = np.unravel_index(np.arange(n**3), (n, n, n))
    px = np.searchsorted(bounds[0], x, side="right") - 1
    py = np.searchsorted(bounds[1], y, side="right") - 1
    pz = np.searchsorted(bounds[2], z, side="right") - 1
    part_of[:] = (px * f[1] + py) * f[2] + pz
    return part_of


def _factor3(p: int) -> tuple[int, int, int]:
    best = (1, 1, p)
    for a in range(1, int(round(p ** (1 / 3))) + 2):
        if p % a:
            continue
        q = p // a
        for b in range(a, int(np.sqrt(q)) + 2):
            if q % b:
                continue
            c = q // b
            if c >= b:
                cand = (a, b, c)
                if max(cand) - min(cand) < max(best) - min(best):
                    best = cand
    return best


# ---------------------------------------------------------------------------
# LP normal equations (Sec. 6.2)
# ---------------------------------------------------------------------------
def lp_constraint_matrix(
    n_rows: int,
    n_cols: int,
    nnz_per_row: float = 7.0,
    n_blocks: int = 8,
    coupling_cols: float = 0.05,
    seed: int = 0,
) -> SparseStructure:
    """Staircase multicommodity-flow-like LP constraint structure: block
    diagonal (per-commodity flow constraints) plus a band of shared coupling
    columns, mimicking pds/fome instances (I < K, ~7 nnz/row)."""
    rng = np.random.default_rng(seed)
    rows_list, cols_list = [], []
    rb = np.linspace(0, n_rows, n_blocks + 1).astype(int)
    n_couple = int(n_cols * coupling_cols)
    cb = np.linspace(0, n_cols - n_couple, n_blocks + 1).astype(int)
    for b in range(n_blocks):
        r0, r1 = rb[b], rb[b + 1]
        c0, c1 = cb[b], cb[b + 1]
        rows = np.arange(r0, r1)
        # each row: ~nnz_per_row-1 entries in its block + 1 coupling entry
        k = max(int(nnz_per_row) - 1, 1)
        for _ in range(k):
            rows_list.append(rows)
            cols_list.append(rng.integers(c0, max(c1, c0 + 1), size=len(rows)))
        rows_list.append(rows)
        cols_list.append(
            n_cols - n_couple + rng.integers(0, max(n_couple, 1), size=len(rows))
        )
    return from_coo(
        np.concatenate(rows_list), np.concatenate(cols_list), (n_rows, n_cols)
    )


def lp_instance(name: str, scale: float = 1.0, seed: int = 0) -> SpGEMMInstance:
    """Named LP instances with Tab. II-like aspect ratios, at reduced size."""
    presets = {
        # name: (I, K, nnz_per_row, blocks)
        "fome21": (6700, 21600, 6.9, 16),
        "pds80": (12900, 43400, 7.2, 24),
        "pds100": (15600, 51400, 7.0, 24),
        "cont11l": (14600, 19600, 3.7, 8),
        "sgpf5y6": (12300, 15600, 3.4, 8),
    }
    I, K, nnz, blocks = presets[name]
    I, K = int(I * scale), int(K * scale)
    a = lp_constraint_matrix(I, K, nnz, blocks, seed=seed)
    return SpGEMMInstance(a, a.transpose(), name=f"LP-{name}")


# ---------------------------------------------------------------------------
# MCL (Sec. 6.3)
# ---------------------------------------------------------------------------
def scale_free_graph(n: int, m: int, seed: int = 0) -> SparseStructure:
    """Barabási–Albert adjacency + identity (self loops), symmetric."""
    import networkx as nx

    g = nx.barabasi_albert_graph(n, m, seed=seed)
    adj = nx.to_scipy_sparse_array(g, format="csr", dtype=np.int8)
    adj = adj + adj.T + sp.identity(n, dtype=np.int8, format="csr")
    return SparseStructure.wrap(sp.csr_matrix(adj))


def road_network_graph(n_side: int, seed: int = 0) -> SparseStructure:
    """2D grid graph with a sprinkling of diagonal shortcuts (roadnet-like:
    avg degree ~2.8-4, huge diameter, no hubs)."""
    rng = np.random.default_rng(seed)
    n = n_side * n_side
    idx = np.arange(n).reshape(n_side, n_side)
    rows = [idx[:, :-1].ravel(), idx[:-1, :].ravel()]
    cols = [idx[:, 1:].ravel(), idx[1:, :].ravel()]
    # delete ~30% of edges to thin it out (roads are sparser than grids)
    r = np.concatenate(rows)
    c = np.concatenate(cols)
    keep = rng.random(len(r)) > 0.3
    r, c = r[keep], c[keep]
    all_r = np.concatenate([r, c, np.arange(n)])
    all_c = np.concatenate([c, r, np.arange(n)])
    return from_coo(all_r, all_c, (n, n))


def mcl_instance(name: str, scale: float = 1.0, seed: int = 0) -> SpGEMMInstance:
    """Named MCL instances (Tab. II families) at reduced size: squaring a
    symmetric adjacency structure."""
    presets = {
        # name: (n, BA attachment m)  — chosen to hit Tab. II avg-degree
        "facebook": (4000, 22),
        "dip": (5000, 4),
        "wiphi": (5900, 4),
        "biogrid11": (5800, 11),
        "enron": (9000, 5),
        "dblp": (12000, 2),
    }
    if name == "roadnetca":
        side = int(140 * np.sqrt(scale))
        a = road_network_graph(side, seed=seed)
    else:
        n, m = presets[name]
        a = scale_free_graph(int(n * scale), m, seed=seed)
    return SpGEMMInstance(a, a, name=f"MCL-{name}")
