"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment,
for the largest MoE configs where full Adam state would not fit HBM).

States are plain pytrees mirroring the params tree, so they inherit the FSDP
('data'-axis) sharding of their parameters (ZeRO-1 by construction under
GSPMD: each data shard owns its slice of moments).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads,
    state,
    params,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mu_hat = mu / (1 - b1**cf)
        nu_hat = nu / (1 - b2**cf)
        step = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern), factored second moment for matrices
# ---------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(leaf, params, is_leaf=lambda x: hasattr(x, "shape")),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads,
    state,
    params,
    lr: float,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            )
            cfac = jax.lax.rsqrt(vc)
            u = g32 * rfac[..., None] * cfac[..., None, :]
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(vv)
            new_v = {"v": vv}
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        newp = p.astype(jnp.float32) - lr * u
        if weight_decay:
            newp = newp - lr * weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v, "count": count}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}
