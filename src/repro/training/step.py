"""Train / serve step builders: pure functions of (state, batch), jit-ready
with sharding annotations supplied by the launcher.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import train_loss, decode_step
from repro.models.transformer import prefill_step
from repro.training.optimizer import OPTIMIZERS


def make_train_step(cfg, optimizer: str = "adamw", lr: float = 3e-4, clip: float = 1.0):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    The full production step: fwd + bwd (remat) + global-norm clip + update.
    """
    _, opt_update = OPTIMIZERS[optimizer]

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch), has_aux=True
        )(params)
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg):
    def step(params, batch):
        return prefill_step(params, cfg, batch)

    return step


def make_decode_step(cfg):
    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return step
