"""Training substrate: optimizers, train/serve step builders, compression."""
from repro.training.optimizer import adamw_init, adamw_update, adafactor_init, adafactor_update
from repro.training.step import make_train_step, make_prefill_step, make_decode_step

__all__ = [
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]
