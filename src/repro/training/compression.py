"""Error-feedback int8 gradient compression for the slow (inter-pod) axis.

At 1000+ node scale the pod axis crosses DCI (data-center interconnect) whose
bandwidth is an order of magnitude below ICI; compressing the pure-DP
gradient all-reduce 4x (bf16 -> int8 + fp32 scale) on that axis is the
standard distributed-optimization trick.  Implemented as a shard_map
collective with persistent error-feedback state so the quantization error is
re-injected next step (EF-SGD / 1-bit-Adam lineage).

``compressed_psum_mean``: quantize -> all_reduce(int32 accumulate) ->
dequantize, returning the mean across the axis plus the new local error.
"""
from __future__ import annotations

import jax

from repro import compat
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale, error)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    err = x32 - q.astype(jnp.float32) * scale
    return q, scale, err


def compressed_psum_mean(
    x: jnp.ndarray,
    err: jnp.ndarray,
    axis: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: mean of ``x + err`` over ``axis`` using int8 wire
    format.  Returns (mean, new_error)."""
    n = compat.axis_size(axis)
    xe = x.astype(jnp.float32) + err
    # scales differ per participant: agree on the axis-max scale (one scalar
    # pmax) so a single int32 reduction is exact w.r.t. the shared scale.
    scale = jnp.maximum(jnp.max(jnp.abs(xe)), 1e-12) / 127.0
    smax = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(xe / smax), -127, 127).astype(jnp.int32)
    acc = jax.lax.psum(q, axis)
    mean = acc.astype(jnp.float32) * smax / n
    new_err = xe - q.astype(jnp.float32) * smax
    return mean, new_err


def compression_ratio(dtype=jnp.bfloat16) -> float:
    return jnp.dtype(dtype).itemsize / jnp.dtype(jnp.int8).itemsize
