"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels execute with interpret=True; on a real
TPU set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to run the
compiled Mosaic kernels.  The BSR entry points also accept host-side
``BlockSparse`` matrices and run the inspector (pair-list construction).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bsr_spgemm import bsr_spgemm, build_pair_lists
from repro.kernels.bsr_spmm import bsr_spmm
from repro.kernels.moe_gemm import moe_gemm
from repro.sparse.bsr import BlockSparse


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def spmm(bsr: BlockSparse, dense: np.ndarray, interpret: bool | None = None):
    """BSR x dense.  Pads a zero block into every empty block-row (the kernel
    initializes an output row-tile on first visit) and sorts by block-row."""
    m_blocks = bsr.shape[0] // bsr.block_shape[0]
    brows, bcols, blocks = bsr.brows, bsr.bcols, bsr.blocks
    missing = np.setdiff1d(np.arange(m_blocks), brows)
    if len(missing):
        b_m, b_k = bsr.block_shape
        blocks = np.concatenate(
            [blocks, np.zeros((len(missing), b_m, b_k), blocks.dtype)]
        )
        brows = np.concatenate([brows, missing])
        bcols = np.concatenate([bcols, np.zeros(len(missing), np.int64)])
    order = np.argsort(brows, kind="stable")
    return bsr_spmm(
        jnp.asarray(blocks[order]),
        jnp.asarray(brows[order]),
        jnp.asarray(bcols[order]),
        jnp.asarray(dense),
        m_blocks=m_blocks,
        interpret=_interpret_default() if interpret is None else interpret,
    )


def spgemm(
    a: BlockSparse, b: BlockSparse, interpret: bool | None = None
) -> tuple[jnp.ndarray, np.ndarray, np.ndarray]:
    """BSR x BSR -> (C blocks, c_brows, c_bcols).  Inspector on host."""
    pa, pb, pc, crows, ccols = build_pair_lists(a.brows, a.bcols, b.brows, b.bcols)
    if len(pa) == 0:
        bm, bn = a.block_shape[0], b.block_shape[1]
        return jnp.zeros((0, bm, bn), a.blocks.dtype), crows, ccols
    out = bsr_spgemm(
        jnp.asarray(a.blocks),
        jnp.asarray(b.blocks),
        jnp.asarray(pa),
        jnp.asarray(pb),
        jnp.asarray(pc),
        n_c_blocks=len(crows),
        interpret=_interpret_default() if interpret is None else interpret,
    )
    return out, crows, ccols


def grouped_gemm(x, w, interpret: bool | None = None):
    """(E, C, d) x (E, d, f) -> (E, C, f)."""
    return moe_gemm(
        jnp.asarray(x),
        jnp.asarray(w),
        interpret=_interpret_default() if interpret is None else interpret,
    )


# re-export oracles for test convenience
bsr_spmm_ref = ref.bsr_spmm_ref
bsr_spgemm_ref = ref.bsr_spgemm_ref
moe_gemm_ref = ref.moe_gemm_ref
