"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def bsr_spmm_ref(
    blocks: jnp.ndarray,  # (nb, bm, bk)
    brows: jnp.ndarray,  # (nb,)
    bcols: jnp.ndarray,  # (nb,)
    dense: jnp.ndarray,  # (K, N)
    m_blocks: int,
) -> jnp.ndarray:
    """A_bsr @ dense -> (m_blocks * bm, N)."""
    nb, bm, bk = blocks.shape
    K, N = dense.shape
    b_tiles = dense.reshape(K // bk, bk, N)
    out = jnp.zeros((m_blocks, bm, N), jnp.promote_types(blocks.dtype, dense.dtype))
    contrib = jnp.einsum("nij,njk->nik", blocks, b_tiles[bcols])
    out = out.at[brows].add(contrib)
    return out.reshape(m_blocks * bm, N)


def bsr_spgemm_ref(
    a_blocks: jnp.ndarray,  # (na, bm, bk)
    b_blocks: jnp.ndarray,  # (nbb, bk, bn)
    pair_a: jnp.ndarray,  # (np,) index into a_blocks
    pair_b: jnp.ndarray,  # (np,) index into b_blocks
    pair_c: jnp.ndarray,  # (np,) index into C block list
    n_c_blocks: int,
) -> jnp.ndarray:
    """Block-sparse x block-sparse -> C blocks (nc, bm, bn).

    The (pair_a, pair_b, pair_c) lists are the inspector output: every
    nontrivial block multiplication and the C block it accumulates into —
    exactly the coarsened multiplication vertices v_(IKJ) of the tiled
    SpGEMM hypergraph.
    """
    prod = jnp.einsum("nij,njk->nik", a_blocks[pair_a], b_blocks[pair_b])
    out = jnp.zeros(
        (n_c_blocks, a_blocks.shape[1], b_blocks.shape[2]),
        jnp.promote_types(a_blocks.dtype, b_blocks.dtype),
    )
    return out.at[pair_c].add(prod)


def moe_gemm_ref(
    x: jnp.ndarray,  # (E, C, d)
    w: jnp.ndarray,  # (E, d, f)
) -> jnp.ndarray:
    """Grouped expert GEMM (the MoE dispatch SpGEMM's dense payload)."""
    return jnp.einsum("ecd,edf->ecf", x, w)
