"""Pallas TPU kernel: block-sparse x block-sparse SpGEMM (BSR x BSR -> BSR).

The paper's numeric SpGEMM, TPU-adapted: tiling A, B, C into b x b blocks is
a vertex coarsening of the fine-grained hypergraph (DESIGN.md Sec. 3).  The
host-side inspector enumerates the coarse multiplication vertices — every
(A-block, B-block) pair with matching inner block index — sorted by their
C block (the monochrome-C fiber), and the kernel streams the pair list
through the MXU, accumulating runs of pairs into one C tile.

Grid: (n_pairs,).  Scalar-prefetched pair lists drive the BlockSpec index
maps; the output tile is revisited for consecutive pairs with equal pair_c,
with a first-visit predicate doing the init (sequential TPU grid).
VMEM per step: 3 * b^2 * 4B (fp32 acc) -> b=256 still only 768 KiB.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(pa_ref, pb_ref, pc_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0, pc_ref[jnp.maximum(i - 1, 0)] != pc_ref[i])

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(
        a_ref[0].astype(acc_dtype),
        b_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] += prod.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_c_blocks", "interpret", "acc_dtype")
)
def _bsr_spgemm_jit(
    a_blocks: jnp.ndarray,
    b_blocks: jnp.ndarray,
    pair_a: jnp.ndarray,
    pair_b: jnp.ndarray,
    pair_c: jnp.ndarray,
    n_c_blocks: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    n_pairs = pair_a.shape[0]
    bm, bk = a_blocks.shape[1], a_blocks.shape[2]
    bn = b_blocks.shape[2]
    out_dtype = jnp.promote_types(a_blocks.dtype, b_blocks.dtype)
    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # pair_a, pair_b, pair_c
            grid=(n_pairs,),
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda i, pa, pb, pc: (pa[i], 0, 0)),
                pl.BlockSpec((1, bk, bn), lambda i, pa, pb, pc: (pb[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda i, pa, pb, pc: (pc[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_c_blocks, bm, bn), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(dimension_semantics=("arbitrary",)),
    )(pair_a, pair_b, pair_c, a_blocks, b_blocks)
    return out


def _pair_list_int32(x) -> jnp.ndarray:
    """Cast one pair-list operand to int32, exactly once, host-side when
    possible: the inspector emits int64, and casting inside jit meant every
    invocation traced/ran an extra convert_element_type on the
    scalar-prefetch path.  Host operands (ndarray / list / tuple) are cast
    in numpy; traced operands (the shard_map executor path) pass through
    unchanged when already int32 and get a single astype otherwise."""
    if isinstance(x, (np.ndarray, list, tuple)):
        return jnp.asarray(np.asarray(x, dtype=np.int32))
    return x if x.dtype == jnp.int32 else x.astype(jnp.int32)


def bsr_spgemm(
    a_blocks: jnp.ndarray,  # (na, bm, bk)
    b_blocks: jnp.ndarray,  # (nb, bk, bn)
    pair_a: jnp.ndarray,  # (np,) int, index into a_blocks
    pair_b: jnp.ndarray,  # (np,) int
    pair_c: jnp.ndarray,  # (np,) int sorted ascending (runs per C block)
    n_c_blocks: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    pair_a = _pair_list_int32(pair_a)
    pair_b = _pair_list_int32(pair_b)
    pair_c = _pair_list_int32(pair_c)
    return _bsr_spgemm_jit(
        a_blocks,
        b_blocks,
        pair_a,
        pair_b,
        pair_c,
        n_c_blocks,
        interpret=interpret,
        acc_dtype=acc_dtype,
    )


def build_pair_lists(
    a_brows: np.ndarray,
    a_bcols: np.ndarray,
    b_brows: np.ndarray,
    b_bcols: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side inspector: coarse multiplication vertices of the tiled
    SpGEMM.  Returns (pair_a, pair_b, pair_c, c_brows, c_bcols) with pair_c
    sorted and C blocks deduplicated.

    Vectorized (CSR-style index arithmetic: group B entries by block-row,
    expand each A entry by its match count, one lexsort); byte-identical to
    ``build_pair_lists_loop``, the original executable specification.
    """
    a_brows = np.asarray(a_brows, dtype=np.int64)
    a_bcols = np.asarray(a_bcols, dtype=np.int64)
    b_brows = np.asarray(b_brows, dtype=np.int64)
    b_bcols = np.asarray(b_bcols, dtype=np.int64)
    z = np.zeros(0, dtype=np.int64)
    if len(a_brows) == 0 or len(b_brows) == 0:
        return z, z, z, z, z
    K = int(max(a_bcols.max(), b_brows.max())) + 1
    # B entries grouped by inner block index k
    b_order = np.argsort(b_brows, kind="stable")
    b_cnt = np.bincount(b_brows, minlength=K)
    b_start = np.cumsum(b_cnt) - b_cnt
    # each A entry i matches the b_cnt[a_bcols[i]] B entries of its k-group
    rep = b_cnt[a_bcols]
    total = int(rep.sum())
    if total == 0:
        return z, z, z, z, z
    ai = np.repeat(np.arange(len(a_brows), dtype=np.int64), rep)
    off = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(rep) - rep, rep)
    bj = b_order[b_start[a_bcols[ai]] + off]
    r, c = a_brows[ai], b_bcols[bj]
    order = np.lexsort((bj, ai, c, r))  # the loop version's (r, c, i, j) sort
    pair_a, pair_b, r, c = ai[order], bj[order], r[order], c[order]
    GC = int(b_bcols.max()) + 1
    uniq, pair_c = np.unique(r * GC + c, return_inverse=True)
    return (
        pair_a,
        pair_b,
        pair_c.astype(np.int64),
        uniq // GC,
        uniq % GC,
    )


def build_pair_lists_loop(
    a_brows: np.ndarray,
    a_bcols: np.ndarray,
    b_brows: np.ndarray,
    b_bcols: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Original pure-Python inspector, kept as the executable specification
    of ``build_pair_lists`` (invariant-tested to match byte for byte)."""
    pairs = []
    by_k: dict[int, list[int]] = {}
    for j, k in enumerate(b_brows):
        by_k.setdefault(int(k), []).append(j)
    for i, (r, k) in enumerate(zip(a_brows, a_bcols)):
        for j in by_k.get(int(k), []):
            pairs.append((int(r), int(b_bcols[j]), i, j))
    if not pairs:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, z, z
    pairs.sort()
    c_coords = sorted({(r, c) for r, c, _, _ in pairs})
    c_id = {rc: n for n, rc in enumerate(c_coords)}
    pair_a = np.array([p[2] for p in pairs], dtype=np.int64)
    pair_b = np.array([p[3] for p in pairs], dtype=np.int64)
    pair_c = np.array([c_id[(p[0], p[1])] for p in pairs], dtype=np.int64)
    c_brows = np.array([rc[0] for rc in c_coords], dtype=np.int64)
    c_bcols = np.array([rc[1] for rc in c_coords], dtype=np.int64)
    return pair_a, pair_b, pair_c, c_brows, c_bcols


def _default_backend() -> str:
    env = os.environ.get("REPRO_SPGEMM_BACKEND")
    if env:
        return env
    return (
        "interpret"
        if os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
        else "pallas"
    )


def bsr_spgemm_local(
    a_blocks: jnp.ndarray,
    b_blocks: jnp.ndarray,
    pair_a: jnp.ndarray,
    pair_b: jnp.ndarray,
    pair_c: jnp.ndarray,
    n_c_blocks: int,
    backend: str | None = None,
) -> jnp.ndarray:
    """Local-compute entry point the distributed executors route through.

    ``backend``: 'pallas' (compiled Mosaic, TPU), 'interpret' (Pallas
    interpreter — correct anywhere, the CPU fallback), or 'xla' (dense
    gather/einsum/segment-add fallback, fastest without a TPU attached).
    Default: $REPRO_SPGEMM_BACKEND, else interpret/pallas per
    $REPRO_PALLAS_INTERPRET like the rest of ``repro.kernels``.
    """
    backend = backend or _default_backend()
    if backend == "xla":
        from repro.kernels.ref import bsr_spgemm_ref

        return bsr_spgemm_ref(a_blocks, b_blocks, pair_a, pair_b, pair_c, n_c_blocks)
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown SpGEMM backend {backend!r}")
    return bsr_spgemm(
        a_blocks,
        b_blocks,
        pair_a,
        pair_b,
        pair_c,
        n_c_blocks=n_c_blocks,
        interpret=backend == "interpret",
    )
