"""Pallas TPU kernel: block-sparse x block-sparse SpGEMM (BSR x BSR -> BSR).

The paper's numeric SpGEMM, TPU-adapted: tiling A, B, C into b x b blocks is
a vertex coarsening of the fine-grained hypergraph (DESIGN.md Sec. 3).  The
host-side inspector enumerates the coarse multiplication vertices — every
(A-block, B-block) pair with matching inner block index — sorted by their
C block (the monochrome-C fiber), and the kernel streams the pair list
through the MXU, accumulating runs of pairs into one C tile.

Grid: (n_pairs,).  Scalar-prefetched pair lists drive the BlockSpec index
maps; the output tile is revisited for consecutive pairs with equal pair_c,
with a first-visit predicate doing the init (sequential TPU grid).
VMEM per step: 3 * b^2 * 4B (fp32 acc) -> b=256 still only 768 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pa_ref, pb_ref, pc_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0, pc_ref[jnp.maximum(i - 1, 0)] != pc_ref[i])

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(
        a_ref[0].astype(acc_dtype),
        b_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] += prod.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_c_blocks", "interpret", "acc_dtype")
)
def bsr_spgemm(
    a_blocks: jnp.ndarray,  # (na, bm, bk)
    b_blocks: jnp.ndarray,  # (nb, bk, bn)
    pair_a: jnp.ndarray,  # (np,) int32, index into a_blocks
    pair_b: jnp.ndarray,  # (np,) int32
    pair_c: jnp.ndarray,  # (np,) int32 sorted ascending (runs per C block)
    n_c_blocks: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    n_pairs = pair_a.shape[0]
    bm, bk = a_blocks.shape[1], a_blocks.shape[2]
    bn = b_blocks.shape[2]
    out_dtype = jnp.promote_types(a_blocks.dtype, b_blocks.dtype)
    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # pair_a, pair_b, pair_c
            grid=(n_pairs,),
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda i, pa, pb, pc: (pa[i], 0, 0)),
                pl.BlockSpec((1, bk, bn), lambda i, pa, pb, pc: (pb[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda i, pa, pb, pc: (pc[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_c_blocks, bm, bn), out_dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(dimension_semantics=("arbitrary",)),
    )(
        pair_a.astype(jnp.int32),
        pair_b.astype(jnp.int32),
        pair_c.astype(jnp.int32),
        a_blocks,
        b_blocks,
    )
    return out


def build_pair_lists(
    a_brows: np.ndarray,
    a_bcols: np.ndarray,
    b_brows: np.ndarray,
    b_bcols: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side inspector: coarse multiplication vertices of the tiled
    SpGEMM.  Returns (pair_a, pair_b, pair_c, c_brows, c_bcols) with pair_c
    sorted and C blocks deduplicated."""
    pairs = []
    by_k: dict[int, list[int]] = {}
    for j, k in enumerate(b_brows):
        by_k.setdefault(int(k), []).append(j)
    for i, (r, k) in enumerate(zip(a_brows, a_bcols)):
        for j in by_k.get(int(k), []):
            pairs.append((int(r), int(b_bcols[j]), i, j))
    if not pairs:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, z, z
    pairs.sort()
    c_coords = sorted({(r, c) for r, c, _, _ in pairs})
    c_id = {rc: n for n, rc in enumerate(c_coords)}
    pair_a = np.array([p[2] for p in pairs], dtype=np.int64)
    pair_b = np.array([p[3] for p in pairs], dtype=np.int64)
    pair_c = np.array([c_id[(p[0], p[1])] for p in pairs], dtype=np.int64)
    c_brows = np.array([rc[0] for rc in c_coords], dtype=np.int64)
    c_bcols = np.array([rc[1] for rc in c_coords], dtype=np.int64)
    return pair_a, pair_b, pair_c, c_brows, c_bcols
