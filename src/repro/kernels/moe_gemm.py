"""Pallas TPU kernel: grouped expert GEMM (E, C, d) x (E, d, f) -> (E, C, f).

The dense payload of the MoE dispatch SpGEMM (the compute the hypergraph
partition schedules onto each expert column).  Standard tiled matmul with an
expert grid axis; K-loop innermost so the fp32 accumulator tile stays
resident in VMEM across K steps.

Grid: (E, C/b_c, f/b_f, d/b_d).  VMEM per step: b_c*b_d + b_d*b_f + b_c*b_f
fp32 tiles; the defaults (128, 128, 512) use ~0.6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, acc_dtype):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0].astype(acc_dtype),
        w_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


@functools.partial(
    jax.jit, static_argnames=("b_c", "b_f", "b_d", "interpret", "acc_dtype")
)
def moe_gemm(
    x: jnp.ndarray,  # (E, C, d)
    w: jnp.ndarray,  # (E, d, f)
    b_c: int = 128,
    b_f: int = 128,
    b_d: int = 512,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    E, C, d = x.shape
    _, _, f = w.shape
    b_c, b_f, b_d = min(b_c, C), min(b_f, f), min(b_d, d)
    if C % b_c or f % b_f or d % b_d:
        raise ValueError(f"dims ({C},{f},{d}) not divisible by ({b_c},{b_f},{b_d})")
    n_k = d // b_d
    grid = (E, C // b_c, f // b_f, n_k)
    kernel = functools.partial(_kernel, n_k=n_k, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b_c, b_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, b_d, b_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, b_c, b_f), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((b_c, b_f), acc_dtype)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
    )(x, w)
