"""Pallas TPU kernel: BSR (block-sparse rows) x dense -> dense.

TPU adaptation of the paper's local SpGEMM compute phase: the hypergraph's
multiplication vertices are coarsened to b_m x b_k blocks (DESIGN.md Sec. 3),
each grid step feeds one block product to the MXU.  Block coordinates ride in
SMEM via scalar prefetch; accumulation into a revisited output tile relies on
TPU's sequential grid execution (blocks are pre-sorted by output row, so the
first-visit predicate initializes the tile).

Grid: (n_blocks, N / b_n).  VMEM working set per step:
b_m*b_k (A block) + b_k*b_n (B tile) + b_m*b_n (accumulator) — e.g.
128^2 * 3 * 4B = 196 KiB, comfortably within the ~16 MiB VMEM budget; b_n can
be raised to widen the MXU N dimension once b_k*b_n stays under ~4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(brows_ref, bcols_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    i = pl.program_id(1)  # block index (inner grid axis)
    # first visit of this output row-block: initialize the accumulator tile
    first = jnp.logical_or(i == 0, brows_ref[jnp.maximum(i - 1, 0)] != brows_ref[i])

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(
        a_ref[0].astype(acc_dtype),
        b_ref[...].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] += prod.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("m_blocks", "b_n", "interpret", "acc_dtype")
)
def bsr_spmm(
    blocks: jnp.ndarray,  # (nb, b_m, b_k), sorted by brows
    brows: jnp.ndarray,  # (nb,) int32
    bcols: jnp.ndarray,  # (nb,) int32
    dense: jnp.ndarray,  # (K, N)
    m_blocks: int,
    b_n: int = 128,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    nb, b_m, b_k = blocks.shape
    K, N = dense.shape
    b_n = min(b_n, N)
    if N % b_n:
        raise ValueError(f"N={N} not divisible by b_n={b_n}")
    # grid: j outer, block index inner — same-row runs revisit the output
    # tile on CONSECUTIVE steps (TPU revisiting requirement).  Caller must
    # guarantee every output block-row has at least one (possibly zero)
    # block, else that row's tiles are never initialized (ops.spmm pads).
    grid = (N // b_n, nb)
    out_dtype = jnp.promote_types(blocks.dtype, dense.dtype)
    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # brows, bcols
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, b_m, b_k), lambda j, i, brows, bcols: (i, 0, 0)),
                pl.BlockSpec((b_k, b_n), lambda j, i, brows, bcols: (bcols[i], j)),
            ],
            out_specs=pl.BlockSpec(
                (b_m, b_n), lambda j, i, brows, bcols: (brows[i], j)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((m_blocks * b_m, N), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
    )(brows.astype(jnp.int32), bcols.astype(jnp.int32), blocks, dense)
    return out
