"""One front door: ``repro.plan(A, B, p=8)`` — partition to product.

The paper's thesis is that a hypergraph partition IS an SpGEMM algorithm.
Using the library used to mean hand-stitching five layers —
``SpGEMMInstance`` -> ``build_model`` -> ``partition`` ->
plan lowering -> ``compile_spgemm`` — with model-specific folklore (monoC's
2D mesh, per-model value layouts, dtype promotion) known only to
``select._execute``.  This module is the stable public pipeline over the
declarative ``ModelSpec`` registry:

    import repro

    spgemm = repro.plan(A, B, p=8, model="auto", eps=0.10, seed=0)
    spgemm.cost_report()             # predicted / planned / padded words
    exe = spgemm.compile()           # mesh + dtype + backend per ModelSpec
    C = exe(a_vals, b_vals)          # dense C, == A @ B
    C = spgemm(a_vals, b_vals)       # same, compile-on-first-use

``A`` / ``B`` are structures (dense array, scipy sparse, or
``SparseStructure``); values are 1-D nonzero vectors in canonical CSR order
for *every* model — the registry's ``pack_values`` hides monoC's block
layout.  ``model="auto"`` partitions every executable model and keeps the
communication-minimal one (the same min-predicted-words rule the
``select.sweep_instance`` report applies, scoped to the models that can
actually run).

Everything jax-flavored is imported lazily so that planning (a pure
numpy/scipy affair) works — and stays fast to import — without touching a
device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import PartitionResult, evaluate
from repro.core import partition as _partition
from repro.core.comm import (
    CommCosts,
    memory_dependent_bound,
    memory_independent_bound,
)
from repro.core.hypergraph import Hypergraph
from repro.core.spgemm_models import SpGEMMInstance
from repro.distributed.plan_ir import (
    ExecutionPlan,
    build_volume_plan,
    measured_route_words,
    route_messages,
)
from repro.distributed.registry import (
    MODEL_SPECS,
    ModelSpec,
    executable_models,
    get_spec,
)
__all__ = [
    "CompiledSpGEMM",
    "PlannedSpGEMM",
    "device_count",
    "plan",
    "session",
]


def device_count() -> int:
    """Devices visible to this process (the one place jax is asked — the
    sweep, the executors and the examples all route through here)."""
    import jax

    return jax.device_count()


# ---------------------------------------------------------------------------
# the compiled handle
# ---------------------------------------------------------------------------
class CompiledSpGEMM:
    """A compiled SpGEMM pipeline: canonical values in, dense C out.

    Wraps the runtime's AOT executable with the model's value packing and
    unpacking so every model takes 1-D nonzero value vectors (canonical CSR
    order of the planned structures) and returns the dense (I, J) product —
    no caller-visible mesh, dtype, block or layout special-casing.  The raw
    device-shard interface stays available as ``.runtime``.

    A handle compiled with ``batch=n`` streams value *batches*: inputs are
    (m, nnz) arrays with ``1 <= m <= batch_capacity`` (the bucketed
    capacity), the output is (m, I, J).  Ragged batches are zero-padded up
    to the capacity on the way in and trimmed on the way out, so every
    batch size within one bucket hits the same AOT executable.
    """

    def __init__(
        self,
        planned: "PlannedSpGEMM",
        runtime_exe,
        spec: ModelSpec,
        out_shape: tuple[int, int] | None = None,
    ):
        self.planned = planned
        self.runtime = runtime_exe
        self.spec = spec
        if out_shape is None:
            I, _, J = planned.instance.shape
            out_shape = (I, J)
        self._out = tuple(out_shape)

    @property
    def mesh(self):
        return self.runtime.mesh

    @property
    def dtype(self):
        return self.runtime.dtype

    @property
    def batch_capacity(self) -> int | None:
        """Batch slots the executor was compiled for (None: unbatched)."""
        return self.runtime.batch

    @property
    def cost_model_words(self) -> tuple[int, int]:
        """(ideal, padded) words per call, from the plan's routes."""
        return self.runtime.cost_model_words

    def pack(self, a_values, b_values) -> tuple[np.ndarray, np.ndarray]:
        """Canonical 1-D nonzero vectors -> the executor's value layout.

        For a batched handle the inputs are (m, nnz) stacks; each row is
        packed independently and the stack is zero-padded to the compiled
        batch capacity (padding rows cost device flops, never correctness —
        their products are simply dropped by ``__call__``).
        """
        block = self.runtime.block
        if self.batch_capacity is None:
            return (
                self.spec.pack_values(np.asarray(a_values), block),
                self.spec.pack_values(np.asarray(b_values), block),
            )
        cap = self.batch_capacity

        def pack_stack(values, name):
            values = np.atleast_2d(np.asarray(values))
            m = values.shape[0]
            if not 1 <= m <= cap:
                raise ValueError(
                    f"{name} batch of {m} exceeds the compiled capacity {cap}; "
                    f"recompile with batch={m} (bucketed) or split the batch"
                )
            packed = np.stack(
                [self.spec.pack_values(values[i], block) for i in range(m)]
            )
            if m < cap:
                pad = np.zeros((cap - m, *packed.shape[1:]), packed.dtype)
                packed = np.concatenate([packed, pad])
            return packed, m

        a, m_a = pack_stack(a_values, "A")
        b, m_b = pack_stack(b_values, "B")
        if m_a != m_b:
            raise ValueError(f"A batch ({m_a}) and B batch ({m_b}) disagree")
        return a, b

    def __call__(self, a_values, b_values) -> np.ndarray:
        I, J = self._out
        if self.batch_capacity is None:
            a, b = self.pack(a_values, b_values)
            return np.asarray(self.runtime.unpack(self.runtime(a, b)))[:I, :J]
        m = np.atleast_2d(np.asarray(a_values)).shape[0]
        a, b = self.pack(a_values, b_values)
        c_local = np.asarray(self.runtime(a, b))[:m]
        return self.runtime.unpack(c_local)[:, :I, :J]


# ---------------------------------------------------------------------------
# the planned handle
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)  # identity semantics: fields hold ndarrays
class PlannedSpGEMM:
    """One partition-is-the-algorithm pipeline, planned and ready.

    Owns the instance, the model hypergraph, the ``PartitionResult`` and
    (for executable models) the lowered ``ExecutionPlan``.  ``compile()``
    builds the model's process grid and AOT-compiles the executor;
    ``execute``/``__call__`` go straight from canonical nonzero values to
    the dense product, compiling on first use (cached thereafter).
    """

    instance: SpGEMMInstance
    model: str
    # None for partition-free baselines (summa2d): no hypergraph was built
    # and no partition ran — the execution plan is the whole story
    hypergraph: Hypergraph | None
    partition: PartitionResult | None
    execution_plan: ExecutionPlan | None
    eps: float = 0.10
    seed: int = 0
    selection: list[dict] | None = None  # model="auto" sweep records

    @property
    def spec(self) -> ModelSpec:
        return get_spec(self.model)

    @property
    def p(self) -> int:
        if self.partition is not None:
            return self.partition.p
        return self.execution_plan.p

    @property
    def executable(self) -> bool:
        return self.execution_plan is not None

    def costs(self) -> CommCosts:
        """The partition's communication metrics (Lemma 4.2 machinery)."""
        if self.hypergraph is None:
            raise ValueError(
                f"model {self.model!r} is partition-free (no hypergraph); "
                f"its communication is the analytic cost_report()"
            )
        return evaluate(self.hypergraph, self.partition.parts, self.p)

    def cost_report(self) -> dict:
        """Predicted vs planned vs padded words, plus the eq. (1) bounds.

        - ``predicted_words``: the connectivity metric the partitioner
          minimized (sum over cut nets of c(n) * (lambda(n) - 1));
        - ``planned_words``: the words the lowered plan's routing tables
          actually schedule (transfer enumeration — an independent code
          path), item-weighted per the model's convention;
        - ``padded_words``: what the padded all_to_all slots move on the
          wire;
        - ``planned_messages``: non-empty (src, dst) route cells + fold
          messages — the alpha term next to the words' beta term;
        - ``bounds``: the classical eq. (1) lower bounds the paper compares
          against (local memory taken as 3 * nnz / p, the bench convention).

        For a partition-free baseline (summa2d) ``predicted_words`` is the
        closed-form analytic volume (``stats["words_analytic"]``) and
        ``planned_words`` the route-table count — their equality is the
        same measured == predicted check, with connectivity replaced by
        the closed form.
        """
        inst, p = self.instance, self.p
        n_nz = inst.a.nnz + inst.b.nnz + inst.c.nnz
        local_mem = max(3 * n_nz / p, 64)
        report = {
            "model": self.model,
            "p": p,
            "executable": self.executable,
            "bounds": {
                "memory_dependent": round(
                    memory_dependent_bound(inst.n_mult, p, local_mem), 1
                ),
                "memory_independent": round(
                    memory_independent_bound(inst.n_mult, n_nz, p), 1
                ),
            },
        }
        if self.hypergraph is None:
            plan_obj = self.execution_plan
            report["predicted_words"] = int(plan_obj.stats["words_analytic"])
            report["planned_words"] = measured_route_words(plan_obj)
            report["padded_words"] = plan_obj.comm_words_padded
            report["planned_messages"] = route_messages(plan_obj)
            return report
        costs = self.costs()
        report.update(
            {
                "n_vertices": self.hypergraph.n_vertices,
                "n_pins": self.hypergraph.n_pins,
                "predicted_words": int(costs.connectivity),
                "predicted_max_part": int(costs.max_part_cost),
                "expand_words": int(costs.expand),
                "fold_words": int(costs.fold),
                "comp_imbalance": round(costs.comp_imbalance, 4),
            }
        )
        plan_obj = self.execution_plan
        if plan_obj is None:
            # plans that didn't lower (include_nz partitions on models whose
            # lowerers don't accept them) still get an IR whose words ==
            # prediction (net costs ride on the routes' per-item overrides)
            plan_obj = build_volume_plan(self.hypergraph, self.partition.parts, p)
            report["planned_words"] = plan_obj.comm_words_ideal
        else:
            item_words = self.spec.item_words(inst)
            report["planned_words"] = measured_route_words(plan_obj, item_words)
            if item_words is not None:
                report["planned_items"] = measured_route_words(plan_obj)
        report["padded_words"] = plan_obj.comm_words_padded
        report["planned_messages"] = route_messages(plan_obj)
        return report

    def compile(
        self,
        devices=None,
        dtype=np.float32,
        backend: str | None = None,
        batch: int | None = None,
    ) -> CompiledSpGEMM:
        """AOT-compile the pipeline's executor.

        The process grid comes from the model's ``ModelSpec`` (monoC gets
        its 2D mesh, including the odd-p fallback, without the caller ever
        seeing it), as do backend defaults; ``devices`` optionally pins the
        device set (default: the first p of ``jax.devices()``).

        ``batch=n`` compiles the *batched* step: the registered runner is
        vmapped over a leading value-batch axis so up to ``n`` same-structure
        multiplies stream through one dispatch (multi-RHS, MCL/AMG iterated
        chains).  ``n`` is rounded up to a geometric capacity bucket
        (``runtime.batch_bucket``) so ragged request batches share one AOT
        executable; the handle pads and trims transparently.
        """
        if self.execution_plan is None:
            if self.spec.executable:
                raise ValueError(
                    f"model {self.model!r} was planned with include_nz=True "
                    f"but its lowerer does not accept V^nz partitions; "
                    f"replan with include_nz=False to execute"
                )
            raise ValueError(
                f"model {self.model!r} is volume-only (predicts, never "
                f"executes); executable models: {executable_models()}"
            )
        from repro.distributed.runtime import batch_bucket, compile_spgemm

        spec = self.spec
        inst = self.instance
        mesh = spec.default_mesh(self.p, devices, instance=inst)
        if backend is None:
            backend = spec.compile_defaults.get("backend")
        runtime_exe = compile_spgemm(
            self.execution_plan,
            inst.a,
            inst.b,
            mesh,
            dtype=dtype,
            backend=backend,
            block=spec.compile_defaults.get("block", 1),
            c_structure=inst.c,
            batch=None if batch is None else batch_bucket(batch),
        )
        return CompiledSpGEMM(self, runtime_exe, spec)

    def execute(self, a_values, b_values, **compile_kwargs) -> np.ndarray:
        """Canonical nonzero values in, dense C out.

        Compiles on first use (the runtime LRU makes repeat calls hit the
        same AOT executable); dtype defaults to the promoted value dtype.
        """
        a_values = np.asarray(a_values)
        b_values = np.asarray(b_values)
        compile_kwargs.setdefault(
            "dtype", np.promote_types(a_values.dtype, b_values.dtype)
        )
        return self.compile(**compile_kwargs)(a_values, b_values)

    __call__ = execute


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------
def _plan_one(
    inst: SpGEMMInstance,
    model: str,
    p: int,
    eps: float,
    seed: int,
    include_nz: bool,
    engine: str = "flat",
    warm_start: np.ndarray | None = None,
    warm_drift_limit: float = 0.5,
    coarsen: str = "auto",
) -> PlannedSpGEMM:
    spec = get_spec(model)
    if spec.build is None:
        # partition-free baseline (summa2d): no hypergraph to build or
        # partition — lower the instance straight to its execution plan
        return PlannedSpGEMM(
            instance=inst,
            model=model,
            hypergraph=None,
            partition=None,
            execution_plan=spec.lower(inst, None, p),
            eps=eps,
            seed=seed,
        )
    hg = spec.build(inst, include_nz=include_nz)
    res = _partition(
        hg,
        p,
        eps=eps,
        seed=seed,
        engine=engine,
        warm_start=warm_start,
        warm_drift_limit=warm_drift_limit,
        coarsen=coarsen,
    )
    plan_obj = None
    if spec.lower is not None and (not include_nz or spec.lower_include_nz):
        plan_obj = spec.lower(inst, res.parts, p)
    return PlannedSpGEMM(
        instance=inst,
        model=model,
        hypergraph=hg,
        partition=res,
        execution_plan=plan_obj,
        eps=eps,
        seed=seed,
    )


def plan(
    A,
    B=None,
    p: int = 8,
    model: str = "auto",
    eps: float = 0.10,
    seed: int = 0,
    name: str = "",
    include_nz: bool = False,
    engine: str = "flat",
    coarsen: str = "auto",
) -> PlannedSpGEMM:
    """Plan a distributed SpGEMM: model the instance, partition, lower.

    ``A`` / ``B`` give the nonzero structures (dense array, scipy sparse
    matrix, or ``SparseStructure`` — values never enter the inspector);
    alternatively ``A`` may be an existing ``SpGEMMInstance`` (``B`` omitted)
    so repeated per-model planning reuses one symbolic inspection.
    ``model`` is one of the paper's seven (``repro.MODELS``, all
    executable), ``"summa2d"`` (the sparsity-oblivious Sparse SUMMA
    baseline — partition-free, never auto-selected), or ``"auto"``:
    partition every auto-eligible model and keep the communication-minimal
    one (the same min-predicted-words rule ``sweep_instance`` reports); the
    per-model records land on ``.selection``.
    ``include_nz`` keeps the V^nz nonzero vertices (Sec. 4 reading); the
    partitioner then places them too, and the handle stays cost/analysis-
    only unless the model's lowerer understands such partitions (fine does).
    ``engine`` selects the partitioner engine (``"flat"`` host default,
    ``"device"`` for the batched jax engine above its size threshold,
    ``"loop"`` for the per-move reference — see DESIGN.md §6); it changes
    planning *speed*, not the plan contract.  ``coarsen`` picks the
    ``engine="device"`` descend (``"auto"``/``"device"`` keep the V-cycle
    device-resident, ``"host"`` forces the host-scipy descend) and is
    ignored by the host engines.
    """
    if isinstance(A, SpGEMMInstance):
        if B is not None:
            raise ValueError("B must be omitted when A is an SpGEMMInstance")
        inst = A
    else:
        if B is None:
            raise ValueError("B is required unless A is an SpGEMMInstance")
        inst = SpGEMMInstance.from_operands(A, B, name=name)
    if model != "auto":
        if model not in MODEL_SPECS:
            raise ValueError(
                f"unknown model {model!r}; choose from "
                f"{tuple(MODEL_SPECS)} or 'auto'"
            )
        return _plan_one(
            inst, model, p, eps, seed, include_nz, engine, coarsen=coarsen
        )
    candidates = [
        _plan_one(inst, m, p, eps, seed, include_nz, engine, coarsen=coarsen)
        for m in executable_models()
    ]
    records = []
    for cand in candidates:
        rec = cand.cost_report()
        rec["selected"] = False
        records.append(rec)
    # auto means "pick something that can run": with include_nz only some
    # lowerers accept the partition, so restrict to those when any exist
    viable = [i for i, c in enumerate(candidates) if c.execution_plan is not None]
    pool = viable or range(len(candidates))
    best = min(pool, key=lambda i: records[i]["predicted_words"])
    records[best]["selected"] = True
    chosen = candidates[best]
    chosen.selection = records
    return chosen


def session(
    p: int = 8,
    model: str = "auto",
    eps: float = 0.10,
    seed: int = 0,
    engine: str = "flat",
    store_dir: str | None = None,
    policy=None,
    **kwargs,
):
    """A resilient handle for iterated, structure-drifting SpGEMM.

    ``repro.session(p=8)`` returns a ``SpGEMMSession``: call it like
    ``plan(...)`` would be called per structure, but across a loop —
    ``sess.multiply(A, B)`` fingerprints the operands, reuses the warm
    executor when the structure is unchanged, warm-start-replans on drift,
    persists plans under ``store_dir`` (a restarted session rebuilds its
    pool from there), and retries/downgrades through ``policy`` (a
    ``repro.FaultPolicy``) on stage failures.  See
    ``repro.distributed.session`` for the full contract.
    """
    from repro.distributed.session import SpGEMMSession

    return SpGEMMSession(
        p=p,
        model=model,
        eps=eps,
        seed=seed,
        engine=engine,
        store_dir=store_dir,
        policy=policy,
        **kwargs,
    )
