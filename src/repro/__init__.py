"""repro — hypergraph-partitioned SpGEMM (paper reproduction, JAX/Pallas).

The public surface is the ``repro.api`` pipeline over the declarative model
registry:

    import repro

    spgemm = repro.plan(A, B, p=8, model="auto")
    spgemm.cost_report()
    C = spgemm.compile()(a_vals, b_vals)      # == (A @ B) values

Submodules (``repro.core``, ``repro.sparse``, ``repro.distributed``) remain
importable for the individual pipeline stages; everything listed in
``__all__`` here is the supported front door and is pinned by
``tests/test_api_surface.py``.  Attributes resolve lazily (PEP 562) so that
``import repro`` — and any ``repro.<submodule>`` import — never drags jax in.
"""
from __future__ import annotations

__all__ = [
    "MODELS",
    "MODEL_SPECS",
    "CompiledSpGEMM",
    "FaultPolicy",
    "ModelSpec",
    "PlannedSpGEMM",
    "SpGEMMInstance",
    "SpGEMMSession",
    "device_count",
    "executable_models",
    "plan",
    "session",
]

_FROM_API = ("plan", "session", "PlannedSpGEMM", "CompiledSpGEMM", "device_count")
_FROM_REGISTRY = ("ModelSpec", "MODEL_SPECS", "executable_models")
_FROM_CORE = ("MODELS", "SpGEMMInstance")
_FROM_RESILIENCE = ("FaultPolicy",)
_FROM_SESSION = ("SpGEMMSession",)


def __getattr__(name: str):
    if name in _FROM_API:
        from repro import api

        return getattr(api, name)
    if name in _FROM_REGISTRY:
        from repro.distributed import registry

        return getattr(registry, name)
    if name in _FROM_CORE:
        from repro.core import spgemm_models

        return getattr(spgemm_models, name)
    if name in _FROM_RESILIENCE:
        from repro import resilience

        return getattr(resilience, name)
    if name in _FROM_SESSION:
        from repro.distributed import session

        return getattr(session, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
