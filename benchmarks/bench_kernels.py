"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness,
not speed); throughput numbers that matter for the roofline come from the
dry-run cost analysis.  Here we time the jitted XLA reference paths (real
compiled CPU code) and the interpret-mode kernels for completeness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops
from repro.sparse.bsr import to_bsr


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(out_dir=None, quick=False):
    records = []
    rng = np.random.default_rng(0)
    block = 16
    m = k = n = 128 if quick else 256
    gm, gk = m // block, k // block
    mask = np.kron(rng.random((gm, gk)) < 0.3, np.ones((block, block), bool))
    a = rng.standard_normal((m, k)).astype(np.float32) * mask
    b = rng.standard_normal((k, n)).astype(np.float32)
    bsr = to_bsr(a, block, block)

    us_ref = _time(
        jax.jit(
            lambda blocks, brows, bcols, dense: ops.bsr_spmm_ref(
                blocks, brows, bcols, dense, gm
            )
        ),
        jnp.asarray(bsr.blocks),
        jnp.asarray(bsr.brows),
        jnp.asarray(bsr.bcols),
        jnp.asarray(b),
    )
    records.append(
        {
            "name": "kernels/bsr_spmm/xla_ref",
            "status": "ok",
            "us_per_call": int(us_ref),
            "nnz_blocks": bsr.n_blocks,
        }
    )
    t0 = time.time()
    ops.spmm(bsr, b, interpret=True)
    records.append(
        {
            "name": "kernels/bsr_spmm/pallas_interpret",
            "status": "ok",
            "us_per_call": int((time.time() - t0) * 1e6),
            "note": "interpret mode: correctness path, not TPU speed",
        }
    )

    E, C, d, f = (4, 64, 64, 64) if quick else (8, 256, 256, 256)
    x = rng.standard_normal((E, C, d)).astype(np.float32)
    w = rng.standard_normal((E, d, f)).astype(np.float32)
    us = _time(jax.jit(ops.moe_gemm_ref), jnp.asarray(x), jnp.asarray(w))
    records.append(
        {
            "name": "kernels/moe_gemm/xla_ref",
            "status": "ok",
            "us_per_call": int(us),
            "gflop": round(2 * E * C * d * f / 1e9, 3),
        }
    )
    emit(records, out_dir, "kernels.json")
    return records
