"""Resilient-session benchmarks: warm vs cold replanning, an MCL-style
drifting loop (optionally with a scripted failure schedule), and the
kill-and-recover cell.

The session's amortization claim extends the paper's: not only does one
partition pay for many same-structure multiplies (``bench_exec.py``), a
*drifted* structure should pay a warm-start replan — label carry-over + one
K-way polish — instead of the full multilevel search.  Cells:

- ``session/warm_replan/*``: planning-only (partition + plan lowering, no
  XLA anywhere) cost of replanning a drifted instance warm vs cold.  This is
  the cell the regression gate tracks, and it asserts warm is at least
  ``WARM_SPEEDUP_FLOOR``x faster.
- ``session_exec/mcl_loop/*``: a full ``repro.session()`` expand-and-prune
  loop — structure drifts every iteration, every product checked against
  numpy.  With ``--faults`` a scripted schedule injects transient failures
  at four stage boundaries mid-loop; the cell asserts they all fired and
  the loop still produced correct products (the resilience acceptance).
- ``session_exec/recover/*``: kill-and-recover — a fresh session on the
  same plan store restores its pool (``restored`` events only) with ZERO
  executor retraces, and the restore path is compared against the cold
  replan it replaces.

Run standalone with forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/bench_session.py --quick --faults
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

WARM_SPEEDUP_FLOOR = 1.5

#: --faults schedule: stage -> 0-based call indices that fail (transient)
FAULT_SCHEDULE = {"partition": [1], "compile": [1], "execute": [2], "store_save": [0]}


def _perturb(struct, rng, frac: float):
    """Drift a structure in place-shape: drop ``frac`` of the nonzeros, add
    the same number of fresh coordinates."""
    from repro.sparse.structure import from_coo

    rows, cols = struct.coo()
    n = len(rows)
    keep = np.ones(n, dtype=bool)
    keep[rng.choice(n, max(1, int(frac * n)), replace=False)] = False
    add = max(1, int(frac * n))
    new_r = rng.integers(0, struct.shape[0], add)
    new_c = rng.integers(0, struct.shape[1], add)
    return from_coo(
        np.concatenate([rows[keep], new_r]),
        np.concatenate([cols[keep], new_c]),
        struct.shape,
    )


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _warm_replan_cell(n, p, density, reps, model="rowwise", seed=0) -> dict:
    """Planning-only: replan a drifted instance cold (full multilevel
    search) vs warm (label carry-over + K-way polish).  Device-independent —
    ``_plan_one`` never touches jax."""
    from repro.api import _plan_one
    from repro.core import SpGEMMInstance
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    a0 = random_structure(n, n, density, rng)
    b = random_structure(n, n, density, rng)
    planned0 = _plan_one(SpGEMMInstance(a0, b), model, p, 0.10, seed, include_nz=False)
    labels = np.asarray(planned0.partition.parts)  # rowwise vertices ARE rows,
    # so the labels align with the drifted instance's vertex set directly
    inst1 = SpGEMMInstance(_perturb(a0, rng, 0.05), b)

    warm_planned = _plan_one(
        inst1, model, p, 0.10, seed, include_nz=False, warm_start=labels
    )
    assert warm_planned.partition.warm, "warm-start fell back to cold at bench scale"
    cold_s = _best_of(
        lambda: _plan_one(inst1, model, p, 0.10, seed, include_nz=False), reps
    )
    warm_s = _best_of(
        lambda: _plan_one(
            inst1, model, p, 0.10, seed, include_nz=False, warm_start=labels
        ),
        reps,
    )
    speedup = cold_s / warm_s
    assert speedup >= WARM_SPEEDUP_FLOOR, (
        f"warm replan {warm_s * 1e6:.0f} us is only {speedup:.2f}x faster than "
        f"cold ({cold_s * 1e6:.0f} us); the drift-aware session claims >= "
        f"{WARM_SPEEDUP_FLOOR}x"
    )
    cold_conn = int(
        _plan_one(inst1, model, p, 0.10, seed, include_nz=False)
        .partition.connectivity
    )
    return {
        "name": f"session/warm_replan/{model}/n{n}/p{p}",
        "status": "ok",
        "us_per_call": int(warm_s * 1e6),
        "cold_us": int(cold_s * 1e6),
        "speedup_vs_cold": round(speedup, 2),
        "warm_connectivity": int(warm_planned.partition.connectivity),
        "cold_connectivity": cold_conn,
    }


def _mcl_seed_matrix(n: int, rng) -> np.ndarray:
    M = (rng.random((n, n)) * (rng.random((n, n)) < 0.15)).astype(np.float32)
    M[np.arange(n), np.arange(n)] = 1.0
    return M


def _mcl_prune(C: np.ndarray, n: int) -> np.ndarray:
    C = C.copy()
    C[C < np.quantile(C[C > 0], 0.3)] = 0.0
    col = C.sum(axis=0)
    M = (C / np.where(col > 0, col, 1.0)).astype(np.float32)
    M[np.arange(n), np.arange(n)] += 0.5
    return M


def _mcl_session_cell(p, n, iters, with_faults: bool, seed=5) -> dict:
    """Full-session MCL loop: drift every iteration, optional scripted
    failures, every product oracle-checked."""
    import contextlib

    import repro
    from repro.resilience import FaultPolicy
    from repro.testing import faults

    store = tempfile.mkdtemp(prefix="bench_session_mcl_")
    try:
        rng = np.random.default_rng(seed)
        M = _mcl_seed_matrix(n, rng)
        s = repro.session(
            p=p, model="rowwise", policy=FaultPolicy(backoff_s=0.0), store_dir=store
        )
        ctx = faults.scripted(FAULT_SCHEDULE) if with_faults else contextlib.nullcontext({})
        t0 = time.perf_counter()
        with ctx as scripts:
            for _ in range(iters):
                C = np.asarray(s.multiply(M, M))
                np.testing.assert_allclose(C, M @ M, rtol=2e-4, atol=2e-4)
                M = _mcl_prune(C, n)
        total_s = time.perf_counter() - t0
        fired = {stage: sc.fired for stage, sc in scripts.items()}
        if with_faults:
            for stage, want in FAULT_SCHEDULE.items():
                assert fired[stage] == len(want), f"{stage} fault never fired"
        counts = s.stats()["events"]
        assert counts.get("cold_replan", 0) + counts.get("warm_replan", 0) == iters
        return {
            "name": f"session_exec/mcl_loop/n{n}/p{p}"
            + ("/faults" if with_faults else ""),
            "status": "ok",
            "us_per_call": int(total_s / iters * 1e6),  # amortized per iteration
            "total_s": round(total_s, 3),
            "iters": iters,
            "warm_replans": counts.get("warm_replan", 0),
            "retries": counts.get("retry", 0),
            "faults_fired": fired,
        }
    finally:
        shutil.rmtree(store, ignore_errors=True)


def _recover_cell(p, n, seed=6) -> dict:
    """Kill-and-recover: session 2 rebuilds its pool from session 1's store
    with zero retraces; restore cost vs the cold replan it replaces."""
    import repro
    from repro.distributed import runtime
    from repro.resilience import FaultPolicy

    store = tempfile.mkdtemp(prefix="bench_session_recover_")
    try:
        rng = np.random.default_rng(seed)
        M = _mcl_seed_matrix(n, rng)
        policy = FaultPolicy(backoff_s=0.0)

        t0 = time.perf_counter()
        s1 = repro.session(p=p, model="rowwise", policy=policy, store_dir=store)
        np.testing.assert_allclose(
            np.asarray(s1.multiply(M, M)), M @ M, rtol=2e-4, atol=2e-4
        )
        cold_s = time.perf_counter() - t0
        del s1  # the crash

        traces0 = runtime.trace_count()
        t0 = time.perf_counter()
        s2 = repro.session(p=p, model="rowwise", policy=policy, store_dir=store)
        np.testing.assert_allclose(
            np.asarray(s2.multiply(M, M)), M @ M, rtol=2e-4, atol=2e-4
        )
        restore_s = time.perf_counter() - t0
        assert runtime.trace_count() == traces0, "restored plan retraced"
        counts = s2.stats()["events"]
        assert counts == {"restored": 1}, counts
        return {
            "name": f"session_exec/recover/n{n}/p{p}",
            "status": "ok",
            "us_per_call": int(restore_s * 1e6),
            "cold_us": int(cold_s * 1e6),
            "speedup_vs_cold": round(cold_s / restore_s, 2),
            "retraces": runtime.trace_count() - traces0,
        }
    finally:
        shutil.rmtree(store, ignore_errors=True)


def run(out_dir: str | None = None, quick: bool = True, with_faults: bool = False):
    import jax

    from benchmarks.common import emit

    if quick:
        n_plan, p_plan, density, reps = 2000, 8, 0.004, 3
        n_exec, p_exec, iters = 96, 4, 5
    else:
        n_plan, p_plan, density, reps = 6000, 8, 0.002, 3
        n_exec, p_exec, iters = 160, 4, 8
    records = [_warm_replan_cell(n_plan, p_plan, density, reps)]
    if jax.device_count() < p_exec:
        records.append(
            {
                "name": f"session_exec/all/p{p_exec}",
                "status": "skipped",
                "reason": f"{jax.device_count()} device(s) < p={p_exec}",
            }
        )
    else:
        records.append(_mcl_session_cell(p_exec, n_exec, iters, with_faults))
        records.append(_recover_cell(p_exec, n_exec))
    emit(records, out_dir, "session.json")
    return records


if __name__ == "__main__":
    import argparse
    import os

    # the exec cells need multiple devices: force them BEFORE jax imports
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=8",
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger planning instances")
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes (the default)")
    ap.add_argument(
        "--faults",
        action="store_true",
        help="run the MCL loop under the scripted failure schedule",
    )
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full, with_faults=args.faults):
        print(r)
