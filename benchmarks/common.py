"""Shared benchmark machinery: run (instance x model x p) cells, emit CSV
rows ``name,us_per_call,derived`` and JSON records.

Scale note (DESIGN.md §5): the ``--scale {small,paper}`` knob in ``run.py``
picks the instance sizes; ``small`` keeps the container default fast while
``paper`` runs the Table-2-style sweeps near paper scale — feasible since
the flat-CSR refinement engine made ``partition()`` ~9x faster than the
loop reference.  The sweep *shapes* (weak/strong scaling, model sets,
balance constraint eps=0.01-0.10) follow the paper at either scale.
Hypergraphs above ``pin_cap`` pins are skipped with a note, mirroring the
paper's own partitioner OOM rows (Sec. 6.1).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import SpGEMMInstance, build_model, evaluate, partition, partition_random

# raised 4M -> 16M with the flat-CSR engine (PR 2); the cap now only trims
# the largest fine-grained 3D models at paper scale
PIN_CAP = 16_000_000


def run_cell(
    inst: SpGEMMInstance,
    model: str,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
    pin_cap: int = PIN_CAP,
    parts_override: np.ndarray | None = None,
    tag: str = "",
) -> dict:
    name = f"{inst.name}/{model}/p{p}{tag}"
    t0 = time.time()
    hg = build_model(inst, model) if model != "geometric" else None
    build_s = time.time() - t0
    if hg is not None and hg.n_pins > pin_cap:
        return {
            "name": name,
            "status": "skipped",
            "reason": f"pins {hg.n_pins} > cap {pin_cap}",
        }
    t0 = time.time()
    if parts_override is not None:
        parts = parts_override
        conn = None
    else:
        res = partition(hg, p, eps=eps, seed=seed)
        parts = res.parts
    part_s = time.time() - t0
    costs = evaluate(hg, parts, p)
    rand = partition_random(hg, p, seed=seed)
    return {
        "name": name,
        "status": "ok",
        "us_per_call": int(part_s * 1e6),
        "build_s": round(build_s, 2),
        "partition_s": round(part_s, 2),
        "n_vertices": hg.n_vertices,
        "n_nets": hg.n_nets,
        "n_pins": hg.n_pins,
        "max_part_cost": int(costs.max_part_cost),
        "total_volume": int(costs.total_volume),
        "connectivity": int(costs.connectivity),
        "expand": int(costs.expand),
        "fold": int(costs.fold),
        "comp_imbalance": round(costs.comp_imbalance, 4),
        "random_connectivity": int(rand.connectivity),
    }


def run_monoC_cell(
    a_dense: np.ndarray,
    b_dense: np.ndarray,
    block: int,
    p: int,
    eps: float = 0.10,
    seed: int = 0,
    tag: str = "",
) -> dict:
    """Plan-build + executor cell for the 2D monochrome-C model.

    Times the full inspector pipeline (tile -> model -> partition -> plan
    IR) and, when the process owns >= p devices, the executor pass through
    the BSR kernel path on a 2D mesh (oracle-checked against dense A @ B).
    With fewer devices the executor step is reported as skipped — plan
    metrics (ideal vs padded volume, pair counts) are device-independent.
    """
    from repro.distributed.plan_ir import plan_monoC_from_dense

    name = f"monoC_exec/b{block}/p{p}{tag}"
    t0 = time.time()
    plan, inst = plan_monoC_from_dense(a_dense, b_dense, block, p, eps=eps, seed=seed)
    plan_s = time.time() - t0
    rec = {
        "name": name,
        "status": "ok",
        "us_per_call": int(plan_s * 1e6),
        "plan_s": round(plan_s, 3),
        "ideal_words": plan.comm_words_ideal,
        "padded_words": plan.comm_words_padded,
        "padding_fraction": round(plan.padding_fraction, 3),
        "n_pairs": plan.stats["n_pairs"],
        "pairs_padded": plan.stats["pairs_padded"],
    }
    import jax

    if jax.device_count() >= p and p % 2 == 0:
        from jax.sharding import Mesh

        from repro.distributed import monoC_spgemm
        from repro.distributed.spgemm_exec import unpack_monoC_result

        mesh = Mesh(np.array(jax.devices()[:p]).reshape(2, p // 2), ("x", "y"))
        t0 = time.time()
        c_local = monoC_spgemm(a_dense, b_dense, plan, mesh, block=block)
        np.asarray(c_local)  # block until done
        rec["exec_s"] = round(time.time() - t0, 3)
        gr, gc = inst.c.shape
        got = unpack_monoC_result(c_local, plan, inst.c, (gr * block, gc * block))
        want = a_dense @ b_dense
        rec["exec_max_err"] = float(
            np.abs(got[: want.shape[0], : want.shape[1]] - want).max()
        )
    elif p % 2 != 0:
        rec["exec"] = f"skipped (odd p={p}; executor mesh is (2, p//2))"
    else:
        rec["exec"] = f"skipped ({jax.device_count()} device(s) < p={p})"
    return rec


def run_geometric_cell(inst, model: str, p: int, parts: np.ndarray, tag: str) -> dict:
    """Evaluate a geometric (non-partitioner) baseline on a model hypergraph."""
    hg = build_model(inst, model)
    costs = evaluate(hg, parts, p)
    return {
        "name": f"{inst.name}/{tag}/p{p}",
        "status": "ok",
        "us_per_call": 0,
        "max_part_cost": int(costs.max_part_cost),
        "total_volume": int(costs.total_volume),
        "connectivity": int(costs.connectivity),
        "comp_imbalance": round(costs.comp_imbalance, 4),
    }


def random_valued_dense(struct, rng, dtype=np.float32) -> np.ndarray:
    """Dense array with random normal values on a SparseStructure's nonzeros
    (the executor suites' standard way to put numbers on a fixed pattern)."""
    dense = np.zeros(struct.shape, dtype=dtype)
    r, c = struct.coo()
    dense[r, c] = rng.standard_normal(len(r)).astype(dtype)
    return dense


def emit(records: list[dict], out_dir: str | None, fname: str) -> None:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(records, f, indent=1)


def csv_lines(records: list[dict]) -> list[str]:
    skip_keys = {"name", "status", "us_per_call", "build_s", "partition_s"}
    out = []
    for r in records:
        if r["status"] != "ok":
            out.append(f"{r['name']},-1,{r.get('reason', 'skipped')}")
            continue
        derived = ";".join(
            f"{k}={v}" for k, v in r.items() if k not in skip_keys
        )
        out.append(f"{r['name']},{r.get('us_per_call', 0)},{derived}")
    return out
