"""Plan-construction micro-benchmark: loop inspector vs vectorized IR.

Cells:
- ``plan_build/loop`` and ``plan_build/vec``: the row-wise inspector on a
  10k-row instance (the acceptance target: vectorized >= 10x faster while
  producing byte-identical routing tables — the identity is asserted here,
  not just reported).
- ``pair_lists/loop`` and ``pair_lists/vec``: the BSR SpGEMM inspector.
- ``plan_build/monoC``: the full 2D monochrome-C inspector pipeline
  (tile -> model -> partition -> plan) at reduced size, reporting route
  volumes (ideal vs padded) next to construction time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SpGEMMInstance
from repro.distributed.plan import build_rowwise_plan, build_rowwise_plan_loop
from repro.kernels.bsr_spgemm import build_pair_lists, build_pair_lists_loop
from repro.sparse.structure import random_structure


def _time(fn, repeats: int) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    records = []
    rng = np.random.default_rng(0)
    I = 2_000 if quick else 10_000
    K, J, p = I // 2, I // 2, 16
    inst = SpGEMMInstance(
        random_structure(I, K, 8.0 / K, rng),
        random_structure(K, J, 8.0 / J, rng),
        name=f"er{I//1000}k",
    )
    row_part = rng.integers(0, p, I)
    b_part = rng.integers(0, p, K)

    t_loop, plan_loop = _time(
        lambda: build_rowwise_plan_loop(inst, row_part, p, b_part), repeats=1
    )
    t_vec, plan_vec = _time(
        lambda: build_rowwise_plan(inst, row_part, p, b_part), repeats=3
    )
    identical = (
        np.array_equal(plan_vec.send_idx, plan_loop.send_idx)
        and np.array_equal(plan_vec.recv_key, plan_loop.recv_key)
        and np.array_equal(plan_vec.local_rows, plan_loop.local_rows)
        and np.array_equal(plan_vec.local_b_rows, plan_loop.local_b_rows)
    )
    assert identical, "vectorized rowwise plan diverged from the loop reference"
    speedup = t_loop / max(t_vec, 1e-9)
    for tag, t in (("loop", t_loop), ("vec", t_vec)):
        records.append(
            {
                "name": f"{inst.name}/plan_build/{tag}/p{p}",
                "status": "ok",
                "us_per_call": int(t * 1e6),
                "rows": I,
                "ideal_words": plan_vec.comm_words_ideal,
                "padded_words": plan_vec.comm_words_padded,
                "byte_identical": identical,
                "speedup_vs_loop": round(speedup, 1),
            }
        )

    # BSR pair-list inspector on a block grid sized to the same instance
    gb = 64 if quick else 160
    na = nb = gb * 8
    args = (
        rng.integers(0, gb, na),
        rng.integers(0, gb, na),
        rng.integers(0, gb, nb),
        rng.integers(0, gb, nb),
    )
    t_ploop, ref_lists = _time(lambda: build_pair_lists_loop(*args), repeats=1)
    t_pvec, vec_lists = _time(lambda: build_pair_lists(*args), repeats=3)
    assert all(np.array_equal(a, b) for a, b in zip(vec_lists, ref_lists))
    for tag, t in (("loop", t_ploop), ("vec", t_pvec)):
        records.append(
            {
                "name": f"bsr{gb}/pair_lists/{tag}",
                "status": "ok",
                "us_per_call": int(t * 1e6),
                "pairs": len(ref_lists[0]),
                "speedup_vs_loop": round(t_ploop / max(t_pvec, 1e-9), 1),
            }
        )

    # full monoC inspector pipeline + executor (when the process owns >= p
    # devices; plan metrics are device-independent either way)
    from benchmarks.common import run_monoC_cell

    n = 96 if quick else 256
    a = (rng.random((n, n)) < 0.08) * rng.standard_normal((n, n)).astype(np.float32)
    b = (rng.random((n, n)) < 0.08) * rng.standard_normal((n, n)).astype(np.float32)
    records.append(run_monoC_cell(a, b, block=8, p=4, tag=f"/n{n}"))

    if out_dir:
        from benchmarks.common import emit

        emit(records, out_dir, "plan_build.json")
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="10k-row acceptance run")
    args = ap.parse_args()
    for r in run(quick=not args.full):
        print(r)
