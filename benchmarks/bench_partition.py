"""Partitioner engine benchmark: flat vs loop vs device engines.

Cells (each instance × engine):
- ``partition/flat`` and ``partition/loop``: end-to-end ``partition()`` wall
  time and final connectivity on the bench instances.  The acceptance cell
  is the 10k-row ER instance at p=16 (``--full``): the flat engine must be
  >= 8x faster than the loop-FM reference at connectivity within 5% (or
  better) and identical balance feasibility.  The quick/smoke grid runs the
  same comparison at reduced size so CI exercises the claim on every PR.
- ``partition/device`` vs ``partition/flat_x{S}``: the device-engine
  multi-start acceptance cell.  One batched ``engine="device"`` call (all S
  seeds refined side by side on device, steady-state — the first call's
  jit compile is warmed up out of band and amortizes across same-bucket
  planning calls) against the flat engine's best-of-S sequential seeds,
  which is the host idiom it replaces.  ``--full`` asserts >= 5x end-to-end
  with connectivity within 5%.
- ``partition/device_coarsen`` vs ``partition/host_coarsen``: the
  device-resident V-cycle acceptance cell.  Both sides are the same
  ``engine="device"`` call; only the descend differs (``coarsen="auto"``
  keeps coarsening on device, ``coarsen="host"`` forces the retained scipy
  descend).  ``--full`` asserts >= 3x end-to-end with connectivity within
  5%.  Device records carry phase-split columns (``coarsen_s`` /
  ``refine_s`` / ``polish_s`` seconds at the best-timed rep).
- a small structured cell (27-pt stencil rowwise model) so quality is
  checked on mesh-like inputs, not just ER.

Every record carries ``engine`` and ``pins_per_sec`` (hypergraph pins
planned per wall-second — the partition-throughput headline that
``check_regression.py`` gates against ``partition_smoke.json``).

Timing is interleaved best-of-``repeats`` per engine (both sides measured
under the same host conditions, so machine noise cannot tilt the ratio).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.core.matrices import stencil27
from repro.sparse.structure import random_structure

ACCEPT_SPEEDUP = 8.0
ACCEPT_CONN = 1.05
DEVICE_ACCEPT_SPEEDUP = 5.0  # device call vs flat best-of-S multi-start
DEVICE_ACCEPT_CONN = 1.05
DEVICE_BENCH_STARTS = 8  # seeds in the multi-start comparison
COARSEN_ACCEPT_SPEEDUP = 3.0  # device-resident V-cycle vs host-coarsen descend
COARSEN_ACCEPT_CONN = 1.05


def _er_instance(rows: int, seed: int = 0) -> SpGEMMInstance:
    rng = np.random.default_rng(seed)
    k = rows // 2
    return SpGEMMInstance(
        random_structure(rows, k, 8.0 / k, rng),
        random_structure(k, k, 8.0 / k, rng),
        name=f"er{rows//1000}k" if rows >= 1000 else f"er{rows}",
    )


def _cell(hg, p: int, name: str, repeats: int = 2, eps: float = 0.10) -> list[dict]:
    # interleaved best-of-``repeats`` per engine, so host-level timing noise
    # hits both sides of the comparison alike
    best = {"flat": float("inf"), "loop": float("inf")}
    res = {}
    for _rep in range(repeats):
        for engine in ("flat", "loop"):
            t0 = time.perf_counter()
            res[engine] = partition(hg, p, eps=eps, seed=0, engine=engine)
            best[engine] = min(best[engine], time.perf_counter() - t0)
    results = {}
    for engine in ("flat", "loop"):
        costs = evaluate(hg, res[engine].parts, p)
        results[engine] = (best[engine], res[engine].connectivity, costs.comp_imbalance)
    t_flat, c_flat, i_flat = results["flat"]
    t_loop, c_loop, i_loop = results["loop"]
    speedup = t_loop / max(t_flat, 1e-9)
    conn_ratio = c_flat / max(c_loop, 1)
    # identical balance feasibility: both inside the eps cap (+ rounding) or
    # both forced over it by heavy vertices
    feas_flat, feas_loop = i_flat <= eps + 0.03, i_loop <= eps + 0.03
    recs = []
    for engine in ("flat", "loop"):
        t, c, imb = results[engine]
        recs.append(
            {
                "name": f"{name}/partition/{engine}/p{p}",
                "status": "ok",
                "engine": engine,
                "us_per_call": int(t * 1e6),
                "n_vertices": hg.n_vertices,
                "n_nets": hg.n_nets,
                "n_pins": hg.n_pins,
                "pins_per_sec": int(hg.n_pins / max(t, 1e-9)),
                "connectivity": int(c),
                "comp_imbalance": round(float(imb), 4),
                "speedup_vs_loop": round(speedup, 1),
                "conn_vs_loop": round(conn_ratio, 3),
                "balance_feasibility_identical": bool(feas_flat == feas_loop),
            }
        )
    return recs


def _device_cell(
    hg,
    p: int,
    name: str,
    repeats: int = 2,
    eps: float = 0.10,
    starts: int = DEVICE_BENCH_STARTS,
) -> list[dict]:
    """Multi-start acceptance cell: one batched ``engine="device"`` call vs
    the flat engine's best-of-``starts`` sequential seeds (the host
    multi-start idiom the device batch replaces)."""
    partition(hg, p, eps=eps, seed=0, engine="device")  # warm the jit cache
    best = {"device": float("inf"), "flat": float("inf")}
    res = {}
    for _rep in range(repeats):
        t0 = time.perf_counter()
        res["device"] = partition(hg, p, eps=eps, seed=0, engine="device")
        best["device"] = min(best["device"], time.perf_counter() - t0)
        t0 = time.perf_counter()
        winner = None
        for s in range(starts):
            cand = partition(hg, p, eps=eps, seed=s, engine="flat")
            if winner is None or cand.connectivity < winner.connectivity:
                winner = cand
        res["flat"] = winner
        best["flat"] = min(best["flat"], time.perf_counter() - t0)
    speedup = best["flat"] / max(best["device"], 1e-9)
    conn_ratio = res["device"].connectivity / max(res["flat"].connectivity, 1)
    recs = []
    for engine, label in (("device", "device"), ("flat", f"flat_x{starts}")):
        t = best[engine]
        imb = evaluate(hg, res[engine].parts, p).comp_imbalance
        rec = {
            "name": f"{name}/partition/{label}/p{p}",
            "status": "ok",
            "engine": engine,
            "multi_starts": starts,
            "us_per_call": int(t * 1e6),
            "n_vertices": hg.n_vertices,
            "n_nets": hg.n_nets,
            "n_pins": hg.n_pins,
            "pins_per_sec": int(hg.n_pins / max(t, 1e-9)),
            "connectivity": int(res[engine].connectivity),
            "comp_imbalance": round(float(imb), 4),
            "speedup_vs_flat_multistart": round(speedup, 2),
            "conn_vs_flat_multistart": round(conn_ratio, 3),
        }
        rec.update(_phase_cols(res[engine]))
        recs.append(rec)
    return recs


def _phase_cols(res) -> dict:
    """Phase-split columns for device-engine records: seconds spent in the
    descend (``coarsen_s``), the batched device refinement (``refine_s``)
    and the host K-way polish (``polish_s``).  Host engines carry no phase
    breakdown and get no columns."""
    phases = getattr(res, "phases", None)
    if not phases:
        return {}
    return {k: round(float(v), 4) for k, v in sorted(phases.items())}


def _coarsen_cell(
    hg, p: int, name: str, repeats: int = 3, eps: float = 0.10
) -> list[dict]:
    """Device-resident coarsening acceptance cell: the same
    ``engine="device"`` call with the descend on device
    (``coarsen="auto"``) against forced host coarsening
    (``coarsen="host"``, the retained scipy descend).  Both sides share the
    batched refinement and host polish, so the column isolates what keeping
    the V-cycle on device buys end to end."""
    for mode in ("auto", "host"):  # warm both jit cache paths
        partition(hg, p, eps=eps, seed=0, engine="device", coarsen=mode)
    best = {"auto": float("inf"), "host": float("inf")}
    res = {}
    phases = {}
    for _rep in range(repeats):
        for mode in ("auto", "host"):
            t0 = time.perf_counter()
            r = partition(hg, p, eps=eps, seed=0, engine="device", coarsen=mode)
            dt = time.perf_counter() - t0
            if dt < best[mode]:
                best[mode] = dt
                phases[mode] = _phase_cols(r)
            res[mode] = r
    speedup = best["host"] / max(best["auto"], 1e-9)
    conn_ratio = res["auto"].connectivity / max(res["host"].connectivity, 1)
    recs = []
    for mode, label in (("auto", "device_coarsen"), ("host", "host_coarsen")):
        t = best[mode]
        imb = evaluate(hg, res[mode].parts, p).comp_imbalance
        rec = {
            "name": f"{name}/partition/{label}/p{p}",
            "status": "ok",
            "engine": "device",
            "coarsen": mode,
            "us_per_call": int(t * 1e6),
            "n_vertices": hg.n_vertices,
            "n_nets": hg.n_nets,
            "n_pins": hg.n_pins,
            "pins_per_sec": int(hg.n_pins / max(t, 1e-9)),
            "connectivity": int(res[mode].connectivity),
            "comp_imbalance": round(float(imb), 4),
            "speedup_vs_host_coarsen": round(speedup, 2),
            "conn_vs_host_coarsen": round(conn_ratio, 3),
        }
        rec.update(phases[mode])
        recs.append(rec)
    return recs


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    records = []
    if quick:
        # 5k rows keeps CI fast but stays on the engines' V-cycle speed
        # path (instances <= SMALL_DIRECT take the multi-start quality path,
        # which deliberately spends the speedup on connectivity instead)
        er = build_model(_er_instance(5_000), "rowwise")
        records += _cell(er, 16, "er5k")
    else:
        # the acceptance instance: 10k rows, p=16
        er = build_model(_er_instance(10_000), "rowwise")
        records += _cell(er, 16, "er10k")
    # small structured quality cell — runs the multi-start quality path, so
    # the interesting column is conn_vs_loop, not the speedup
    a = stencil27(7)
    records += _cell(
        build_model(SpGEMMInstance(a, a, name="stencil7"), "rowwise"), 4, "stencil7"
    )
    # device multi-start throughput cell on the same ER instance (skipped
    # gracefully where jax is absent: the driver falls back to flat and the
    # comparison would be flat-vs-flat noise)
    try:
        import repro.core.refine_device  # noqa: F401
    except ImportError:
        pass
    else:
        name = "er5k" if quick else "er10k"
        records += _device_cell(er, 16, name)
        # device-resident coarsening cell: device vs host descend inside the
        # same engine="device" call (the V-cycle residency acceptance)
        records += _coarsen_cell(er, 16, name)
    if not quick:
        rec = records[0]
        assert rec["balance_feasibility_identical"], "balance feasibility diverged"
        assert rec["speedup_vs_loop"] >= ACCEPT_SPEEDUP, (
            f"flat engine only {rec['speedup_vs_loop']}x faster on er10k "
            f"(acceptance: >= {ACCEPT_SPEEDUP}x)"
        )
        assert rec["conn_vs_loop"] <= ACCEPT_CONN, (
            f"flat connectivity {rec['conn_vs_loop']}x the loop reference "
            f"(acceptance: <= {ACCEPT_CONN})"
        )
        dev = [r for r in records if r.get("engine") == "device"]
        assert dev, "device acceptance cell missing (jax unavailable?)"
        assert dev[0]["speedup_vs_flat_multistart"] >= DEVICE_ACCEPT_SPEEDUP, (
            f"device engine only {dev[0]['speedup_vs_flat_multistart']}x the "
            f"flat multi-start on er10k (acceptance: >= {DEVICE_ACCEPT_SPEEDUP}x)"
        )
        assert dev[0]["conn_vs_flat_multistart"] <= DEVICE_ACCEPT_CONN, (
            f"device connectivity {dev[0]['conn_vs_flat_multistart']}x the "
            f"flat multi-start winner (acceptance: <= {DEVICE_ACCEPT_CONN})"
        )
        resident = [r for r in records if r.get("coarsen") == "auto"]
        assert resident, "device-coarsening acceptance cell missing"
        assert resident[0]["speedup_vs_host_coarsen"] >= COARSEN_ACCEPT_SPEEDUP, (
            f"device-resident coarsening only "
            f"{resident[0]['speedup_vs_host_coarsen']}x the host-coarsen "
            f"descend on er10k (acceptance: >= {COARSEN_ACCEPT_SPEEDUP}x)"
        )
        assert resident[0]["conn_vs_host_coarsen"] <= COARSEN_ACCEPT_CONN, (
            f"device-resident connectivity "
            f"{resident[0]['conn_vs_host_coarsen']}x the host-coarsen result "
            f"(acceptance: <= {COARSEN_ACCEPT_CONN})"
        )
    if out_dir and not quick:
        # only the full acceptance run refreshes the committed artifact;
        # smoke runs print without clobbering the 10k measurement
        from benchmarks.common import emit

        emit(records, out_dir, "partition.json")
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--full", action="store_true", help="10k-row acceptance run")
    mode.add_argument(
        "--smoke", action="store_true", help="reduced-size CI run (the default)"
    )
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full):
        print(r)
