"""Fig. 8 reproduction: LP normal equations A D^2 A^T, strong scaling.

S_B = S_A^T, so column-wise == row-wise and monoB == monoA (paper Sec. 6.2 —
those curves are omitted).  Expected qualitative result: fine-grained ~
outer-product ~ monoA are most communication-efficient; row-wise and monoC
the least (up to ~23x), and 2D gives little advantage over outer-product.
"""
from __future__ import annotations

from benchmarks.common import emit, run_cell
from repro.core.matrices import lp_instance

INSTANCES = ["fome21", "pds80", "pds100", "cont11l", "sgpf5y6"]
MODELS = ("rowwise", "outer", "monoA", "monoC", "fine")


def run(out_dir=None, quick=False):
    names = INSTANCES[:2] if quick else INSTANCES
    ps = (16,) if quick else (4, 16, 64)
    # paper scale doubled (0.05 -> 0.10) with the flat-CSR partitioner
    scale = 0.02 if quick else 0.10
    records = []
    for name in names:
        inst = lp_instance(name, scale=scale)
        for p in ps:
            for model in MODELS:
                records.append(run_cell(inst, model, p, eps=0.10))
    emit(records, out_dir, "lp.json")
    return records
