"""Fig. 7 reproduction: AMG triple-product SpGEMMs, weak scaling.

Paper: 27-pt model problem A@P and P^T@(AP), seven parallelizations + the
geometric 1D baselines.  Weak scaling keeps rows/processor roughly constant;
our reduced sizes pair (n=9, p=8), (n=12, p=27), (n=15, p=64).
Expected qualitative result (Sec. 6.1): row-wise nearly optimal for A@P;
outer-product (and the 2D refinements monoA/monoB) nearly optimal for PTAP
with ~an order of magnitude gap to row-wise/monoC.

Paper scale adds the (18, 125) point (5832 fine rows/chip-count step kept
~constant) — in reach since the flat-CSR partitioner landed.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_cell, run_geometric_cell
from repro.core.matrices import amg_instances, geometric_row_partition
from repro.core.spgemm_models import MODELS

WEAK = [(9, 8), (12, 27)]
WEAK_FULL = [(9, 8), (12, 27), (15, 64), (18, 125)]


def run(out_dir=None, quick=False, flavor="model"):
    pairs = WEAK if quick else WEAK_FULL
    models = ("rowwise", "outer", "monoC") if quick else MODELS
    records = []
    for n, p in pairs:
        ap, ptap = amg_instances(n, flavor=flavor)
        for inst, kind in ((ap, "AP"), (ptap, "PTAP")):
            for model in models:
                records.append(run_cell(inst, model, p, eps=0.10))
        # geometric baselines: row-wise on A rows (AP); outer on fine points (PTAP)
        geo = geometric_row_partition(n, p)
        records.append(run_geometric_cell(ap, "rowwise", p, geo, "geometric-row"))
        records.append(run_geometric_cell(ptap, "outer", p, geo, "geometric-outer"))
    emit(records, out_dir, f"amg_{flavor}.json")
    return records
