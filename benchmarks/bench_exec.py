"""Cold vs steady-state executor latency: the compile-once runtime claim.

The paper's amortization premise (one partition, many same-structure
multiplies) only pays off if the per-call cost after the first call is the
collectives + local compute the plan prescribes — not host repacking and
retracing.  For the replicated-free executors (fine-grained and
monochrome-C) this suite measures:

- ``rebuild_us``: the pre-runtime rebuild-everything path — a fresh executor
  (scatter-spec build + route upload + shard_map trace + XLA compile) on
  every call, which is exactly what each call paid before the runtime
  existed (``compile_spgemm(..., cache=False)``);
- ``cold_us``: one ``CompiledSpGEMM`` construction + first call;
- ``us_per_call``: steady-state — post-warmup value-only calls through the
  cached AOT executable (this is the cell the regression gate tracks);

plus an MCL-style iterated loop (same structure, fresh values every
iteration, one executor — with a zero-retrace assertion) and a
device-independent host-packing micro-cell (per-device Python loop vs the
``np.nonzero`` scatter idiom the executors now use).

Acceptance assertion (ISSUE 4): steady-state is >= 5x faster than the
rebuild path for fine + monoC at bench scale.

Run standalone with forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/bench_exec.py
"""
from __future__ import annotations

import time

import numpy as np

SPEEDUP_FLOOR = 5.0


def _steady(exe, a_vals, b_vals, reps: int) -> float:
    """Best post-warmup per-call seconds (each call blocked to completion).
    Min-of-N, not mean: host-device collectives on a shared machine have
    heavy-tailed stragglers, and the gate needs a stable statistic."""
    import jax

    for _ in range(2):  # warmup: first dispatches populate caches
        jax.block_until_ready(exe(a_vals, b_vals))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(exe(a_vals, b_vals))
        best = min(best, time.perf_counter() - t0)
    return best


def _rebuild(build_exe, a_vals, b_vals, reps: int) -> float:
    """Best-of per-call seconds for the rebuild-everything path: a fresh
    (uncached) executor per call, as every call paid before the runtime."""
    import jax

    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        exe = build_exe()
        jax.block_until_ready(exe(a_vals, b_vals))
        best = min(best, time.perf_counter() - t0)
    return best


def _cell(name, build_exe, a_vals, b_vals, steady_reps, rebuild_reps, plan) -> dict:
    import jax

    rebuild_s = _rebuild(build_exe, a_vals, b_vals, rebuild_reps)
    t0 = time.perf_counter()
    exe = build_exe()
    jax.block_until_ready(exe(a_vals, b_vals))
    cold_s = time.perf_counter() - t0
    steady_s = _steady(exe, a_vals, b_vals, steady_reps)
    speedup = rebuild_s / steady_s
    assert speedup >= SPEEDUP_FLOOR, (
        f"{name}: steady-state {steady_s * 1e6:.0f} us is only {speedup:.1f}x "
        f"faster than the rebuild path ({rebuild_s * 1e6:.0f} us); "
        f"the compile-once runtime claims >= {SPEEDUP_FLOOR}x"
    )
    return {
        "name": name,
        "status": "ok",
        "us_per_call": int(steady_s * 1e6),
        "cold_us": int(cold_s * 1e6),
        "rebuild_us": int(rebuild_s * 1e6),
        "speedup_vs_rebuild": round(speedup, 1),
        "ideal_words": plan.comm_words_ideal,
        "padded_words": plan.comm_words_padded,
    }


def _fine_cell(p, n, density, steady_reps, rebuild_reps, seed=0) -> dict:
    import jax
    from jax.sharding import Mesh

    from repro.distributed.plan_ir import plan_fine_from_dense
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    a_s = random_structure(n, n, density, rng)
    b_s = random_structure(n, n, density, rng)
    # structure-only planning: no dense operand anywhere in the pipeline
    plan, inst = plan_fine_from_dense(a_s, b_s, p)
    a_vals = rng.standard_normal(a_s.nnz).astype(np.float32)
    b_vals = rng.standard_normal(b_s.nnz).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:p]), ("x",))

    def build_exe():
        return compile_spgemm(plan, inst.a, inst.b, mesh, cache=False)

    return _cell(
        f"exec/fine/n{n}/p{p}", build_exe, a_vals, b_vals,
        steady_reps, rebuild_reps, plan,
    )


def _monoC_cell(p, n, density, block, steady_reps, rebuild_reps, seed=1) -> dict:
    import jax
    from jax.sharding import Mesh

    from benchmarks.common import random_valued_dense
    from repro.distributed.plan_ir import plan_monoC_from_dense
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.bsr import to_bsr
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    a_dense = random_valued_dense(random_structure(n, n, density, rng), rng)
    b_dense = random_valued_dense(random_structure(n, n, density, rng), rng)
    plan, inst = plan_monoC_from_dense(a_dense, b_dense, block, p)
    ab = to_bsr(a_dense, block, block)
    bb = to_bsr(b_dense, block, block)
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(2, p // 2), ("x", "y"))

    def build_exe():
        return compile_spgemm(
            plan, inst.a, inst.b, mesh, block=block, cache=False
        )

    return _cell(
        f"exec/monoC/n{n}/b{block}/p{p}", build_exe, ab.blocks, bb.blocks,
        steady_reps, rebuild_reps, plan,
    )


def _mcl_cell(p, n, density, iters, seed=2) -> dict:
    """MCL-style loop: one compiled executor, ``iters`` same-structure A*A
    multiplies with fresh values each iteration (the inflation step updates
    values on a fixed structure), zero recompiles after warmup."""
    import jax
    from jax.sharding import Mesh

    from repro.distributed import runtime
    from repro.distributed.plan_ir import plan_fine_from_dense
    from repro.distributed.runtime import compile_spgemm
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    a_s = random_structure(n, n, density, rng)
    plan, inst = plan_fine_from_dense(a_s, a_s, p)
    mesh = Mesh(np.array(jax.devices()[:p]), ("x",))
    exe = compile_spgemm(plan, inst.a, inst.b, mesh, cache=False)
    vals = rng.standard_normal(a_s.nnz).astype(np.float32)
    jax.block_until_ready(exe(vals, vals))  # warmup call
    traces0 = runtime.trace_count()
    total0 = time.perf_counter()
    best = float("inf")
    for _ in range(iters):
        vals = rng.standard_normal(a_s.nnz).astype(np.float32)
        t0 = time.perf_counter()
        jax.block_until_ready(exe(vals, vals))
        best = min(best, time.perf_counter() - t0)
    total_s = time.perf_counter() - total0
    assert runtime.trace_count() == traces0, "MCL loop retraced after warmup"
    return {
        "name": f"exec/mcl_loop/n{n}/p{p}",
        "status": "ok",
        "us_per_call": int(best * 1e6),
        "total_s": round(total_s, 3),
        "iters": iters,
        "retraces_after_warmup": runtime.trace_count() - traces0,
        "ideal_words": plan.comm_words_ideal,
    }


def _pack_micro(reps: int = 5) -> dict:
    """Host-packing micro-cell: the old per-device Python loop vs the
    ``np.nonzero(local_ids >= 0)`` scatter idiom (device-independent)."""
    from repro.distributed.plan_ir import padded_id_lists

    rng = np.random.default_rng(0)
    p, I, K = 512, 16384, 32  # many devices, small shards: loop-bound regime
    local_rows, _ = padded_id_lists(rng.integers(0, p, I), p)
    dense = rng.standard_normal((I, K)).astype(np.float32)
    I_max = local_rows.shape[1]

    def pack_loop():
        out = np.zeros((p, I_max, K), dense.dtype)
        for d in range(p):
            rows = local_rows[d]
            valid = rows >= 0
            out[d, valid] = dense[rows[valid]]
        return out

    def pack_vec():
        out = np.zeros((p, I_max, K), dense.dtype)
        dev, slot = np.nonzero(local_rows >= 0)
        out[dev, slot] = dense[local_rows[dev, slot]]
        return out

    np.testing.assert_array_equal(pack_loop(), pack_vec())

    def best_of(fn):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    loop_s = best_of(pack_loop)
    vec_s = best_of(pack_vec)
    return {
        "name": "exec/micro/pack_rows",
        "status": "ok",
        "us_per_call": int(vec_s * 1e6),
        "loop_us": int(loop_s * 1e6),
        "speedup_vs_loop": round(loop_s / vec_s, 1),
    }


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    import jax

    from benchmarks.common import emit

    records = [_pack_micro()]
    if quick:
        p_list, n, density, steady_reps, rebuild_reps, iters = (4,), 96, 0.06, 15, 2, 10
    else:
        p_list, n, density, steady_reps, rebuild_reps, iters = (4, 8), 192, 0.04, 25, 3, 20
    for p in p_list:
        if jax.device_count() < p:
            records.append(
                {
                    "name": f"exec/all/p{p}",
                    "status": "skipped",
                    "reason": f"{jax.device_count()} device(s) < p={p}",
                }
            )
            continue
        records.append(_fine_cell(p, n, density, steady_reps, rebuild_reps))
        records.append(_monoC_cell(p, n, density, 8, steady_reps, rebuild_reps))
        records.append(_mcl_cell(p, n, density, iters))
    emit(records, out_dir, "exec.json")
    return records


if __name__ == "__main__":
    import argparse
    import os

    # executors need multiple devices: force host devices BEFORE jax imports
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=8",
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes, p in {4, 8}")
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes (the default)")
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full):
        print(r)
