"""Fig. 9 reproduction: MCL adjacency squaring, strong scaling.

Symmetric A: column-wise == row-wise, monoB == monoA.  Expected qualitative
result (Sec. 6.3): on scale-free graphs 2D/3D models need far less
communication than 1D and keep scaling with p (downward curves) while 1D
flattens; 1D partitions violate the balance constraint (heavy vertices).
roadnetca (mesh-like) is the exception where 1D is fine.
"""
from __future__ import annotations

from benchmarks.common import emit, run_cell
from repro.core.matrices import mcl_instance

# (name, scale) tuned so the 2D/3D hypergraphs stay under the pin cap —
# roughly doubled toward paper scale alongside the flat-CSR partitioner
# and the 16M PIN_CAP
INSTANCES = [
    ("facebook", 0.25),
    ("dip", 0.75),
    ("wiphi", 0.75),
    ("biogrid11", 0.5),
    ("enron", 0.5),
    ("dblp", 0.4),
    ("roadnetca", 0.75),
]
MODELS = ("rowwise", "outer", "monoA", "monoC", "fine")


def run(out_dir=None, quick=False):
    chosen = [INSTANCES[0], INSTANCES[-1]] if quick else INSTANCES
    ps = (16,) if quick else (4, 16, 64)
    records = []
    for name, scale in chosen:
        inst = mcl_instance(name, scale=scale * (0.5 if quick else 1.0))
        for p in ps:
            for model in MODELS:
                records.append(run_cell(inst, model, p, eps=0.10))
    emit(records, out_dir, "mcl.json")
    return records
