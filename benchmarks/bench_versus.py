"""Head-to-head: sparsity-dependent ``model="auto"`` vs oblivious SUMMA.

The paper's headline claim as a live gate: a hypergraph partition tuned to
the instance's sparsity must communicate no more than the classic
sparsity-*oblivious* competitor.  For each AMG/LP/MCL instance this suite

1. plans ``model="auto"`` (partitions every executable model, keeps the
   communication-minimal one) and the ``model="summa2d"`` baseline;
2. asserts the measured == predicted identity on BOTH sides — every
   selection record's route-table words equal its connectivity prediction,
   and SUMMA's route tables ship exactly the closed-form
   ``nnz(A)(pc-1) + nnz(B)(pr-1)`` volume — so the comparison below is
   between *verified* numbers, not two cost models;
3. records ``comm_ratio = auto_words / summa_words`` (< 1: the partition
   beats the oblivious broadcast) and, when the process owns >= p devices,
   runs both executors against the dense oracle.

Acceptance (also enforced by ``check_regression.py versus``): auto wins on
at least 2 of the 3 application instances.  SUMMA legitimately wins some
near-dense instances — the suite reports the ratio so that regime stays
visible instead of hidden.

Run standalone with forced host devices to exercise the executors:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/bench_versus.py
"""
from __future__ import annotations

import time

import numpy as np

#: auto must beat (or tie) the oblivious baseline on this many of the three
#: application instances — the suite FAILS otherwise, in any harness
MIN_WINS = 2


def _oracle_exec(handle, a_dense, b_dense, want) -> dict:
    """Compile + run one planned pipeline; report cold wall time + max err."""
    inst = handle.instance
    a_vals = a_dense[inst.a.coo()]
    b_vals = b_dense[inst.b.coo()]
    t0 = time.time()
    got = handle(a_vals, b_vals)
    prefix = handle.model if handle.model == "summa2d" else "auto"
    return {
        f"{prefix}_run_s": round(time.time() - t0, 3),
        f"{prefix}_max_err": float(np.abs(got - want).max()),
    }


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    import repro
    from benchmarks.bench_select import _instances
    from benchmarks.common import emit, random_valued_dense
    from repro.api import device_count
    from repro.distributed.summa import summa_words_ideal

    p = 4 if quick else 8
    rng = np.random.default_rng(0)
    records = []
    wins = 0
    for inst in _instances(quick):
        t0 = time.time()
        auto = repro.plan(inst, p=p, model="auto")
        auto_s = time.time() - t0
        t0 = time.time()
        summa = repro.plan(inst, p=p, model="summa2d")
        summa_s = time.time() - t0

        # measured == predicted on every contestant before comparing them
        for sel in auto.selection:
            assert sel["planned_words"] == sel["predicted_words"], (
                f"{inst.name}/{sel['model']}: planned {sel['planned_words']} "
                f"!= predicted {sel['predicted_words']}"
            )
        s_report = summa.cost_report()
        s_plan = summa.execution_plan
        assert s_report["planned_words"] == s_report["predicted_words"], s_report
        assert s_report["predicted_words"] == summa_words_ideal(
            inst, s_plan.pr, s_plan.pc
        )

        auto_words = auto.cost_report()["predicted_words"]
        summa_words = s_report["predicted_words"]
        win = int(auto_words <= summa_words)
        wins += win
        rec = {
            "name": f"{inst.name}/versus/p{p}",
            "status": "ok",
            "us_per_call": int((auto_s + summa_s) * 1e6),
            "p": p,
            "auto_model": auto.model,
            "auto_words": int(auto_words),
            "summa_words": int(summa_words),
            "summa_mesh": f"{s_plan.pr}x{s_plan.pc}",
            "comm_ratio": round(auto_words / max(summa_words, 1), 4),
            "auto_wins": win,
            "auto_messages": auto.cost_report()["planned_messages"],
            "summa_messages": s_report["planned_messages"],
        }
        if device_count() >= p:
            a_dense = random_valued_dense(inst.a, rng)
            b_dense = random_valued_dense(inst.b, rng)
            want = a_dense @ b_dense
            rec.update(_oracle_exec(auto, a_dense, b_dense, want))
            rec.update(_oracle_exec(summa, a_dense, b_dense, want))
            for k in ("auto_max_err", "summa2d_max_err"):
                assert rec[k] < 1e-2, f"{rec['name']}: {k} = {rec[k]}"
        else:
            rec["run"] = f"skipped ({device_count()} device(s) < p={p})"
        records.append(rec)
    assert wins >= MIN_WINS, (
        f"sparsity-dependent auto beat oblivious SUMMA on only {wins} of "
        f"{len(records)} instances (need >= {MIN_WINS}): "
        + ", ".join(f"{r['name']} ratio={r['comm_ratio']}" for r in records)
    )
    emit(records, out_dir, "versus.json")
    return records


if __name__ == "__main__":
    import argparse
    import os

    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=8",
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale instances")
    ap.add_argument("--quick", action="store_true", help="CI smoke scale")
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full):
        print(r)
