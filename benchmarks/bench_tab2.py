"""Tab. II reproduction: instance statistics at our reduced scales."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.matrices import amg_instances, lp_instance, mcl_instance


def run(out_dir=None, quick=False):
    records = []
    insts = []
    # paper scale raised (12 -> 15, LP/MCL scales ~doubled) with the
    # flat-CSR partitioner; quick stays container-fast
    n = 9 if quick else 15
    insts += list(amg_instances(n))
    if not quick:
        insts += list(amg_instances(9, flavor="sa_rho"))
    insts += [lp_instance("fome21", scale=0.02 if quick else 0.10)]
    insts += [mcl_instance("facebook", scale=0.06 if quick else 0.25)]
    if not quick:
        insts += [
            lp_instance("sgpf5y6", scale=0.10),
            mcl_instance("dip", scale=0.75),
            mcl_instance("roadnetca", scale=0.75),
        ]
    for inst in insts:
        s = inst.stats()
        records.append(
            {
                "name": f"tab2/{inst.name}",
                "status": "ok",
                "us_per_call": 0,
                **{k: (round(v, 2) if isinstance(v, float) else v) for k, v in s.items()},
            }
        )
    emit(records, out_dir, "tab2.json")
    return records
