"""Tab. II reproduction: instance statistics at our reduced scales."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.matrices import amg_instances, lp_instance, mcl_instance


def run(out_dir=None, quick=False):
    records = []
    insts = []
    n = 9 if quick else 12
    insts += list(amg_instances(n))
    if not quick:
        insts += list(amg_instances(9, flavor="sa_rho"))
    insts += [lp_instance("fome21", scale=0.02 if quick else 0.05)]
    insts += [mcl_instance("facebook", scale=0.06 if quick else 0.12)]
    if not quick:
        insts += [
            lp_instance("sgpf5y6", scale=0.05),
            mcl_instance("dip", scale=0.5),
            mcl_instance("roadnetca", scale=0.5),
        ]
    for inst in insts:
        s = inst.stats()
        records.append(
            {
                "name": f"tab2/{inst.name}",
                "status": "ok",
                "us_per_call": 0,
                **{k: (round(v, 2) if isinstance(v, float) else v) for k, v in s.items()},
            }
        )
    emit(records, out_dir, "tab2.json")
    return records
