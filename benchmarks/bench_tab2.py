"""Tab. II reproduction: instance statistics at our reduced scales.

``us_per_call`` is real work, not a placeholder: per instance it times
construction (the symbolic SpGEMM + multiplication-space walk — the actual
instance-analysis hot path), ``inst.stats()``, and one representative model
build, so the suite doubles as a regression canary for that path.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import build_model
from repro.core.matrices import amg_instances, lp_instance, mcl_instance


def run(out_dir=None, quick=False):
    records = []
    makers = []
    # paper scale raised (12 -> 15, LP/MCL scales ~doubled) with the
    # flat-CSR partitioner; quick stays container-fast
    n = 9 if quick else 15
    makers.append(lambda: list(amg_instances(n)))
    if not quick:
        makers.append(lambda: list(amg_instances(9, flavor="sa_rho")))
    makers.append(lambda: [lp_instance("fome21", scale=0.02 if quick else 0.10)])
    makers.append(lambda: [mcl_instance("facebook", scale=0.06 if quick else 0.25)])
    if not quick:
        makers += [
            lambda: [lp_instance("sgpf5y6", scale=0.10)],
            lambda: [mcl_instance("dip", scale=0.75)],
            lambda: [mcl_instance("roadnetca", scale=0.75)],
        ]
    for make in makers:
        t0 = time.perf_counter()
        group = make()
        build_each_s = (time.perf_counter() - t0) / max(len(group), 1)
        for inst in group:
            t0 = time.perf_counter()
            s = inst.stats()
            stats_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            hg = build_model(inst, "rowwise")
            model_s = time.perf_counter() - t0
            records.append(
                {
                    **{
                        k: (round(v, 2) if isinstance(v, float) else v)
                        for k, v in s.items()
                    },
                    # after the stats spread: stats() carries its own "name"
                    # which must not strip the suite prefix
                    "name": f"tab2/{inst.name}",
                    "status": "ok",
                    "us_per_call": int((build_each_s + stats_s + model_s) * 1e6),
                    "instance_us": int(build_each_s * 1e6),
                    "stats_us": int(stats_s * 1e6),
                    "model_build_us": int(model_s * 1e6),
                    "model_pins": hg.n_pins,
                }
            )
    emit(records, out_dir, "tab2.json")
    return records
