"""Serving-tier benchmarks: batched value streams + the warm-pool loop.

Two claims, two cells:

- ``serve/stream/*`` — the batched-step claim.  An MCL-style iterated
  workload (one structure, fresh values every multiply) through the classic
  one-multiply-per-call path vs the batched executor
  (``PlannedSpGEMM.compile(batch=B)``): B multiplies per dispatch amortize
  the per-call dispatch + collective launch overhead.  The cell asserts
  batched steady-state throughput is >= ``BATCHED_SPEEDUP_FLOOR``x the
  looped path (the ISSUE 8 acceptance number) and records both rates.

- ``serve/loop/*`` — the serving-loop claim.  A ``SpGEMMServer`` drains a
  mixed workload (pool hits + warm replans + cold structures, the three
  regimes production traffic mixes) after a warmup pass that populates the
  warm pool and the batch-bucket executables; the steady phase then measures
  what a warmed service actually delivers: QPS, p50/p99 request latency, and
  batch efficiency (items / padded slots).  ``us_per_call`` is the p99 — the
  number a latency SLO would gate — and ``qps`` is floor-gated by
  ``check_regression.py`` against a machine-calibrated baseline.

Run standalone with forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src:. python benchmarks/bench_serve.py --quick
"""
from __future__ import annotations

import time

import numpy as np

BATCHED_SPEEDUP_FLOOR = 3.0


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _stream_cell(p, n, density, batch, reps, model="fine", seed=0) -> dict:
    """Batched vs looped steady-state on an iterated same-structure stream.

    Both paths ship the same ``batch`` multiplies per timed repetition with
    host packing included (fresh values each call, the MCL regime); only the
    dispatch granularity differs.  Timing is min-of-N over full repetitions
    (heavy-tailed collective stragglers would otherwise dominate the gate).
    """
    import jax

    import repro
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(seed)
    a_s = random_structure(n, n, density, rng)
    planned = repro.plan(a_s, a_s, p=p, model=model)
    exe_one = planned.compile()
    exe_batch = planned.compile(batch=batch)
    vals = [rng.standard_normal(a_s.nnz).astype(np.float32) for _ in range(batch)]
    stack = np.stack(vals)

    def looped():
        for v in vals:
            jax.block_until_ready(exe_one.runtime(*exe_one.pack(v, v)))

    def batched():
        jax.block_until_ready(exe_batch.runtime(*exe_batch.pack(stack, stack)))

    looped()  # warmup both executables (compiles excluded from timing)
    batched()
    looped_s = _best_of(looped, reps)
    batched_s = _best_of(batched, reps)
    speedup = looped_s / batched_s
    assert speedup >= BATCHED_SPEEDUP_FLOOR, (
        f"batched stream is only {speedup:.1f}x the one-multiply-per-call "
        f"path ({batched_s * 1e6 / batch:.0f} vs {looped_s * 1e6 / batch:.0f} "
        f"us/multiply); the serving tier claims >= {BATCHED_SPEEDUP_FLOOR}x"
    )
    return {
        "name": f"serve/stream/{model}/n{n}/p{p}",
        "status": "ok",
        "us_per_call": int(batched_s / batch * 1e6),
        "looped_us_per_call": int(looped_s / batch * 1e6),
        "qps": int(batch / batched_s),
        "looped_qps": int(batch / looped_s),
        "speedup_vs_looped": round(speedup, 1),
        "batch": batch,
    }


def _loop_cell(p, n, density, requests, structures, model="fine", seed=1) -> dict:
    """Warmed serving loop over mixed traffic: hits + warm replans + colds.

    The warmup pass submits one window per structure so planning, AOT
    compiles, and every batch bucket the steady phase will use are already
    resident; the timed phase then serves ``requests`` mixed requests and
    reports the warmed service's QPS / latency / batch efficiency.
    """
    from repro.launch.serve import ServeStats, SpGEMMServer
    from repro.sparse.structure import random_structure

    from repro.sparse.structure import from_coo

    rng = np.random.default_rng(seed)
    pool = [random_structure(n, n, density, rng) for _ in range(structures)]
    server = SpGEMMServer(p=p, model=model, max_batch=8, batch_window=16, seed=seed)

    def vals(s):
        return (
            rng.standard_normal(s.nnz).astype(np.float32),
            rng.standard_normal(s.nnz).astype(np.float32),
        )

    def perturb(s, frac=0.08):
        """Genuine drift (the MCL/AMG regime): most nonzeros survive, so the
        session warm-starts instead of replanning cold."""
        rows, cols = s.coo()
        keep = rng.random(len(rows)) > frac
        extra = max(1, int(frac * len(rows)))
        return from_coo(
            np.concatenate([rows[keep], rng.integers(0, n, extra)]),
            np.concatenate([cols[keep], rng.integers(0, n, extra)]),
            s.shape,
        )

    # warmup: every structure through every bucket the steady phase uses
    for s in pool:
        for m in (8, 1):
            for _ in range(m):
                va, vb = vals(s)
                server.submit((s, va), (s, vb))
            server.drain()
    # reset the accounting; keep the warm pool and compiled executables
    server.stats = ServeStats()
    server._latencies.clear()
    server._t_first = server._t_last = None
    steady_from = len(server.session.events)

    drift_every = max(8, requests // 4)
    for i in range(requests):
        if i and i % drift_every == 0:
            # mild structure drift mid-stream: absorbed by a warm replan
            pool[i % structures] = perturb(pool[i % structures])
        elif i == (requests // 2) + 1:
            # one cold structure: the worst-case path rides the same p99
            pool[i % structures] = random_structure(n, n, density, rng)
        s = pool[i % structures]
        va, vb = vals(s)
        server.submit((s, va), (s, vb))
        if server.queue_depth >= server.config.batch_window:
            server.step()
    server.drain()
    report = server.report()
    from collections import Counter

    events = dict(Counter(e.kind for e in server.session.events[steady_from:]))
    assert report["completed"] == requests, report
    assert events.get("hit", 0) > 0, "steady phase never hit the warm pool"
    assert events.get("warm_replan", 0) >= 1, events
    return {
        "name": f"serve/loop/{model}/n{n}/p{p}",
        "status": "ok",
        "us_per_call": report["p99_us"],
        "p50_us": report["p50_us"],
        "qps": report["qps"],
        "batch_efficiency": report["batch_efficiency"],
        "dispatches": report["dispatches"],
        "requests": requests,
        "hits": events.get("hit", 0),
        "warm_replans": events.get("warm_replan", 0),
        "cold_replans": events.get("cold_replan", 0),
    }


def _faults_cell(p, n, density, requests, model="fine", seed=2) -> dict:
    """Serving under scripted faults: transient execute failures mid-stream
    are retried by the session policy — every request still completes."""
    from repro.launch.serve import SpGEMMServer
    from repro.resilience import FaultPolicy
    from repro.sparse.structure import random_structure
    from repro.testing import faults

    rng = np.random.default_rng(seed)
    s = random_structure(n, n, density, rng)
    server = SpGEMMServer(
        p=p, model=model, max_batch=4, policy=FaultPolicy(backoff_s=0.0), seed=seed
    )
    with faults.inject("execute", times=2, after=2) as script:
        for _ in range(requests):
            va = rng.standard_normal(s.nnz).astype(np.float32)
            vb = rng.standard_normal(s.nnz).astype(np.float32)
            server.submit((s, va), (s, vb))
        server.drain()
    report = server.report()
    assert script.fired == 2, script.fired
    assert report["completed"] == requests, report
    retries = sum(1 for e in server.session.events if e.kind == "retry")
    assert retries >= 2, retries
    return {
        "name": f"serve/faults/{model}/n{n}/p{p}",
        "status": "ok",
        "us_per_call": report["p99_us"],
        "qps": report["qps"],
        "faults_fired": script.fired,
        "retries": retries,
    }


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    import jax

    from benchmarks.common import emit

    records = []
    if quick:
        p_list, n, density, batch, reps = (4,), 96, 0.06, 8, 8
        requests, structures = 48, 3
    else:
        p_list, n, density, batch, reps = (4, 8), 192, 0.04, 8, 15
        requests, structures = 128, 4
    for p in p_list:
        if jax.device_count() < p:
            records.append(
                {
                    "name": f"serve/all/p{p}",
                    "status": "skipped",
                    "reason": f"{jax.device_count()} device(s) < p={p}",
                }
            )
            continue
        records.append(_stream_cell(p, n, density, batch, reps))
        records.append(_loop_cell(p, n, density, requests, structures))
        records.append(_faults_cell(p, n, density, requests=12))
    emit(records, out_dir, "serve.json")
    return records


if __name__ == "__main__":
    import argparse
    import os

    # the serving loop needs multiple devices: force host devices BEFORE jax
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=8",
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes, p in {4, 8}")
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes (the default)")
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full):
        print(r)
