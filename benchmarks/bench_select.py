"""End-to-end model selection sweep: predicted vs measured communication.

For each AMG/LP/MCL instance, partition *every* hypergraph model, lower all
seven (the full registry is executable) to plans, count the words their
routing tables ship, and — when the process owns enough devices — run the
executors against the dense oracle.  The suite's acceptance assertion is
the paper's central claim made executable: for the replicated-free plans
(fine-grained and the monochrome family) the measured words equal the
connectivity metric the partitioner minimized, exactly; rowwise/columnwise
match through their nnz-weighted useful words.

Run standalone with forced host devices to exercise the executors:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/bench_select.py

Under ``run.py`` (single device) the executor cells are skipped; the
predicted == measured assertion is device-independent and always runs.
"""
from __future__ import annotations

import numpy as np

from repro.distributed.registry import MODEL_SPECS

# replicated-free plans: every shipped item is one nonzero payload, so the
# words on the wire (minus padding) are exactly the connectivity cost
EXACT_MODELS = tuple(n for n, s in MODEL_SPECS.items() if s.measured == "exact")
# outer's fold volume and rowwise's nnz-weighted useful words also reproduce
# their models' predictions; asserted too, reported separately
USEFUL_EXACT_MODELS = tuple(n for n, s in MODEL_SPECS.items() if s.measured == "useful")


def _instances(quick: bool):
    from repro.core.matrices import amg_instances, lp_instance, mcl_instance

    if quick:
        yield amg_instances(6)[0]
        yield lp_instance("fome21", scale=0.02)
        yield mcl_instance("facebook", scale=0.02)
    else:
        yield from amg_instances(9)
        yield lp_instance("fome21", scale=0.05)
        yield mcl_instance("facebook", scale=0.06)


def run(out_dir: str | None = None, quick: bool = True) -> list[dict]:
    from benchmarks.common import PIN_CAP, emit, random_valued_dense
    from repro.distributed.select import sweep_instance

    records = []
    p_list = (4,) if quick else (4, 8)
    rng = np.random.default_rng(0)
    for inst in _instances(quick):
        a_dense = random_valued_dense(inst.a, rng)
        b_dense = random_valued_dense(inst.b, rng)
        for p in p_list:
            recs = sweep_instance(
                inst,
                p,
                a_dense=a_dense,
                b_dense=b_dense,
                execute=True,
                pin_cap=PIN_CAP,
            )
            for rec in recs:
                if rec["status"] != "ok":
                    continue
                model = rec["model"]
                if model in EXACT_MODELS + USEFUL_EXACT_MODELS and "measured_words" in rec:
                    assert rec["measured_words"] == rec["predicted_words"], (
                        f"{rec['name']}: measured {rec['measured_words']} != "
                        f"predicted {rec['predicted_words']}"
                    )
                    rec["measured_eq_predicted"] = True
                if "exec_max_err" in rec:
                    assert rec["exec_max_err"] < 1e-2, (
                        f"{rec['name']}: executor diverged from the oracle "
                        f"(max err {rec['exec_max_err']})"
                    )
            records.extend(recs)
    emit(records, out_dir, "select.json")
    return records


if __name__ == "__main__":
    import argparse
    import os

    # executors need multiple devices: force host devices BEFORE jax imports
    # (safe here — standalone entry, jax not yet imported via repro)
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=8",
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale instances")
    ap.add_argument("--out", default=None, help="artifact dir, e.g. experiments/paper")
    args = ap.parse_args()
    for r in run(out_dir=args.out, quick=not args.full):
        print(r)
