"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dryrun-dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import analyze_record, PEAK_FLOPS


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(dryrun_dir: str, only_base: bool = True) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if only_base and rec.get("opts"):
            continue
        name = f"{rec['arch']} × {rec['shape']} × {rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(f"| {name} | skip | {rec.get('reason','')[:58]} | | | |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {name} | FAIL | {rec.get('error','')[:58]} | | | |")
            continue
        mem = rec.get("memory", {})
        cols = rec.get("collectives", {})
        col_str = " ".join(
            f"{k.replace('all-','a').replace('reduce-scatter','rs').replace('collective-permute','cp')}:{v['count']}"
            for k, v in sorted(cols.items())
        )
        rows.append(
            f"| {name} | ok | flops/chip {rec['flops']:.2e}, hbm-rw {rec['bytes_accessed']:.2e} B"
            f" | arg {fmt_bytes(mem.get('argument_size_in_bytes',0))} GB, temp {fmt_bytes(mem.get('temp_size_in_bytes',0))} GB"
            f" | wire {rec['wire_bytes']:.2e} B | {col_str} |"
        )
    head = (
        "| cell | status | cost_analysis | memory_analysis (per chip) | collective bytes | collective schedule |\n"
        "|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def roofline_table(dryrun_dir: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("opts"):
            continue
        name = f"{rec['arch']} | {rec['shape']} | {rec['mesh']}"
        if rec.get("status") != "ok":
            rows.append(f"| {name} | — | — | — | {rec.get('reason','skip')[:40]} | | |")
            continue
        a = analyze_record(rec)
        rows.append(
            f"| {name} | {a['compute_s']:.3f} | {a['memory_s']:.3f} | "
            f"{a['collective_s']:.3f} | **{a['dominant']}** | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']*100:.1f}% |"
        )
    head = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | useful | roofline |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def perf_rows(dryrun_dir: str) -> str:
    """All opt-tagged cells: the hillclimb measurements."""
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("opts") or rec.get("status") != "ok":
            continue
        a = analyze_record(rec)
        mem = rec.get("memory", {})
        rows.append(
            f"| {rec['arch']} × {rec['shape']} | {'+'.join(rec['opts'])} | "
            f"{a['compute_s']:.3f} | {a['memory_s']:.3f} | {a['collective_s']:.3f} | "
            f"{max(a['compute_s'],a['memory_s'],a['collective_s']):.3f} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes',0))} GB |"
        )
    head = (
        "| cell | opts | compute s | memory s | collective s | step LB s | temp/chip |\n"
        "|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def partition_table(paper_dir: str) -> str:
    """Engine comparison table (benchmarks/bench_partition.py): flat vs loop
    vs device, with pins/sec planning throughput so the trajectory across
    PRs is visible straight from partition.json."""
    path = os.path.join(paper_dir, "partition.json")
    if not os.path.exists(path):
        return "(no partition.json — run `python benchmarks/bench_partition.py --full --out experiments/paper`)"
    rows = []
    for rec in json.load(open(path)):
        if rec.get("status") != "ok":
            rows.append(f"| {rec['name']} | skip | {rec.get('reason','')} | | | | | |")
            continue
        pins_per_sec = rec.get("pins_per_sec")
        throughput = f"{pins_per_sec/1e6:.2f} Mpins/s" if pins_per_sec else ""
        # each cell family carries the speedup/quality ratio against its own
        # reference: loop-FM for the host engines, best-of-S sequential flat
        # multi-start for the device engine
        if "speedup_vs_loop" in rec:
            speedup, conn_vs = f"{rec['speedup_vs_loop']}x", rec["conn_vs_loop"]
        elif "speedup_vs_flat_multistart" in rec:
            speedup = f"{rec['speedup_vs_flat_multistart']}x"
            conn_vs = rec["conn_vs_flat_multistart"]
        else:
            speedup, conn_vs = "", ""
        rows.append(
            f"| {rec['name']} | {rec.get('engine', '')} | "
            f"{rec['us_per_call']/1e6:.3f} s | {throughput} | "
            f"{rec['connectivity']} | {rec['comp_imbalance']:.3f} | "
            f"{speedup} | {conn_vs} |"
        )
    head = (
        "| cell | engine | partition s | throughput | connectivity | "
        "imbalance | speedup vs ref | conn vs ref |\n"
        "|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--baseline-dir", default="experiments/baseline")
    ap.add_argument("--paper-dir", default="experiments/paper")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("<!-- dryrun table -->")
        print(dryrun_table(args.baseline_dir))
    if args.section in ("all", "roofline"):
        print("\n<!-- roofline table (baseline) -->")
        print(roofline_table(args.baseline_dir))
    if args.section in ("all", "perf"):
        print("\n<!-- perf (opt-tagged) cells -->")
        print(perf_rows(args.dryrun_dir))
    if args.section in ("all", "partition"):
        print("\n<!-- partitioner engine table -->")
        print(partition_table(args.paper_dir))


if __name__ == "__main__":
    main()
