"""Benchmark-regression gate: diff current timings against committed baselines.

``--update`` records a baseline file per suite under ``experiments/baselines``
(cell timings + a machine-calibration measurement); the default check mode
re-runs the suite and fails when any comparable cell is more than
``--tolerance`` (default 25%) slower than the baseline *after* scaling by the
calibration ratio, so a slower CI runner doesn't trip the gate while a real
hot-path regression does.

Cells are compared by name; only ``status == ok`` cells with a timing above
``--min-us`` on both sides participate (micro-cells are timer noise).
Quality metrics ride along: a cell whose ``connectivity`` worsens by more
than the tolerance also fails, and a cell whose throughput
(``pins_per_sec`` planning rate, serving-loop ``qps``) drops below the
machine-scaled baseline floor fails too — the gate guards the
speed/quality claim of the partitioner and the serving tier's QPS/p99
headline, not just wall time.  Engine-vs-engine speedup ratios
(``speedup_vs_host_coarsen``, the device-resident V-cycle's end-to-end
win over the host descend — coarsening included) are floor-gated
*without* machine scaling: both sides of a ratio are timed interleaved on
the same host, so the machine factor cancels and the ratio is the one
number immune to a slow runner.

CI usage:
    PYTHONPATH=src:. python benchmarks/check_regression.py partition plan
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

BASELINE_DIR = os.path.join("experiments", "baselines")
SUITES = ("partition", "plan", "exec", "session", "serve", "versus")
MIN_US = {
    "partition": 5_000,
    "plan": 2_500,
    "exec": 1_000,
    "session": 2_000,
    "serve": 100,
    "versus": 5_000,
}
# per-suite slowdown allowance overriding the CLI/global default: exec/serve
# cells time multi-host-device collectives whose scheduling jitter is far
# above the numpy suites' (2-3x between runs on a contended machine), while
# the regressions they guard against — steady state falling back to the
# rebuild/retrace path, or the serving loop losing its warm pool / batched
# dispatch — are 5-170x cliffs.  A 3x gate is immune to the jitter and
# still catches those cliffs instantly.
TOLERANCE = {"exec": 2.0, "serve": 2.0}
#: throughput fields floor-gated per cell (same machine-factor scaling the
#: timing ceiling gets): partitioner planning rate, serving-loop QPS
THROUGHPUT_FIELDS = ("pins_per_sec", "qps")
#: engine-vs-engine speedup ratios floor-gated with NO machine scaling —
#: both sides are timed interleaved on one host so the factor cancels.
#: A cell pair carries the ratio on both records; it is gated once.
RATIO_FLOOR_FIELDS = ("speedup_vs_host_coarsen",)


def _suite_records(suite: str) -> list[dict]:
    if suite == "partition":
        from benchmarks.bench_partition import run

        return run(out_dir=None, quick=True)
    if suite == "plan":
        from benchmarks.bench_plan_build import run

        # full size: the quick cells finish in ~1.5ms and would all fall
        # under the noise floor, leaving the gate vacuous; at 10k rows the
        # vectorized cells are 4-10ms and the whole suite still runs in ~6s
        return run(out_dir=None, quick=False)
    if suite == "exec":
        # steady-state executor cells (needs forced host devices >= 4, the
        # multidev CI job; single-device runs emit only skip cells)
        from benchmarks.bench_exec import run

        return run(out_dir=None, quick=True)
    if suite == "session":
        # the gated cell (session/warm_replan) is planning-only numpy; the
        # session_exec cells ride along ungated (the "exec" name filter
        # below) but still assert their own floors when devices allow
        from benchmarks.bench_session import run

        return run(out_dir=None, quick=True)
    if suite == "serve":
        # serving tier: batched-stream speedup + warmed serving-loop QPS/p99
        # (multidev CI job; single-device runs emit only skip cells)
        from benchmarks.bench_serve import run

        return run(out_dir=None, quick=True)
    if suite == "versus":
        # auto vs oblivious SUMMA: run() itself asserts auto wins >= 2 of 3
        # instances (so the gate fails hard, not just on drift); the check
        # below additionally pins each instance's win bit and comm_ratio
        from benchmarks.bench_versus import run

        return run(out_dir=None, quick=True)
    raise ValueError(f"unknown suite {suite!r}; choose from {SUITES}")


def calibrate() -> int:
    """Machine-speed probe: a fixed numpy workload shaped like the engines'
    hot paths (stable argsort + bincount + scalar loop), best of 5, in
    microseconds.  Sized ~100ms so scheduler jitter averages out — the
    factor must be stable to a few percent for a 25% gate to mean anything."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 30, 2_000_000)
    x = rng.standard_normal((512, 512))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        order = np.argsort(keys, kind="stable")
        np.bincount(keys[order] % 65536)
        acc = 0
        for i in range(200_000):  # scalar-FM-style Python-loop component
            acc += i & 7
        (x @ x).sum()
        best = min(best, time.perf_counter() - t0)
    return int(best * 1e6)


def baseline_path(suite: str) -> str:
    return os.path.join(BASELINE_DIR, f"{suite}_smoke.json")


def update(suite: str, calibration_us: int) -> None:
    # best-of-2 per cell: a baseline inflated by a scheduling hiccup would
    # make the gate vacuous for that cell
    records = _suite_records(suite)
    second = {r["name"]: r for r in _suite_records(suite)}
    for rec in records:
        twin = second.get(rec["name"])
        if twin and rec.get("status") == "ok" and "us_per_call" in twin:
            rec["us_per_call"] = min(rec["us_per_call"], twin["us_per_call"])
    os.makedirs(BASELINE_DIR, exist_ok=True)
    payload = {"calibration_us": calibration_us, "records": records}
    with open(baseline_path(suite), "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[{suite}] baseline written: {baseline_path(suite)}")


def check(suite: str, tolerance: float, min_us: int, cur_cal: int) -> list[str]:
    with open(baseline_path(suite)) as f:
        base = json.load(f)
    base_by_name = {
        r["name"]: r for r in base["records"] if r.get("status") == "ok"
    }
    # the probe only ever RELAXES the gate (slower runner -> larger
    # allowance); a probe that ran fast while the benches ran slow must not
    # tighten it below the raw baseline comparison
    factor = max(cur_cal / max(base["calibration_us"], 1), 1.0)
    records = _suite_records(suite)
    failures = []
    gated_ratios: set[str] = set()
    for rec in records:
        if rec.get("status") != "ok" or rec["name"] not in base_by_name:
            continue
        if suite not in ("exec", "serve") and (
            "exec" in rec["name"] or "/loop" in rec["name"]
        ):
            # in the partition/plan suites, executor cells time XLA jit
            # compiles and the retained loop references are single-repeat
            # Python loops — both far too variable for a 25% gate.  The
            # exec suite's own cells are steady-state means (compiles
            # excluded from the timed region) and ARE gated.
            continue
        ref = base_by_name[rec["name"]]
        cur_us, base_us = rec.get("us_per_call", 0), ref.get("us_per_call", 0)
        if min(cur_us, base_us) >= min_us:
            allowed = base_us * factor * (1 + tolerance)
            verdict = "FAIL" if cur_us > allowed else "ok"
            print(
                f"[{suite}] {verdict:4s} {rec['name']}: {cur_us} us "
                f"(baseline {base_us} us x {factor:.2f} machine factor, "
                f"allowed {int(allowed)})"
            )
            if cur_us > allowed:
                failures.append(f"{rec['name']}: {cur_us} us > {int(allowed)} us")
        if "connectivity" in rec and "connectivity" in ref and ref["connectivity"]:
            if rec["connectivity"] > ref["connectivity"] * (1 + tolerance):
                failures.append(
                    f"{rec['name']}: connectivity {rec['connectivity']} > "
                    f"baseline {ref['connectivity']} * {1 + tolerance}"
                )
        # versus head-to-head ride-alongs (machine-independent, so no
        # calibration factor): an instance where auto used to beat the
        # oblivious SUMMA baseline and no longer does is a regression even
        # at identical wall time, and comm_ratio (auto words / SUMMA words,
        # lower is better) is ceiling-gated like connectivity
        if "auto_wins" in ref and rec.get("auto_wins", 0) < ref["auto_wins"]:
            failures.append(
                f"{rec['name']}: auto_wins {rec.get('auto_wins', 0)} < "
                f"baseline {ref['auto_wins']} (auto lost to SUMMA)"
            )
        if ref.get("comm_ratio"):
            if rec.get("comm_ratio", 0) > ref["comm_ratio"] * (1 + tolerance):
                failures.append(
                    f"{rec['name']}: comm_ratio {rec.get('comm_ratio', 0)} > "
                    f"baseline {ref['comm_ratio']} * {1 + tolerance}"
                )
        # throughput ride-alongs (device-engine pin rate, serving QPS): the
        # same machine factor that relaxes the timing gate lowers the floor
        for field in THROUGHPUT_FIELDS:
            if ref.get(field) and min(cur_us, base_us) >= min_us:
                floor = ref[field] / factor / (1 + tolerance)
                if rec.get(field, 0) < floor:
                    failures.append(
                        f"{rec['name']}: {field} {rec.get(field, 0)} "
                        f"< floor {int(floor)} (baseline {ref[field]})"
                    )
        # same-host speedup ratios (machine factor cancels, no scaling)
        for field in RATIO_FLOOR_FIELDS:
            if not ref.get(field) or field in gated_ratios:
                continue
            gated_ratios.add(field)
            floor = ref[field] / (1 + tolerance)
            verdict = "FAIL" if rec.get(field, 0) < floor else "ok"
            print(
                f"[{suite}] {verdict:4s} {rec['name']}: {field} "
                f"{rec.get(field, 0)} (baseline {ref[field]}, "
                f"floor {floor:.2f})"
            )
            if rec.get(field, 0) < floor:
                failures.append(
                    f"{rec['name']}: {field} {rec.get(field, 0)} "
                    f"< floor {floor:.2f} (baseline {ref[field]})"
                )
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", metavar="suite", help=f"subset of {SUITES}")
    ap.add_argument("--update", action="store_true", help="record new baselines")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=None,
        help="allowed slowdown fraction; overrides the per-suite defaults "
        "(%s, else 0.25 = 25%%) and $REGRESSION_TOLERANCE" % (TOLERANCE,),
    )
    ap.add_argument(
        "--min-us",
        type=int,
        default=None,
        help="noise floor override (per-suite defaults: %s)" % (MIN_US,),
    )
    args = ap.parse_args(argv)
    suites = args.suites or list(SUITES)
    # one probe for the whole invocation: per-suite probes recorded minutes
    # apart drift with machine load and skew the factors against each other
    calibration_us = calibrate()
    print(f"calibration: {calibration_us} us")
    if args.update:
        for s in suites:
            update(s, calibration_us)
        return
    env_tol = os.environ.get("REGRESSION_TOLERANCE")
    failures = []
    for s in suites:
        min_us = args.min_us if args.min_us is not None else MIN_US[s]
        # precedence: explicit --tolerance > env > per-suite default > 0.25
        if args.tolerance is not None:
            tolerance = args.tolerance
        elif env_tol is not None:
            tolerance = float(env_tol)
        else:
            tolerance = TOLERANCE.get(s, 0.25)
        failures += check(s, tolerance, min_us, calibration_us)
    if failures:
        print("\nREGRESSIONS:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("\nno benchmark regressions")


if __name__ == "__main__":
    main()
