"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--scale small`` (the default)
finishes in a few minutes and exercises every harness; ``--scale paper``
runs the paper-scale sweeps (tens of minutes of partitioning — the flat-CSR
refinement engine makes these feasible in-container).  ``--full`` is kept as
an alias for ``--scale paper``.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import bench_amg, bench_bounds, bench_exec, bench_kernels, bench_lp
from benchmarks import bench_mcl, bench_partition, bench_plan_build, bench_select
from benchmarks import bench_serve, bench_tab2, bench_versus, roofline
from benchmarks.common import csv_lines

SUITES = {
    "tab2": bench_tab2.run,
    "amg": bench_amg.run,
    "lp": bench_lp.run,
    "mcl": bench_mcl.run,
    "bounds": bench_bounds.run,
    "kernels": bench_kernels.run,
    "plan": bench_plan_build.run,
    "partition": bench_partition.run,
    "select": bench_select.run,
    "versus": bench_versus.run,
    "exec": bench_exec.run,
    "serve": bench_serve.run,
    "roofline": roofline.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--scale",
        default=None,
        choices=("small", "paper"),
        help="instance sizes: 'small' keeps the container default fast, "
        "'paper' runs the paper-scale sweep",
    )
    ap.add_argument(
        "--full", action="store_true", help="alias for --scale paper (kept for CI)"
    )
    ap.add_argument(
        "--quick", action="store_true", help="alias for --scale small (CI smoke)"
    )
    ap.add_argument("--only", default=None, choices=list(SUITES))
    ap.add_argument("--out", default="experiments/paper")
    args = ap.parse_args(argv)
    if args.quick and (args.full or args.scale == "paper"):
        ap.error("--quick conflicts with --full/--scale paper")
    scale = args.scale or ("paper" if args.full else "small")

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            if name == "roofline":
                records = fn(out_dir="experiments")
            else:
                records = fn(out_dir=args.out, quick=scale == "small")
        except Exception as e:  # a suite failing should not hide the others
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for line in csv_lines(records):
            print(line)
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
