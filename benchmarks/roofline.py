"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in experiments/dryrun/.

  compute term    = HLO_FLOPs_per_chip / 197e12        [s]   (bf16 peak)
  memory term     = HLO_bytes_per_chip / 819e9         [s]   (HBM bw)
  collective term = wire_bytes_per_chip / 50e9         [s]   (1 ICI link,
                    ring-model effective bytes; conservative)

cost_analysis of the partitioned module is per-chip, so no further division
by chip count is needed.  MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill),
2*N*B (decode), with N = active params for MoE.  The useful-compute ratio
MODEL_FLOPS/HLO_FLOPs exposes remat and dispatch overhead.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def model_flops_per_chip(arch: str, shape: str, n_devices: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode) with exact embedding
    accounting: the embedding gather contributes no matmul flops; the unembed
    matmul (d x V) applies to every token in train but only to the final
    token per sequence in prefill/decode."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.models import active_param_count

    cfg = get_config(arch)
    n_active = active_param_count(cfg)
    embed = cfg.vocab * cfg.d_model
    unembed_params = 0 if cfg.tie_embeddings else embed
    n_layers_only = n_active - embed - unembed_params
    unembed_matmul = embed  # d x V logits matmul (tied or not)
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        total = 6.0 * (n_layers_only + unembed_matmul) * B * S
    elif spec.kind == "prefill":
        total = 2.0 * n_layers_only * B * S + 2.0 * unembed_matmul * B
    else:  # decode: one token per sequence
        total = 2.0 * (n_layers_only + unembed_matmul) * B
    return total / n_devices


def analyze_record(rec: dict) -> dict:
    ct = rec["flops"] / PEAK_FLOPS
    mt = rec["bytes_accessed"] / HBM_BW
    xt = rec["wire_bytes"] / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": xt}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(rec["arch"], rec["shape"], rec["n_devices"])
    step_lb = max(terms.values())
    mem = rec.get("memory", {})
    hbm = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
    )
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": ct,
        "memory_s": mt,
        "collective_s": xt,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / step_lb if step_lb else 0.0,
        "hbm_bytes_per_chip": hbm,
        "fits_hbm": hbm <= HBM_PER_CHIP,
    }


def run(out_dir="experiments", dryrun_dir=None, quick=False):
    if dryrun_dir is None:  # prefer the frozen baseline artifacts
        dryrun_dir = (
            "experiments/baseline"
            if os.path.isdir("experiments/baseline")
            else "experiments/dryrun"
        )
    records = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("opts"):  # §Perf variants live in their own table
            continue
        if rec.get("status") != "ok":
            records.append(
                {
                    "name": f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                    "status": rec.get("status", "missing"),
                    "us_per_call": -1,
                    "reason": rec.get("reason", rec.get("error", "")),
                }
            )
            continue
        a = analyze_record(rec)
        records.append(
            {
                "name": f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
                "status": "ok",
                "us_per_call": int(max(a["compute_s"], a["memory_s"], a["collective_s"]) * 1e6),
                **{
                    k: (f"{v:.3e}" if isinstance(v, float) else v)
                    for k, v in a.items()
                    if k not in ("arch", "shape", "mesh")
                },
            }
        )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "roofline.json"), "w") as f:
            json.dump(records, f, indent=1)
        with open(os.path.join(out_dir, "roofline.md"), "w") as f:
            f.write(markdown_table(records))
    return records


def markdown_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| useful | roofline frac | HBM/chip | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|"[:-4],
    ]
    for r in records:
        if r["status"] != "ok":
            name = r["name"].split("/")
            lines.append(
                f"| {name[1]} | {name[2]} | {name[3]} | — | — | — | skipped: "
                f"{r.get('reason','')[:40]} | | | |"
            )
            continue
        name = r["name"].split("/")
        hbm_gb = float(r["hbm_bytes_per_chip"]) / 1e9 if r.get("hbm_bytes_per_chip") else 0
        lines.append(
            f"| {name[1]} | {name[2]} | {name[3]} | {r['compute_s']} | "
            f"{r['memory_s']} | {r['collective_s']} | {r['dominant']} | "
            f"{r['useful_ratio']} | {r['roofline_fraction']} | {hbm_gb:.1f}GB | "
            f"{'y' if r.get('fits_hbm') else 'n'} |"
        )
    return "\n".join(lines) + "\n"
