"""Sec. 4 reproduction: hypergraph (Thm. 4.5) bounds vs classical eq. (1).

The partition-based cost is an *attainable* upper bound within O(log p) of
the sparsity-dependent lower bound; eq. (1)'s memory-(in)dependent bounds are
worst-case and can be orders looser on sparse instances — which is the
paper's motivation.  Also exercises the sequential Thm. 4.10 estimate.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import (
    SpGEMMInstance,
    build_model,
    classical_bound,
    evaluate,
    memory_dependent_bound,
    memory_independent_bound,
    partition,
    sequential_io_estimate,
)
from repro.core.matrices import amg_instances, mcl_instance


def run(out_dir=None, quick=False):
    records = []
    insts = [amg_instances(6 if quick else 9)[0], mcl_instance("dip", 0.2)]
    for inst in insts:
        hg = build_model(inst, "fine")
        n_nz = inst.a.nnz + inst.b.nnz + inst.c.nnz
        for p in (4, 16) if quick else (4, 16, 64):
            t0 = time.time()
            res = partition(hg, p, eps=0.10)
            costs = evaluate(hg, res.parts, p)
            mem = max(3 * n_nz / p, 64)
            records.append(
                {
                    "name": f"bounds/{inst.name}/p{p}",
                    "status": "ok",
                    "us_per_call": int((time.time() - t0) * 1e6),
                    "hypergraph_maxpart": int(costs.max_part_cost),
                    "eq1_memdep": round(memory_dependent_bound(inst.n_mult, p, mem), 1),
                    "eq1_memindep": round(
                        memory_independent_bound(inst.n_mult, n_nz, p), 1
                    ),
                    "eq1_combined": round(classical_bound(inst.n_mult, n_nz, p, mem), 1),
                }
            )
        seq = sequential_io_estimate(build_model(inst, "fine", include_nz=True), 256)
        records.append(
            {
                "name": f"bounds/{inst.name}/sequential_M256",
                "status": "ok",
                "us_per_call": 0,
                **seq,
            }
        )
    emit(records, out_dir, "bounds.json")
    return records
