"""Device-engine invariants: ``partition(engine="device")`` vs the flat host
engine (cross-engine agreement, satellite of the device-engine PR).

The device engine is an *above-threshold* engine: the driver routes
instances at or below ``DEVICE_MIN_VERTICES`` to the host quality path, so
these tests monkeypatch the threshold to 0 to exercise the jax kernel on the
small ``test_partition_invariants.py`` instance family.  Sampled label
propagation from random starts is weaker than full multilevel recursive
bisection at these sizes (that is exactly why the threshold exists), so the
quality gate is a *bounded* connectivity ratio rather than parity; balance,
determinism, the size-threshold deferral, the jax-absent fallback and the
compile-once retrace accounting are exact.
"""
import importlib
import sys
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.sparse.structure import random_structure

partition_mod = importlib.import_module("repro.core.partition")
refine_device = importlib.import_module("repro.core.refine_device")


def _instance(seed=0, shape=(60, 50, 55), density=0.08):
    rng = np.random.default_rng(seed)
    a = random_structure(shape[0], shape[1], density, rng)
    b = random_structure(shape[1], shape[2], density, rng)
    return SpGEMMInstance(a, b)


@pytest.fixture(autouse=True)
def fresh_fallback_warnings(monkeypatch):
    """The device->flat fallback warns once per process per reason; give each
    test its own warned-set so warning assertions stay order-independent."""
    monkeypatch.setattr(partition_mod, "_FALLBACK_WARNED", set())


@pytest.fixture
def device_everywhere(monkeypatch):
    """Route every size through the device engine."""
    monkeypatch.setattr(partition_mod, "DEVICE_MIN_VERTICES", 0)


# ---------------------------------------------------------------------------
# balance + determinism
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p,eps", [(2, 0.05), (4, 0.10), (8, 0.10)])
def test_device_balance_cap_respected(device_everywhere, p, eps):
    hg = build_model(_instance(1, shape=(90, 70, 80)), "rowwise")
    res = partition(hg, p, eps=eps, seed=0, engine="device")
    w = hg.w_comp.astype(np.float64)
    part_w = np.bincount(res.parts, weights=w, minlength=p)
    cap = max((1 + eps) * w.sum() / p, float(w.max()))
    assert (part_w <= cap + 1e-9).all()


def test_device_reported_connectivity_matches_fresh_evaluation(device_everywhere):
    hg = build_model(_instance(2), "rowwise")
    res = partition(hg, 4, eps=0.10, seed=3, engine="device")
    assert res.connectivity == evaluate(hg, res.parts, 4).connectivity


def test_device_deterministic_for_fixed_seed(device_everywhere):
    hg = build_model(_instance(3, shape=(80, 60, 70)), "rowwise")
    a = partition(hg, 4, eps=0.10, seed=7, engine="device")
    b = partition(hg, 4, eps=0.10, seed=7, engine="device")
    assert np.array_equal(a.parts, b.parts)
    assert a.connectivity == b.connectivity
    c = partition(hg, 4, eps=0.10, seed=8, engine="device")
    # different seed is allowed to (and generally does) differ
    assert c.parts.shape == a.parts.shape


# ---------------------------------------------------------------------------
# bounded connectivity ratio vs the flat engine
# ---------------------------------------------------------------------------
def test_device_connectivity_ratio_bounded_vs_flat(device_everywhere):
    """Per-cell and aggregate bounds over the invariant-suite instance grid
    (all p in {2, 4, 8}).  Empirically the device engine lands ~1.10x flat in
    aggregate at these sub-threshold sizes (worst cell ~1.35); the asserted
    bounds leave headroom for sampling noise, not for regressions."""
    tot_dev = tot_flat = 0
    for seed in (0, 4, 5):
        inst = _instance(seed, shape=(60 + 10 * seed, 50 + 5 * seed, 55))
        for model in ("rowwise", "fine"):
            hg = build_model(inst, model)
            for p in (2, 4, 8):
                cd = partition(hg, p, eps=0.10, seed=seed, engine="device").connectivity
                cf = partition(hg, p, eps=0.10, seed=seed, engine="flat").connectivity
                assert cd <= 1.6 * cf, f"{model}/p{p}/seed{seed}: {cd} vs {cf}"
                tot_dev += cd
                tot_flat += cf
    assert tot_dev <= 1.25 * tot_flat


# ---------------------------------------------------------------------------
# driver routing: threshold deferral + jax-absent fallback
# ---------------------------------------------------------------------------
def test_device_defers_to_host_below_threshold():
    """Without the monkeypatch, sub-threshold instances take the flat
    quality path bit-for-bit (host FM stays authoritative there)."""
    hg = build_model(_instance(0), "rowwise")
    assert hg.n_vertices <= partition_mod.DEVICE_MIN_VERTICES
    a = partition(hg, 4, eps=0.10, seed=0, engine="device")
    b = partition(hg, 4, eps=0.10, seed=0, engine="flat")
    assert np.array_equal(a.parts, b.parts)
    assert a.connectivity == b.connectivity


def test_device_falls_back_to_flat_without_jax(device_everywhere, monkeypatch):
    """With the refine_device import blocked (as when jax is absent), the
    driver warns ONCE and produces exactly the flat-engine result —
    planning-side callers keep working with no jax installed (PR 5's
    contract), and a replanning loop doesn't spam a warning per call."""
    monkeypatch.setitem(sys.modules, "repro.core.refine_device", None)
    hg = build_model(_instance(1), "rowwise")
    with pytest.warns(RuntimeWarning, match="falling back"):
        a = partition(hg, 4, eps=0.10, seed=0, engine="device")
    b = partition(hg, 4, eps=0.10, seed=0, engine="flat")
    assert np.array_equal(a.parts, b.parts)
    # second call: same fallback, no second warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        c = partition(hg, 4, eps=0.10, seed=0, engine="device")
    assert np.array_equal(c.parts, b.parts)


def test_device_engine_failure_falls_back_to_flat(device_everywhere, monkeypatch):
    """A device engine that *fails at runtime* (OOM, kernel error) degrades
    to the flat engine with one warning and the identical flat result —
    partitioning never dies because the accelerator did."""

    def boom(hg, p, part_cap, seed, rd):
        raise RuntimeError("RESOURCE_EXHAUSTED: injected device OOM")

    monkeypatch.setattr(partition_mod, "_partition_device", boom)
    hg = build_model(_instance(1), "rowwise")
    with pytest.warns(RuntimeWarning, match="falling back"):
        a = partition(hg, 4, eps=0.10, seed=0, engine="device")
    b = partition(hg, 4, eps=0.10, seed=0, engine="flat")
    assert np.array_equal(a.parts, b.parts)
    assert a.connectivity == b.connectivity
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        partition(hg, 4, eps=0.10, seed=0, engine="device")  # warns once only


def test_unknown_engine_still_rejected():
    hg = build_model(_instance(0), "rowwise")
    with pytest.raises(ValueError):
        partition(hg, 2, engine="device2")


# ---------------------------------------------------------------------------
# compile-once shape bucketing
# ---------------------------------------------------------------------------
def test_device_kernel_retraces_once_per_shape_bucket(device_everywhere):
    """Repeat calls — and different seeds — on same-bucket shapes must reuse
    the jitted refiner: the retrace counter moves only on the first call."""
    hg = build_model(_instance(4, shape=(80, 60, 70)), "rowwise")
    partition(hg, 4, eps=0.10, seed=0, engine="device")  # warm the cache
    before = refine_device.trace_count()
    partition(hg, 4, eps=0.10, seed=0, engine="device")
    partition(hg, 4, eps=0.10, seed=9, engine="device")
    assert refine_device.trace_count() == before
    # a different p is a different kernel: exactly one fresh trace per level
    partition(hg, 5, eps=0.10, seed=0, engine="device")
    after_p5 = refine_device.trace_count()
    assert after_p5 > before
    partition(hg, 5, eps=0.10, seed=1, engine="device")
    assert refine_device.trace_count() == after_p5


def test_refine_batch_is_balance_feasible_and_scored(device_everywhere):
    """Direct kernel contract: scores are finite, the argmin seed is the
    best, and feasible seeds respect the cap the kernel was given."""
    hg = build_model(_instance(5, shape=(90, 70, 80)), "fine")
    p = 4
    w = hg.w_comp.astype(np.float64)
    cap = max(1.25 * w.sum() / p, float(w.max()))
    batch0 = refine_device.initial_partitions(hg, p, seed=0)
    batch, scores = refine_device.refine_batch(hg, batch0, p, cap, rounds=8)
    assert batch.shape == batch0.shape
    assert ((batch >= 0) & (batch < p)).all()
    assert np.isfinite(scores).all()
    feasible = scores < 1e11  # below the infeasibility penalty
    assert feasible.any()
    for s in np.flatnonzero(feasible):
        pw = np.bincount(batch[s], weights=w, minlength=p)
        assert (pw <= cap + 1e-6).all()
