"""Hypothesis property tests for the Pallas kernels (split from
``test_kernels.py`` so its deterministic oracle tests still run in
environments without hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; test_kernels.py covers the oracles"
)
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.sparse.bsr import to_bsr, bsr_to_dense, BlockSparse


def _random_block_dense(rng, m, k, density, block):
    """Dense matrix whose nonzero support is block-structured."""
    gm, gk = m // block, k // block
    mask = rng.random((gm, gk)) < density
    if not mask.any():
        mask[0, 0] = True
    dense = rng.standard_normal((m, k)).astype(np.float32)
    full = np.kron(mask, np.ones((block, block), bool))
    return dense * full


@settings(max_examples=12, deadline=None)
@given(
    gm=st.integers(2, 5),
    gk=st.integers(2, 5),
    n=st.sampled_from([8, 16]),
    density=st.floats(0.2, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_bsr_spmm_property(gm, gk, n, density, seed):
    """Property: kernel == dense matmul for arbitrary block supports."""
    block = 8
    rng = np.random.default_rng(seed)
    a = _random_block_dense(rng, gm * block, gk * block, density, block)
    b = rng.standard_normal((gk * block, n)).astype(np.float32)
    bsr = to_bsr(a, block, block)
    got = np.asarray(ops.spmm(bsr, b, interpret=True))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    gm=st.integers(2, 4),
    gk=st.integers(2, 4),
    gn=st.integers(2, 4),
    da=st.floats(0.25, 0.8),
    db=st.floats(0.25, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
def test_bsr_spgemm_property(gm, gk, gn, da, db, seed):
    block = 8
    rng = np.random.default_rng(seed)
    a = _random_block_dense(rng, gm * block, gk * block, da, block)
    b = _random_block_dense(rng, gk * block, gn * block, db, block)
    ab, bb = to_bsr(a, block, block), to_bsr(b, block, block)
    c_blocks, crows, ccols = ops.spgemm(ab, bb, interpret=True)
    c = bsr_to_dense(
        BlockSparse(np.asarray(c_blocks), crows, ccols, (gm * block, gn * block))
    )
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
