"""Serving-tier tests: batched value streams + the SpGEMM serving loop.

Single-process parts run at p=1 (a 1-device mesh runs the full executor
program without forced host devices): batched-vs-looped oracle equality for
every executable model, capacity bucketing + zero-retrace inside a bucket,
donation safety on the batched step, and the serving loop's lifecycle
(enqueue -> batch -> evict -> drain) including admission rejection and
scripted faults.  The same coverage at p in {4, 8} runs through the
subprocess runner (forced host devices must not leak into this pytest
process' jax).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def _run(case: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = str(devices)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, case],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("devices", [4, 8])
def test_serve_multidev(devices):
    """Batched executors for all four models at p in {4, 8}: oracle equality
    vs the per-call path, zero retraces inside a capacity bucket, donation
    safety, and a batched serving-loop window."""
    assert f"OK serve p={devices}" in _run("serve", devices=devices)


# ---------------------------------------------------------------------------
# batch bucketing (jax-free)
# ---------------------------------------------------------------------------
def test_batch_bucket_geometric():
    from repro.distributed.runtime import batch_bucket

    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [
        1, 2, 4, 4, 8, 8, 16,
    ]
    with pytest.raises(ValueError, match="batch size"):
        batch_bucket(0)


def test_compile_batch_rounds_to_bucket():
    import repro
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(0)
    a_s = random_structure(14, 14, 0.3, rng)
    planned = repro.plan(a_s, a_s, p=1, model="rowwise")
    exe = planned.compile(batch=5)
    assert exe.batch_capacity == 8
    # same bucket -> the identical cached AOT executable (the api-level
    # handle is a fresh thin wrapper per compile()); p=1 keeps this cheap
    assert planned.compile(batch=7).runtime is exe.runtime
    assert planned.compile(batch=8).runtime is exe.runtime
    assert planned.compile(batch=2).runtime is not exe.runtime


# ---------------------------------------------------------------------------
# batched oracle at p=1 (all executable models)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def operands():
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(3)
    a_s = random_structure(16, 13, 0.25, rng)
    b_s = random_structure(13, 15, 0.25, rng)
    m = 4
    av = rng.standard_normal((m, a_s.nnz)).astype(np.float32)
    bv = rng.standard_normal((m, b_s.nnz)).astype(np.float32)
    return a_s, b_s, av, bv


def _dense(s, vals):
    d = np.zeros(s.shape, np.float32)
    d[s.coo()] = vals
    return d


def test_batched_matches_looped_all_models_p1(operands):
    """compile(batch=m) over a value stack == m single compiles == oracle,
    for every executable model."""
    import repro

    a_s, b_s, av, bv = operands
    for model in repro.executable_models():
        planned = repro.plan(a_s, b_s, p=1, model=model)
        exe_one = planned.compile()
        got = planned.compile(batch=len(av))(av, bv)
        assert got.shape == (len(av), 16, 15), (model, got.shape)
        for i in range(len(av)):
            want = _dense(a_s, av[i]) @ _dense(b_s, bv[i])
            np.testing.assert_allclose(
                got[i], want, rtol=1e-4, atol=1e-4, err_msg=model
            )
            np.testing.assert_allclose(
                exe_one(av[i], bv[i]), want, rtol=1e-4, atol=1e-4, err_msg=model
            )


def test_ragged_batches_share_bucket_without_retrace(operands):
    import repro
    from repro.distributed import runtime

    a_s, b_s, av, bv = operands
    exe = repro.plan(a_s, b_s, p=1, model="fine").compile(batch=4)
    exe(av[:2], bv[:2])  # bucket warm
    n0 = runtime.trace_count()
    for m in (1, 2, 3, 4):
        got = exe(av[:m], bv[:m])
        assert got.shape[0] == m
    assert runtime.trace_count() == n0, "ragged batches inside one bucket retraced"


def test_batched_step_is_donation_safe(operands):
    """PR 4 regression, batched flavor: repeated calls reusing the same numpy
    value buffers must not alias donated device buffers."""
    import repro

    a_s, b_s, av, bv = operands
    exe = repro.plan(a_s, b_s, p=1, model="fine").compile(batch=len(av))
    av_copy, bv_copy = av.copy(), bv.copy()
    r1 = np.asarray(exe(av, bv))
    r2 = np.asarray(exe(av, bv))
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(av, av_copy)
    np.testing.assert_array_equal(bv, bv_copy)


def test_batch_overflow_and_operand_mismatch_raise(operands):
    import repro

    a_s, b_s, av, bv = operands
    exe = repro.plan(a_s, b_s, p=1, model="rowwise").compile(batch=2)
    with pytest.raises(ValueError, match="batch"):
        exe(av, bv)  # 4 rows into a capacity-2 executable
    with pytest.raises(ValueError, match="batch"):
        exe(av[:2], bv[:1])  # mismatched A/B batch sizes


# ---------------------------------------------------------------------------
# serving-loop lifecycle at p=1
# ---------------------------------------------------------------------------
def _submit_stream(server, s, rng, count):
    return [
        server.submit(
            (s, rng.standard_normal(s.nnz).astype(np.float32)),
            (s, rng.standard_normal(s.nnz).astype(np.float32)),
        )
        for _ in range(count)
    ]


def test_serving_loop_lifecycle():
    """enqueue -> batch -> evict -> drain: same-structure requests ride
    batched dispatches, the pool LRU evicts (visible on session events), and
    every completed result matches the dense oracle."""
    from repro.launch.serve import SpGEMMServer
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(5)
    structs = [random_structure(14, 14, 0.25, rng) for _ in range(3)]
    server = SpGEMMServer(
        p=1, model="rowwise", max_batch=4, batch_window=8, pool_entries=2
    )
    # enqueue: 6 same-structure requests sit in the queue until stepped
    reqs = _submit_stream(server, structs[0], rng, 6)
    assert server.queue_depth == 6 and server.stats.completed == 0
    # batch: one window serves all 6 in ceil(6/4) = 2 dispatches
    server.step()
    assert server.stats.completed == 6 and server.stats.dispatches == 2
    assert server.stats.batch_items == 6
    for r in reqs:
        assert r.done and r.latency_s >= 0
        want = _dense(structs[0], r.a_vals) @ _dense(structs[0], r.b_vals)
        np.testing.assert_allclose(r.result, want, rtol=1e-4, atol=1e-4)
    # evict: a 2-entry pool sees a third structure -> LRU eviction event
    _submit_stream(server, structs[1], rng, 1)
    _submit_stream(server, structs[2], rng, 1)
    server.drain()

    def replans(kinds):
        # same-shape structures warm-start off resident entries, so a new
        # structure may classify warm_replan rather than cold — both are
        # full replans as far as the pool lifecycle is concerned
        return kinds.count("cold_replan") + kinds.count("warm_replan")

    kinds = [e.kind for e in server.session.events]
    assert replans(kinds) == 3, kinds
    assert "evict" in kinds, kinds
    # drain: the evicted structure must replan, a resident one pool-hits
    _submit_stream(server, structs[2], rng, 1)  # resident -> hit
    _submit_stream(server, structs[0], rng, 1)  # evicted -> replan again
    served = server.drain()
    assert served == 2 and server.queue_depth == 0
    kinds = [e.kind for e in server.session.events]
    assert kinds.count("hit") >= 1
    assert replans(kinds) == 4, kinds
    report = server.report()
    assert report["completed"] == 10 and report["failed"] == 0
    assert report["qps"] > 0 and report["p99_us"] >= report["p50_us"] > 0
    assert 0 < report["batch_efficiency"] <= 1


def test_admission_rejects_when_full():
    from repro.launch.serve import QueueFull, SpGEMMServer
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(6)
    s = random_structure(12, 12, 0.3, rng)
    server = SpGEMMServer(p=1, model="rowwise", queue_limit=2)
    _submit_stream(server, s, rng, 2)
    with pytest.raises(QueueFull, match="capacity"):
        _submit_stream(server, s, rng, 1)
    assert server.stats.rejected == 1
    server.drain()  # the admitted two still complete
    assert server.stats.completed == 2


def test_serve_spgemm_driver_steps_inline_on_full_queue():
    """The offline driver submits past queue_limit by stepping inline — no
    request is ever dropped."""
    from repro.launch.serve import serve_spgemm
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(7)
    s = random_structure(12, 12, 0.3, rng)
    workload = [
        (
            (s, rng.standard_normal(s.nnz).astype(np.float32)),
            (s, rng.standard_normal(s.nnz).astype(np.float32)),
        )
        for _ in range(9)
    ]
    requests, report = serve_spgemm(
        workload, p=1, model="rowwise", queue_limit=4, max_batch=4, batch_window=4
    )
    assert report["completed"] == 9 and report["failed"] == 0
    assert all(r.result is not None for r in requests)


def test_serving_loop_retries_scripted_transient_fault():
    from repro.launch.serve import SpGEMMServer
    from repro.resilience import FaultPolicy
    from repro.sparse.structure import random_structure
    from repro.testing import faults

    rng = np.random.default_rng(8)
    s = random_structure(12, 12, 0.3, rng)
    server = SpGEMMServer(
        p=1, model="rowwise", max_batch=4, policy=FaultPolicy(backoff_s=0.0)
    )
    _submit_stream(server, s, rng, 4)
    with faults.inject("execute", times=1) as script:
        server.drain()
    assert script.fired == 1
    assert server.stats.completed == 4 and server.stats.failed == 0
    assert any(e.kind == "retry" for e in server.session.events)


def test_serving_loop_isolates_permanent_failure():
    """A batch that exhausts the retry budget marks only its own requests
    failed; the loop keeps serving the next window."""
    from repro.launch.serve import SpGEMMServer
    from repro.resilience import FaultPolicy
    from repro.sparse.structure import random_structure
    from repro.testing import faults
    from repro.testing.faults import InjectedFault

    rng = np.random.default_rng(9)
    s = random_structure(12, 12, 0.3, rng)
    policy = FaultPolicy(max_retries=1, backoff_s=0.0)
    server = SpGEMMServer(p=1, model="rowwise", max_batch=4, policy=policy)
    doomed = _submit_stream(server, s, rng, 2)
    # fail the first attempt AND its retry: the chunk fails permanently
    with faults.inject("execute", times=2) as script:
        server.drain()
    assert script.fired == 2
    assert server.stats.failed == 2 and server.stats.completed == 0
    assert all(isinstance(r.error, InjectedFault) and r.done for r in doomed)
    # the loop is still alive: the next window completes normally
    healthy = _submit_stream(server, s, rng, 2)
    server.drain()
    assert server.stats.completed == 2
    assert all(r.result is not None for r in healthy)
