"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; deterministic tests cover the rest"
)
from hypothesis import given, settings, strategies as st

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.core.coarsen import coarsen_vertices
from repro.core.spgemm_models import MODELS
from repro.sparse.structure import random_structure, spgemm_symbolic


def _inst(seed, i, k, j, density):
    rng = np.random.default_rng(seed)
    a = random_structure(i, k, density, rng)
    b = random_structure(k, j, density, rng)
    return SpGEMMInstance(a, b)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    i=st.integers(4, 24),
    k=st.integers(4, 20),
    j=st.integers(4, 24),
    density=st.floats(0.1, 0.5),
    model=st.sampled_from(MODELS),
    p=st.sampled_from([2, 3, 5]),
)
def test_comm_evaluation_invariants(seed, i, k, j, density, model, p):
    inst = _inst(seed, i, k, j, density)
    hg = build_model(inst, model)
    rng = np.random.default_rng(seed)
    parts = rng.integers(0, p, size=hg.n_vertices)
    c = evaluate(hg, parts, p)
    # 0 <= connectivity <= total volume <= p * connectivity
    assert 0 <= c.connectivity <= c.total_volume <= p * max(c.connectivity, 1)
    # expand + fold == connectivity
    assert c.expand + c.fold == c.connectivity
    # max part cost <= sum of all cut-net costs * 1 (each net counts once/part)
    assert c.max_part_cost <= c.total_volume
    # single part: zero communication
    z = evaluate(hg, np.zeros(hg.n_vertices, dtype=np.int64), 1)
    assert z.connectivity == 0


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p=st.sampled_from([2, 4]),
)
def test_partitioner_output_valid_and_no_worse_than_trivial(seed, p):
    inst = _inst(seed, 20, 14, 18, 0.25)
    hg = build_model(inst, "rowwise")
    res = partition(hg, p, eps=0.5, seed=seed)
    assert res.parts.shape == (hg.n_vertices,)
    assert res.parts.min() >= 0 and res.parts.max() < p
    # objective never exceeds the all-nets-cut ceiling
    assert res.connectivity <= int(hg.net_cost.sum() * (p - 1))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), groups=st.integers(2, 8))
def test_coarsening_never_increases_cut(seed, groups):
    """For any partition REFINED by the coarse map, cut costs are identical;
    coarsening can only restrict the solution space (Sec. 5)."""
    inst = _inst(seed, 16, 12, 14, 0.3)
    hg = build_model(inst, "fine")
    rng = np.random.default_rng(seed)
    cmap = rng.integers(0, groups, size=hg.n_vertices)
    _, cmap = np.unique(cmap, return_inverse=True)
    coarse = coarsen_vertices(hg, cmap)
    # assign each coarse group a part; induce the fine partition
    p = 3
    coarse_parts = rng.integers(0, p, size=coarse.n_vertices)
    fine_parts = coarse_parts[cmap]
    c_fine = evaluate(hg, fine_parts, p)
    c_coarse = evaluate(coarse, coarse_parts, p)
    assert c_fine.connectivity == c_coarse.connectivity
    assert c_fine.max_part_cost == c_coarse.max_part_cost


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    i=st.integers(3, 20),
    k=st.integers(3, 16),
    j=st.integers(3, 20),
    density=st.floats(0.1, 0.6),
)
def test_symbolic_spgemm_matches_dense(seed, i, k, j, density):
    rng = np.random.default_rng(seed)
    a = random_structure(i, k, density, rng)
    b = random_structure(k, j, density, rng)
    c = spgemm_symbolic(a, b)
    ad = np.zeros((i, k), bool)
    ad[a.coo()] = True
    bd = np.zeros((k, j), bool)
    bd[b.coo()] = True
    want = (ad @ bd)
    got = np.zeros((i, j), bool)
    got[c.coo()] = True
    assert np.array_equal(got, want)
    # |V^m| identity: sum_k nnz(A col k) * nnz(B row k)
    inst = SpGEMMInstance(a, b)
    assert inst.n_mult == int((a.col_counts() * b.row_counts()).sum())
