"""Sharding-rule unit tests: divisibility-aware spec fitting, serve overlay,
batch sharding, parameter tree consistency for every architecture."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.models.sharding import (
    _fit_spec,
    batch_sharding,
    param_logical_axes,
    param_shardings,
    serve_overlay,
    spec_for,
)
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()  # 1 device: ('data', 'model') sizes (1, 1)


def test_fit_spec_drops_nondivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # 4 KV heads cannot shard over 16-way model
    spec = _fit_spec(P(None, "model", None), (64, 4, 128), FakeMesh())
    assert spec == P(None, None, None)
    # 64 heads can
    spec = _fit_spec(P(None, "model", None), (64, 64, 128), FakeMesh())
    assert spec == P(None, "model", None)
    # vocab 32001 not divisible -> replicate
    spec = _fit_spec(P("model"), (32001,), FakeMesh())
    assert spec == P(None)
    # tuple axes: keep only the prefix that divides
    spec = _fit_spec(P(("pod", "data")), (2,), _mk(pod=2, data=16))
    assert spec == P("pod")


def _mk(**sizes):
    class FakeMesh:
        axis_names = tuple(sizes)
        shape = dict(sizes)

    return FakeMesh()


def test_batch_sharding_divisibility():
    mesh = _mk(pod=2, data=16, model=16)

    class M:
        axis_names = mesh.axis_names
        shape = mesh.shape

    # full divisibility: both axes
    import repro.models.sharding as sh

    # use the real function with a real mesh of 1 device but fake sizes is
    # not possible; test the pure logic through _fit_spec instead
    spec = _fit_spec(P(("pod", "data")), (256,), M())
    assert spec == P(("pod", "data"))
    spec = _fit_spec(P(("pod", "data")), (1,), M())
    assert spec == P(None)


@pytest.mark.parametrize("arch", all_arch_ids())
def test_param_shardings_tree_matches_params(arch, mesh):
    cfg = get_config(arch)
    sh = param_shardings(cfg, mesh)
    from functools import partial
    from repro.models import init_params

    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    # same treedef
    assert jax.tree.structure(sh) == jax.tree.structure(shapes)


def test_serve_overlay_drops_fsdp_axis():
    cfg = get_config("internlm2-1.8b")
    axes = param_logical_axes(cfg)
    served = serve_overlay(axes)
    assert axes["embed"]["tokens"] == ("vocab", "embed_fsdp")
    assert served["embed"]["tokens"] == ("vocab", None)
    assert served["layers"]["attn"]["wq"][1] is None  # embed_fsdp dropped
    assert served["layers"]["attn"]["wq"][2] == "heads"  # TP kept
