"""Plan-store contract: byte-faithful round-trips for every executable
model's lowered plan, atomic commits, checksum/version quarantine.

Planning and the store are jax-free; ``plan_fingerprint`` (the identity the
executor LRU keys on) is the equality we assert — a restored plan with the
same fingerprint compiles to a cache hit, which is the whole point of
persisting it.
"""
import os
import json
import shutil

import numpy as np
import pytest

from repro.api import _plan_one
from repro.checkpoint import (
    PLAN_STORE_VERSION,
    PlanStoreError,
    list_plans,
    restore_plan,
    save_plan,
)
from repro.core import SpGEMMInstance
from repro.sparse.structure import random_structure

EXEC_MODELS = ("fine", "rowwise", "outer", "monoC")


def _planned(model, seed=0):
    rng = np.random.default_rng(seed)
    a = random_structure(30, 26, 0.15, rng)
    b = random_structure(26, 28, 0.15, rng)
    return _plan_one(SpGEMMInstance(a, b), model, 2, 0.10, 0, include_nz=False)


def _fp(plan):
    from repro.distributed.runtime import plan_fingerprint

    return plan_fingerprint(plan)


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", EXEC_MODELS)
def test_roundtrip_preserves_plan_fingerprint(model, tmp_path):
    planned = _planned(model)
    plan = planned.execution_plan
    assert plan is not None
    store = str(tmp_path / "store")
    save_plan(store, f"k_{model}", plan)
    back = restore_plan(store, f"k_{model}")
    assert back is not None
    assert type(back.plan).__name__ == type(plan).__name__
    assert back.plan.model == plan.model and back.plan.p == plan.p
    assert back.plan.stats == {k: int(v) for k, v in plan.stats.items()}
    assert _fp(back.plan) == _fp(plan)


def test_roundtrip_preserves_extra_arrays_and_meta(tmp_path):
    plan = _planned("rowwise").execution_plan
    store = str(tmp_path / "store")
    labels = np.arange(30) % 2
    save_plan(store, "k", plan, arrays={"labels": labels}, meta={"p": 2, "m": "x"})
    back = restore_plan(store, "k")
    np.testing.assert_array_equal(back.arrays["labels"], labels)
    assert back.meta == {"p": 2, "m": "x"}


def test_missing_entry_returns_none(tmp_path):
    assert restore_plan(str(tmp_path), "nothere") is None
    assert list_plans(str(tmp_path / "void")) == []


def test_bad_key_rejected(tmp_path):
    plan = _planned("rowwise").execution_plan
    with pytest.raises(ValueError, match="plan key"):
        save_plan(str(tmp_path), "../escape", plan)
    with pytest.raises(ValueError, match="plan key"):
        restore_plan(str(tmp_path), "a/b")


# ---------------------------------------------------------------------------
# atomicity + crash recovery
# ---------------------------------------------------------------------------
def test_tmp_and_quarantined_dirs_are_invisible(tmp_path):
    store = str(tmp_path / "store")
    save_plan(store, "good", _planned("rowwise").execution_plan)
    os.makedirs(os.path.join(store, "half.tmp"))  # crash mid-write
    os.makedirs(os.path.join(store, "bad.quarantined-0"))
    assert list_plans(store) == ["good"]
    assert restore_plan(store, "half") is None


def test_interrupted_overwrite_recovers_previous_entry(tmp_path):
    store = str(tmp_path / "store")
    plan = _planned("rowwise").execution_plan
    save_plan(store, "k", plan, meta={"gen": 1})
    # crash window: old renamed aside, new never landed
    os.rename(os.path.join(store, "k"), os.path.join(store, "k.prev"))
    assert list_plans(store) == ["k"]  # reader promotes the .prev back
    assert restore_plan(store, "k").meta == {"gen": 1}
    # overwrite commits atomically and drops any stale .prev
    save_plan(store, "k", plan, meta={"gen": 2})
    shutil.copytree(os.path.join(store, "k"), os.path.join(store, "k.prev"))
    assert restore_plan(store, "k").meta == {"gen": 2}
    assert not os.path.exists(os.path.join(store, "k.prev"))


# ---------------------------------------------------------------------------
# integrity: quarantine, not crash
# ---------------------------------------------------------------------------
def _corrupt_arrays(store, key):
    blob = os.path.join(store, key, "arrays.npz")
    raw = bytearray(open(blob, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(blob, "wb").write(bytes(raw))


def test_checksum_mismatch_quarantines(tmp_path):
    store = str(tmp_path / "store")
    save_plan(store, "k", _planned("rowwise").execution_plan)
    _corrupt_arrays(store, "k")
    with pytest.warns(RuntimeWarning, match="quarantined 'k'.*checksum"):
        assert restore_plan(store, "k") is None
    assert list_plans(store) == []
    assert os.path.isdir(os.path.join(store, "k.quarantined-0"))


def test_version_mismatch_quarantines(tmp_path):
    store = str(tmp_path / "store")
    save_plan(store, "k", _planned("rowwise").execution_plan)
    man = os.path.join(store, "k", "manifest.json")
    with open(man) as f:
        manifest = json.load(f)
    manifest["version"] = PLAN_STORE_VERSION + 1
    with open(man, "w") as f:
        json.dump(manifest, f)
    with pytest.warns(RuntimeWarning, match="version"):
        assert restore_plan(store, "k") is None
    assert list_plans(store) == []


def test_quarantine_false_raises_instead(tmp_path):
    store = str(tmp_path / "store")
    save_plan(store, "k", _planned("rowwise").execution_plan)
    _corrupt_arrays(store, "k")
    with pytest.raises(PlanStoreError, match="checksum"):
        restore_plan(store, "k", quarantine=False)
    assert list_plans(store) == ["k"]  # untouched: the caller decides


def test_repeated_corruption_gets_numbered_quarantines(tmp_path):
    store = str(tmp_path / "store")
    plan = _planned("rowwise").execution_plan
    for n in range(2):
        save_plan(store, "k", plan)
        _corrupt_arrays(store, "k")
        with pytest.warns(RuntimeWarning):
            restore_plan(store, "k")
        assert os.path.isdir(os.path.join(store, f"k.quarantined-{n}"))
