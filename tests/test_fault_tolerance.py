"""Fault tolerance: checkpoint atomicity, deterministic resume (restart
reproduces the uninterrupted run bit-for-bit), straggler detection, data
pipeline resumability."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.store import all_steps
from repro.data.pipeline import SyntheticTokens
from repro.launch.elastic import InjectedFailure, run_loop
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.training.optimizer import adamw_init
from repro.training.step import make_train_step


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "a": np.arange(12).reshape(3, 4).astype(np.float32),
        "nested": {"b": np.ones(5, np.int32), "c": [np.zeros(2), np.full(3, 7.0)]},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, state)
    restored, step = restore_checkpoint(d)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], state["a"])
    np.testing.assert_array_equal(restored["nested"]["c"][1], state["nested"]["c"][1])


def test_checkpoint_gc_keeps_last(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, {"x": np.array([s])}, keep_last=2)
    assert all_steps(d) == [4, 5]


def test_checkpoint_no_partial_commit(tmp_path):
    """A .tmp dir must never be visible as a checkpoint."""
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": np.array([1])})
    os.makedirs(os.path.join(d, ".tmp-2"))  # simulated crash mid-save
    assert latest_step(d) == 1


def test_interrupted_commit_leaves_restorable_checkpoint(tmp_path):
    """Every crash window of the overwrite commit leaves a restorable latest
    checkpoint.  The old protocol (rmtree(final) then rename) had a window
    where the only copy of a step was gone; the rename-aside protocol never
    does, and readers recover an orphaned .prev automatically."""
    import shutil

    d = str(tmp_path / "ckpt")
    step_dir = os.path.join(d, f"step_{1:012d}")
    save_checkpoint(d, 1, {"x": np.array([1])})

    # crash window A: old renamed aside, new not yet in place
    os.rename(step_dir, step_dir + ".prev")
    assert latest_step(d) == 1  # reader recovers the .prev
    restored, _ = restore_checkpoint(d, 1)
    np.testing.assert_array_equal(restored["x"], [1])

    # crash window B: new committed, stale .prev left behind
    save_checkpoint(d, 1, {"x": np.array([2])})
    shutil.copytree(step_dir, step_dir + ".prev")
    assert all_steps(d) == [1]  # stale .prev dropped, not double-counted
    restored, _ = restore_checkpoint(d, 1)
    np.testing.assert_array_equal(restored["x"], [2])  # new copy wins
    assert not os.path.exists(step_dir + ".prev")


def test_checkpoint_overwrite_same_step(tmp_path):
    """Re-saving a step replaces it atomically (the elastic loop re-saves the
    restored step after a crash)."""
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, {"x": np.array([1])})
    save_checkpoint(d, 3, {"x": np.array([9])})
    restored, step = restore_checkpoint(d)
    assert step == 3
    np.testing.assert_array_equal(restored["x"], [9])


def test_checkpoint_tuple_roundtrip(tmp_path):
    """Tuples survive restore as tuples (they used to come back as lists,
    breaking pytree-structure equality in tree_to_state)."""
    state = {
        "pair": (np.array([1.0]), np.array([2.0])),
        "mixed": [np.array([3]), (np.array([4]), np.array([5]))],
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, state)
    restored, _ = restore_checkpoint(d)
    assert isinstance(restored["pair"], tuple)
    assert isinstance(restored["mixed"], list)
    assert isinstance(restored["mixed"][1], tuple)
    np.testing.assert_array_equal(restored["pair"][1], [2.0])
    np.testing.assert_array_equal(restored["mixed"][1][0], [4])
    assert jax.tree.structure(restored) == jax.tree.structure(state)


def test_retryable_predicate_classification():
    """run_loop and FaultPolicy share one explicit predicate — not the old
    'substring RESOURCE_EXHAUSTED in any RuntimeError' check."""
    from repro.resilience import RetryableError, is_retryable

    assert is_retryable(InjectedFailure("node lost"))
    assert is_retryable(RetryableError("x"))
    assert is_retryable(RuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert is_retryable(MemoryError())
    assert is_retryable(TimeoutError())
    assert is_retryable(OSError("disk blip"))
    assert not is_retryable(FileNotFoundError("gone"))
    assert not is_retryable(PermissionError("no"))
    assert not is_retryable(ValueError("shape mismatch"))
    assert not is_retryable(RuntimeError("plain bug"))


def test_run_loop_does_not_restart_on_permanent_failure(tmp_path):
    def step_fn(state, idx):
        if idx == 2:
            raise ValueError("permanent bug")
        return state

    with pytest.raises(ValueError):
        run_loop(0, step_fn, 5, ckpt_dir=str(tmp_path / "c"), ckpt_every=1)


def test_run_loop_backoff_between_restarts(tmp_path):
    """Consecutive restarts back off exponentially; a completed step resets."""
    sleeps = []
    fails = {"n": 0}

    def step_fn(state, idx):
        if idx == 1 and fails["n"] < 3:
            fails["n"] += 1
            raise InjectedFailure("flaky step")
        return state

    _, stats = run_loop(
        0,
        step_fn,
        3,
        ckpt_dir=str(tmp_path / "c"),
        ckpt_every=1,
        max_restarts=5,
        restart_backoff_s=0.1,
        sleep=sleeps.append,
    )
    assert stats.restarts == 3
    assert sleeps == pytest.approx([0.1, 0.2, 0.4])


def _make_trainer():
    cfg = get_smoke_config("internlm2-1.8b")
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)

    def step_fn(state, idx):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(idx).items()}
        p, o, _ = step(p, o, batch)
        return p, o

    return (params, opt), step_fn


def test_resume_is_deterministic(tmp_path):
    """Run 8 steps straight; run 8 steps with a crash at step 5 + restart;
    final params must match exactly (pure-function data pipeline + ckpt)."""
    state0, step_fn = _make_trainer()
    ref, _ = run_loop(state0, step_fn, 8, ckpt_dir=None)

    d = str(tmp_path / "ckpt")
    state0b, step_fn_b = _make_trainer()
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node loss")

    got, stats = run_loop(
        state0b,
        step_fn_b,
        8,
        ckpt_dir=d,
        ckpt_every=2,
        failure_injector=injector,
        state_to_tree=lambda s: {"p": s[0], "o": s[1]},
        tree_to_state=lambda t, s: (
            jax.tree.map(jnp.asarray, t["p"]),
            jax.tree.map(jnp.asarray, t["o"]),
        ),
    )
    assert stats.restarts == 1
    for a, b in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    calls = {"n": 0}

    def step_fn(state, idx):
        calls["n"] += 1
        if idx == 7:
            time.sleep(0.35)
        else:
            time.sleep(0.01)
        return state

    _, stats = run_loop(0, step_fn, 10, straggler_factor=3.0)
    assert [s[0] for s in stats.stragglers] == [7]


def test_data_pipeline_deterministic_and_host_sharded():
    ds = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(4)["tokens"], b1["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1, n_hosts=2, host_id=0)
    h1 = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1, n_hosts=2, host_id=1)
    assert h0.batch(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_restore_with_shardings_resharding(tmp_path):
    """Elastic re-scale path: restore onto a different (here trivial) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    d = str(tmp_path / "ckpt")
    state = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    save_checkpoint(d, 1, state)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P())}
    restored, _ = restore_checkpoint(d, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
