"""Fault tolerance: checkpoint atomicity, deterministic resume (restart
reproduces the uninterrupted run bit-for-bit), straggler detection, data
pipeline resumability."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.store import all_steps
from repro.data.pipeline import SyntheticTokens
from repro.launch.elastic import InjectedFailure, run_loop
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.training.optimizer import adamw_init
from repro.training.step import make_train_step


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "a": np.arange(12).reshape(3, 4).astype(np.float32),
        "nested": {"b": np.ones(5, np.int32), "c": [np.zeros(2), np.full(3, 7.0)]},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, state)
    restored, step = restore_checkpoint(d)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], state["a"])
    np.testing.assert_array_equal(restored["nested"]["c"][1], state["nested"]["c"][1])


def test_checkpoint_gc_keeps_last(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, {"x": np.array([s])}, keep_last=2)
    assert all_steps(d) == [4, 5]


def test_checkpoint_no_partial_commit(tmp_path):
    """A .tmp dir must never be visible as a checkpoint."""
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": np.array([1])})
    os.makedirs(os.path.join(d, ".tmp-2"))  # simulated crash mid-save
    assert latest_step(d) == 1


def _make_trainer():
    cfg = get_smoke_config("internlm2-1.8b")
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)

    def step_fn(state, idx):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(idx).items()}
        p, o, _ = step(p, o, batch)
        return p, o

    return (params, opt), step_fn


def test_resume_is_deterministic(tmp_path):
    """Run 8 steps straight; run 8 steps with a crash at step 5 + restart;
    final params must match exactly (pure-function data pipeline + ckpt)."""
    state0, step_fn = _make_trainer()
    ref, _ = run_loop(state0, step_fn, 8, ckpt_dir=None)

    d = str(tmp_path / "ckpt")
    state0b, step_fn_b = _make_trainer()
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node loss")

    got, stats = run_loop(
        state0b,
        step_fn_b,
        8,
        ckpt_dir=d,
        ckpt_every=2,
        failure_injector=injector,
        state_to_tree=lambda s: {"p": s[0], "o": s[1]},
        tree_to_state=lambda t, s: (
            jax.tree.map(jnp.asarray, t["p"]),
            jax.tree.map(jnp.asarray, t["o"]),
        ),
    )
    assert stats.restarts == 1
    for a, b in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    calls = {"n": 0}

    def step_fn(state, idx):
        calls["n"] += 1
        if idx == 7:
            time.sleep(0.35)
        else:
            time.sleep(0.01)
        return state

    _, stats = run_loop(0, step_fn, 10, straggler_factor=3.0)
    assert [s[0] for s in stats.stragglers] == [7]


def test_data_pipeline_deterministic_and_host_sharded():
    ds = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(4)["tokens"], b1["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1, n_hosts=2, host_id=0)
    h1 = SyntheticTokens(vocab=100, seq_len=16, global_batch=8, seed=1, n_hosts=2, host_id=1)
    assert h0.batch(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_restore_with_shardings_resharding(tmp_path):
    """Elastic re-scale path: restore onto a different (here trivial) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    d = str(tmp_path / "ckpt")
    state = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    save_checkpoint(d, 1, state)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P())}
    restored, _ = restore_checkpoint(d, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
