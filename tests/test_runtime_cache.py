"""Compile-once runtime tests.

Single-process parts run at p=1 (a 1-device mesh exercises the full scatter
-> expand -> compute -> reduce program without forced host devices): cache
identity, LRU bounds, fingerprints, sparse-input entry points, and the
value-shape guard.  The multi-device oracle + retrace-counter + donation
coverage at p in {4, 8} runs through the subprocess runner (forced host
devices must not leak into this pytest process' jax).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def _run(case: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = str(devices)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, case],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("devices", [4, 8])
def test_runtime_all_executors_value_only_oracle(devices):
    """All four executors through CompiledSpGEMM at p in {4, 8}: value-only
    updates == dense oracle, zero retraces across >= 10 calls, donation-safe
    numpy reuse, cache-hit identity, mismatched-structure raise."""
    assert f"OK runtime p={devices}" in _run("runtime", devices=devices)


# ---------------------------------------------------------------------------
# single-process coverage at p=1
# ---------------------------------------------------------------------------
@pytest.fixture
def tiny():
    import jax
    from jax.sharding import Mesh

    from repro.core import SpGEMMInstance
    from repro.distributed import build_fine_plan
    from repro.sparse.structure import random_structure

    rng = np.random.default_rng(0)
    a_s = random_structure(12, 10, 0.3, rng)
    b_s = random_structure(10, 11, 0.3, rng)
    inst = SpGEMMInstance(a_s, b_s, name="tiny")
    plan = build_fine_plan(inst, np.zeros(inst.n_mult, dtype=np.int64), 1)
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    a = np.zeros(a_s.shape, np.float32)
    b = np.zeros(b_s.shape, np.float32)
    a[a_s.coo()] = rng.standard_normal(a_s.nnz).astype(np.float32)
    b[b_s.coo()] = rng.standard_normal(b_s.nnz).astype(np.float32)
    return inst, plan, mesh, a, b


def test_all_models_match_oracle_at_p1(tiny):
    """Every runtime lowering produces A @ B on a 1-device mesh (the
    size-1 collectives degenerate to copies)."""
    import jax
    from jax.sharding import Mesh

    from repro.core import SpGEMMInstance
    from repro.distributed.runtime import compile_spgemm
    from repro.distributed.select import build_executable_plan

    inst, _, _, a, b = tiny
    p = 1
    ar, ac = inst.a.coo()
    br, bc = inst.b.coo()
    for model in ("rowwise", "outer", "monoC", "fine"):
        parts = {
            "rowwise": np.zeros(inst.shape[0], np.int64),
            "outer": np.zeros(inst.shape[1], np.int64),
            "monoC": np.zeros(inst.c.nnz, np.int64),
            "fine": np.zeros(inst.n_mult, np.int64),
        }[model]
        plan = build_executable_plan(inst, model, parts, p)
        if model == "monoC":
            mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
            exe = compile_spgemm(
                plan, inst.a, inst.b, mesh, block=1, backend="xla",
                c_structure=inst.c,
            )
            got = exe.unpack(exe(a[ar, ac].reshape(-1, 1, 1), b[br, bc].reshape(-1, 1, 1)))
        else:
            mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
            exe = compile_spgemm(plan, inst.a, inst.b, mesh, c_structure=inst.c)
            got = exe.unpack(exe(a[ar, ac], b[br, bc]))
        np.testing.assert_allclose(
            got[:12, :11], a @ b, rtol=1e-5, atol=1e-5, err_msg=model
        )


def test_cache_hit_returns_same_executable(tiny):
    from repro.distributed import runtime

    inst, plan, mesh, _, _ = tiny
    runtime.cache_clear()
    exe1 = runtime.compile_spgemm(plan, inst.a, inst.b, mesh)
    hits0 = runtime.cache_info()["hits"]
    exe2 = runtime.compile_spgemm(plan, inst.a, inst.b, mesh)
    assert exe2 is exe1
    assert runtime.cache_info()["hits"] == hits0 + 1
    # equal-content but distinct structure/plan objects still hit: the key
    # is the content fingerprint, not object identity
    from repro.core import SpGEMMInstance
    from repro.distributed import build_fine_plan

    inst2 = SpGEMMInstance(inst.a, inst.b)
    plan2 = build_fine_plan(inst2, np.zeros(inst2.n_mult, dtype=np.int64), 1)
    exe3 = runtime.compile_spgemm(plan2, inst2.a, inst2.b, mesh)
    assert exe3 is exe1


def test_cache_is_a_bounded_lru(tiny, monkeypatch):
    from repro.distributed import runtime

    inst, plan, mesh, _, _ = tiny
    runtime.cache_clear()
    monkeypatch.setattr(runtime, "CACHE_SIZE", 2)
    exe_f32 = runtime.compile_spgemm(plan, inst.a, inst.b, mesh, dtype=np.float32)
    runtime.compile_spgemm(plan, inst.a, inst.b, mesh, dtype=np.float16)
    runtime.compile_spgemm(plan, inst.a, inst.b, mesh, dtype=np.int32)
    assert runtime.cache_info()["size"] == 2
    # float32 (least recently used) was evicted: same key now rebuilds
    exe_again = runtime.compile_spgemm(plan, inst.a, inst.b, mesh, dtype=np.float32)
    assert exe_again is not exe_f32
    runtime.cache_clear()


def test_value_shape_mismatch_raises(tiny):
    from repro.distributed.runtime import compile_spgemm

    inst, plan, mesh, a, b = tiny
    exe = compile_spgemm(plan, inst.a, inst.b, mesh)
    av = a[inst.a.coo()]
    bv = b[inst.b.coo()]
    with pytest.raises(ValueError, match="same-structure"):
        exe(av[:-1], bv)
    with pytest.raises(ValueError, match="same-structure"):
        exe(av, np.concatenate([bv, bv]))


def test_fingerprints_are_id_stable_and_content_sensitive(tiny):
    from repro.core import SpGEMMInstance
    from repro.distributed import build_fine_plan
    from repro.distributed.runtime import plan_fingerprint, structure_fingerprint

    inst, plan, _, _, _ = tiny
    fp = plan_fingerprint(plan)
    assert plan_fingerprint(plan) == fp  # memoized on the object
    assert plan.__dict__.get("_fingerprint") == fp
    # identical content -> identical fingerprint on a fresh object
    plan2 = build_fine_plan(
        SpGEMMInstance(inst.a, inst.b), np.zeros(inst.n_mult, dtype=np.int64), 1
    )
    assert plan_fingerprint(plan2) == fp
    # different partition -> different fingerprint
    other = np.zeros(inst.n_mult, dtype=np.int64)
    plan3 = build_fine_plan(SpGEMMInstance(inst.a, inst.b), other, 2)
    assert plan_fingerprint(plan3) != fp
    assert structure_fingerprint(inst.a) != structure_fingerprint(inst.b)
    assert structure_fingerprint(inst.a) == structure_fingerprint(inst.a)


def test_fine_spgemm_accepts_sparse_operands(tiny):
    """The dense, scipy-sparse, and (structure, values) entry points agree —
    sparse callers never round-trip through dense."""
    import scipy.sparse as sp

    from repro.distributed import fine_spgemm

    inst, plan, mesh, a, b = tiny
    dense = np.asarray(fine_spgemm(a, b, plan, mesh))
    sparse = np.asarray(fine_spgemm(sp.csr_matrix(a), sp.csr_matrix(b), plan, mesh))
    pair = np.asarray(
        fine_spgemm(
            (inst.a, a[inst.a.coo()]), (inst.b, b[inst.b.coo()]), plan, mesh
        )
    )
    np.testing.assert_allclose(sparse, dense, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(pair, dense, rtol=1e-6, atol=1e-6)


def test_plan_fine_from_dense_accepts_structures(tiny):
    """Structure-only planning: no dense operand materialized anywhere."""
    from repro.distributed.plan_ir import plan_fine_from_dense

    inst, _, _, a, b = tiny
    plan_s, inst_s = plan_fine_from_dense(inst.a, inst.b, p=2)
    plan_d, _ = plan_fine_from_dense(a, b, p=2)
    from repro.distributed.runtime import plan_fingerprint

    assert plan_fingerprint(plan_s) == plan_fingerprint(plan_d)
    assert inst_s.a == inst.a
