"""Multi-device executor tests (subprocess: needs 4 placeholder devices,
which must not leak into this pytest process' jax)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def _run(case: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = str(devices)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, case],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize(
    "case", ["rowwise", "outer", "spsumma", "rowwise_identity_partition"]
)
def test_distributed_spgemm(case):
    assert f"OK {case.split('_partition')[0]}" in _run(case)


@pytest.mark.parametrize("devices", [4, 8])
@pytest.mark.parametrize("case", ["monoC", "monoC_blocked"])
def test_monoC_spgemm_matches_dense_oracle(case, devices):
    """2 instances x p in {4, 8}: the 2D monochrome-C executor equals A @ B."""
    assert f"OK {case} p={devices}" in _run(case, devices=devices)


def test_monoC_identity_partition_has_zero_traffic():
    assert "OK monoC_identity" in _run("monoC_identity_partition")


@pytest.mark.parametrize("devices", [4, 8])
@pytest.mark.parametrize("case", ["fine", "fine_nz"])
def test_fine_spgemm_matches_dense_oracle(case, devices):
    """3D fine-grained executor == A @ B at p in {4, 8}, with the planned
    words pinned to the fine hypergraph's connectivity cost."""
    assert f"OK {case} p={devices}" in _run(case, devices=devices)


def test_fine_identity_partition_has_zero_traffic():
    assert "OK fine_identity" in _run("fine_identity_partition")


@pytest.mark.parametrize("devices", [4, 8])
def test_model_selection_sweep_end_to_end(devices):
    """sweep_instance: all models partitioned, executors run, and measured
    == predicted words for the replicated-free (fine, monoC) plans."""
    assert "OK select best=" in _run("select", devices=devices)


def test_compressed_psum_error_feedback():
    assert "OK compressed_psum" in _run("compressed_psum")


def test_moe_expert_parallel_matches_fallback():
    """shard_map EP dispatch == single-device dispatch (no-drop capacity)."""
    assert "OK moe_ep" in _run("moe_ep")
