"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step + one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, shape_applicable
from repro.models import (
    decode_step,
    init_kv_cache,
    init_params,
    param_count,
    active_param_count,
    train_loss,
)
from repro.models.transformer import prefill_step


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    n_front = 16 if cfg.frontend == "vision" else 0
    batch = {}
    if n_front:
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((B, n_front, cfg.d_model)), cfg.dtype
        )
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S - n_front)), jnp.int32
    )
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S - n_front)), jnp.int32
    )
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        loss, metrics = train_loss(p, cfg, b)
        grads = jax.grad(lambda p: train_loss(p, cfg, b)[0])(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm), f"{arch}: grad norm not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    B = 2
    cache = init_kv_cache(cfg, B, 128)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tokens = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = step(params, cache, tokens)
        tokens = logits.argmax(-1)[:, None].astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode logits not finite"
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b", "hymba-1.5b"])
def test_prefill_matches_decode(arch):
    """Prefill-then-decode must agree with token-by-token decode."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    B, S = 1, 16
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    logits_p, cache_p = jax.jit(lambda p, b: prefill_step(p, cfg, b))(
        params, {"tokens": toks}
    )
    cache_d = init_kv_cache(cfg, B, S + 8)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    for i in range(S):
        logits_d, cache_d = step(params, cache_d, toks[:, i : i + 1])
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(logits_d, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


@pytest.mark.parametrize("arch", all_arch_ids())
def test_full_config_shapes(arch):
    """Full configs are exercised via eval_shape only (no allocation)."""
    cfg = get_config(arch)
    n = param_count(cfg)
    expected = {
        "starcoder2-15b": 15e9,
        "internlm2-1.8b": 1.8e9,
        "phi3-mini-3.8b": 3.8e9,
        "command-r-35b": 35e9,
        "llava-next-34b": 34e9,
        "falcon-mamba-7b": 7e9,
        "qwen3-moe-235b-a22b": 235e9,
        "dbrx-132b": 132e9,
        "musicgen-large": 1.5e9,  # musicgen-large backbone ~1.5B (audio LM)
        "hymba-1.5b": 1.5e9,
    }[arch]
    assert 0.5 * expected < n < 1.7 * expected, f"{arch}: {n/1e9:.1f}B params"
    if cfg.moe:
        a = active_param_count(cfg)
        assert a < n / 2, "MoE active params should be far below total"


def test_long_500k_applicability():
    ok = [a for a in all_arch_ids() if shape_applicable(get_config(a), "long_500k")[0]]
    assert set(ok) == {"falcon-mamba-7b", "hymba-1.5b"}
