"""Pallas kernel tests: shape/dtype sweeps, allclose vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU).

Hypothesis property tests live in ``test_kernels_property.py`` so this
module's deterministic oracle coverage survives environments without
hypothesis installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.bsr_spgemm import build_pair_lists
from repro.sparse.bsr import to_bsr, bsr_to_dense, BlockSparse


def _random_block_dense(rng, m, k, density, block):
    """Dense matrix whose nonzero support is block-structured."""
    gm, gk = m // block, k // block
    mask = rng.random((gm, gk)) < density
    if not mask.any():
        mask[0, 0] = True
    dense = rng.standard_normal((m, k)).astype(np.float32)
    full = np.kron(mask, np.ones((block, block), bool))
    return dense * full


# ---------------------------------------------------------------------------
# bsr_spmm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [8, 16])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("mn", [(32, 32, 16), (64, 32, 64)])
def test_bsr_spmm_matches_oracle(block, dtype, mn):
    m, k, n = mn
    rng = np.random.default_rng(0)
    a = _random_block_dense(rng, m, k, 0.4, block).astype(dtype)
    b = rng.standard_normal((k, n)).astype(dtype)
    bsr = to_bsr(np.asarray(a, np.float32), block, block)
    bsr = BlockSparse(bsr.blocks.astype(dtype), bsr.brows, bsr.bcols, bsr.shape)
    got = ops.spmm(bsr, b, interpret=True)
    want = ops.bsr_spmm_ref(
        jnp.asarray(bsr.blocks), jnp.asarray(bsr.brows), jnp.asarray(bsr.bcols),
        jnp.asarray(b), m // block,
    )
    tol = 1e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )
    # and the oracle itself matches plain matmul
    np.testing.assert_allclose(
        np.asarray(want, np.float32),
        np.asarray(a, np.float32) @ np.asarray(b, np.float32),
        rtol=tol * 3,
        atol=tol * 3,
    )


# ---------------------------------------------------------------------------
# bsr_spgemm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [8, 16])
@pytest.mark.parametrize("shape", [(32, 16, 48), (48, 48, 48)])
def test_bsr_spgemm_matches_dense(block, shape):
    m, k, n = shape
    rng = np.random.default_rng(1)
    a = _random_block_dense(rng, m, k, 0.5, block)
    b = _random_block_dense(rng, k, n, 0.5, block)
    ab, bb = to_bsr(a, block, block), to_bsr(b, block, block)
    c_blocks, crows, ccols = ops.spgemm(ab, bb, interpret=True)
    c = bsr_to_dense(
        BlockSparse(np.asarray(c_blocks), crows, ccols, (m, n))
    )
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)


def test_bsr_spgemm_pair_list_int32_cast_covers_all_operand_kinds():
    """The host-side int32 cast is one explicit helper: int64 ndarrays and
    Python lists cast host-side (no convert inside jit), already-int32
    traced operands pass through untouched, and other traced int dtypes get
    a single astype — all three kinds produce identical results."""
    from repro.kernels.bsr_spgemm import _pair_list_int32, bsr_spgemm

    # helper semantics per operand kind
    out = _pair_list_int32(np.array([0, 1, 2], dtype=np.int64))
    assert out.dtype == jnp.int32
    out = _pair_list_int32([0, 1, 2])
    assert out.dtype == jnp.int32
    traced32 = jnp.array([0, 1, 2], dtype=jnp.int32)
    assert _pair_list_int32(traced32) is traced32  # no-op, no copy
    assert _pair_list_int32(jnp.array([0, 1], dtype=jnp.int16)).dtype == jnp.int32

    # end to end: the kernel result is identical through every kind
    rng = np.random.default_rng(4)
    block = 8
    a = _random_block_dense(rng, 32, 16, 0.5, block)
    b = _random_block_dense(rng, 16, 24, 0.5, block)
    ab, bb = to_bsr(a, block, block), to_bsr(b, block, block)
    from repro.kernels.bsr_spgemm import build_pair_lists

    pa, pb, pc, crows, ccols = build_pair_lists(ab.brows, ab.bcols, bb.brows, bb.bcols)
    n_c = len(crows)
    want = bsr_spgemm(ab.blocks, bb.blocks, pa, pb, pc, n_c, interpret=True)
    as_list = bsr_spgemm(
        ab.blocks, bb.blocks, list(pa), list(pb), list(pc), n_c, interpret=True
    )
    as_jnp = bsr_spgemm(
        ab.blocks,
        bb.blocks,
        jnp.asarray(pa, jnp.int32),
        jnp.asarray(pb, jnp.int32),
        jnp.asarray(pc, jnp.int32),
        n_c,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(want), np.asarray(as_list))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(as_jnp))


def test_bsr_spgemm_pair_list_is_tiled_hypergraph():
    """The inspector's pair list cardinality equals |V^m| of the coarsened
    (block-level) SpGEMM hypergraph."""
    from repro.core import SpGEMMInstance
    from repro.sparse import from_coo

    rng = np.random.default_rng(2)
    block = 8
    a = _random_block_dense(rng, 40, 32, 0.4, block)
    b = _random_block_dense(rng, 32, 24, 0.4, block)
    ab, bb = to_bsr(a, block, block), to_bsr(b, block, block)
    pa, pb, pc, crows, ccols = build_pair_lists(ab.brows, ab.bcols, bb.brows, bb.bcols)
    inst = SpGEMMInstance(ab.block_structure(), bb.block_structure())
    assert len(pa) == inst.n_mult
    assert len(crows) == inst.c.nnz


# ---------------------------------------------------------------------------
# moe_gemm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 16, 32, 24), (4, 128, 64, 16)])
def test_moe_gemm_matches_oracle(dtype, shape):
    E, C, d, f = shape
    rng = np.random.default_rng(3)
    x = rng.standard_normal((E, C, d)).astype(dtype)
    w = rng.standard_normal((E, d, f)).astype(dtype)
    got = ops.grouped_gemm(x, w, interpret=True)
    want = ops.moe_gemm_ref(jnp.asarray(x), jnp.asarray(w))
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )
