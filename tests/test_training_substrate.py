"""Optimizer + roofline-analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
)


def _quadratic_problem():
    target = {"w": jnp.array([1.0, -2.0, 3.0]), "m": jnp.ones((4, 5)) * 0.5}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(
            jnp.sum(jnp.square(a - b))
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target))
        )

    return params, loss


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(opt):
    params, loss = _quadratic_problem()
    init, update = {
        "adamw": (adamw_init, adamw_update),
        "adafactor": (adafactor_init, adafactor_update),
    }[opt]
    state = init(params)
    l0 = float(loss(params))
    kw = {"weight_decay": 0.0} if opt == "adamw" else {}
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = update(grads, state, params, lr=5e-2, **kw)
    assert float(loss(params)) < l0 * 1e-2


def test_adamw_state_shapes_match_params():
    params = {"a": jnp.ones((3, 4)), "b": {"c": jnp.ones(7)}}
    st = adamw_init(params)
    assert jax.tree.structure(st["mu"]) == jax.tree.structure(params)
    for m, p in zip(jax.tree.leaves(st["mu"]), jax.tree.leaves(params)):
        assert m.shape == p.shape and m.dtype == jnp.float32


def test_adafactor_factored_second_moment_is_small():
    params = {"w": jnp.ones((128, 256))}
    st = adafactor_init(params)
    leaf = st["v"]["w"]
    # factored: 128 + 256 numbers, not 128*256
    assert leaf["vr"].shape == (128,) and leaf["vc"].shape == (256,)


def test_roofline_analyzer_terms():
    from benchmarks.roofline import analyze_record, PEAK_FLOPS, HBM_BW, ICI_BW

    rec = {
        "arch": "internlm2-1.8b",
        "shape": "train_4k",
        "mesh": "16x16",
        "n_devices": 256,
        "flops": PEAK_FLOPS,  # exactly one second of compute
        "bytes_accessed": HBM_BW * 2.0,  # two seconds of memory
        "wire_bytes": ICI_BW * 0.5,
        "memory": {"argument_size_in_bytes": 1, "temp_size_in_bytes": 2},
    }
    a = analyze_record(rec)
    assert abs(a["compute_s"] - 1.0) < 1e-9
    assert abs(a["memory_s"] - 2.0) < 1e-9
    assert abs(a["collective_s"] - 0.5) < 1e-9
    assert a["dominant"] == "memory"
    assert 0 < a["roofline_fraction"] < 1
