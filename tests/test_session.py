"""Resilient session lifecycle: pool hits, drift-aware warm replanning,
injected failures at every stage boundary, model downgrades, and
kill-and-restore from the persistent plan store with zero recompilation.

Runs at ``p=1`` so the whole lifecycle executes in-process on one device;
the multi-device variant lives in ``tests/multidev_runner.py``.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

import repro
from repro.checkpoint import list_plans
from repro.distributed import runtime
from repro.resilience import FaultPolicy
from repro.testing import faults

FAST = FaultPolicy(max_retries=2, backoff_s=0.0)


def _mats(seed=0, shape=(14, 12, 13), density=0.35):
    rng = np.random.default_rng(seed)
    A = rng.random(shape[:2]) * (rng.random(shape[:2]) < density)
    B = rng.random(shape[1:]) * (rng.random(shape[1:]) < density)
    # no empty rows/cols on the contraction axis (keeps products non-trivial)
    A[np.arange(shape[0]), rng.integers(0, shape[1], shape[0])] = 1.0
    B[np.arange(shape[1]), rng.integers(0, shape[2], shape[1])] = 1.0
    return A.astype(np.float32), B.astype(np.float32)


def _drift(M, seed=1, frac=0.15):
    """Perturb the sparsity structure in place-shape: drop some nonzeros,
    add some new ones."""
    rng = np.random.default_rng(seed)
    out = M.copy()
    nz = np.flatnonzero(out)
    drop = rng.choice(nz, max(1, int(frac * len(nz))), replace=False)
    out.flat[drop] = 0.0
    z = np.flatnonzero(out == 0)
    add = rng.choice(z, max(1, int(frac * len(nz))), replace=False)
    out.flat[add] = rng.random(len(add)).astype(np.float32) + 0.1
    return out


def _kinds(s):
    return [e.kind for e in s.events]


def _check(s, A, B):
    C = np.asarray(s.multiply(A, B))
    np.testing.assert_allclose(C, A @ B, rtol=2e-4, atol=2e-4)
    return C


# ---------------------------------------------------------------------------
# lifecycle: cold plan -> pool hit -> drift -> warm replan
# ---------------------------------------------------------------------------
def test_unchanged_structure_hits_warm_pool():
    A, B = _mats(0)
    s = repro.session(p=1, model="rowwise", policy=FAST)
    _check(s, A, B)
    assert _kinds(s) == ["cold_replan"]
    # same structure, new values: pool hit, no replanning of any kind
    _check(s, A * 2.0, B)
    assert _kinds(s) == ["cold_replan", "hit"]
    assert s.stats()["pool_size"] == 1


def test_drifted_structure_warm_starts_the_partitioner():
    A, B = _mats(1)
    s = repro.session(p=1, model="rowwise", policy=FAST)
    _check(s, A, B)
    A2 = _drift(A, seed=2)
    _check(s, A2, B)
    kinds = _kinds(s)
    assert kinds.count("warm_replan") == 1
    warm = next(e for e in s.events if e.kind == "warm_replan")
    assert 0.0 <= warm.detail["drift"] < 1.0
    # drifting back: the first structure is still in the pool
    _check(s, A, B)
    assert _kinds(s)[-1] == "hit"


def test_shape_change_forces_cold_replan():
    s = repro.session(p=1, model="rowwise", policy=FAST)
    _check(s, *_mats(3))
    _check(s, *_mats(3, shape=(20, 12, 13)))  # labels can't carry across I
    assert _kinds(s) == ["cold_replan", "cold_replan"]


def test_model_auto_resolves_once_then_warm_starts():
    A, B = _mats(4)
    s = repro.session(p=1, model="auto", policy=FAST)
    _check(s, A, B)
    resolved = s.stats()["model"]
    assert resolved in repro.executable_models()
    _check(s, _drift(A, seed=5), B)
    assert s.stats()["model"] == resolved
    assert _kinds(s)[-1] == "warm_replan"


def test_pool_is_bounded_lru():
    s = repro.session(p=1, model="rowwise", policy=FAST, max_entries=2)
    for seed in range(4):
        _check(s, *_mats(seed))
    assert s.stats()["pool_size"] == 2


# ---------------------------------------------------------------------------
# fault injection: every stage boundary, transient and permanent
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stage", faults.STAGES)
def test_transient_fault_at_each_stage_is_retried(stage, tmp_path):
    """One multiply touches all five boundaries (empty store: restore is
    attempted, returns nothing, plan is saved).  A transient failure at any
    one of them must be retried and leave the result correct."""
    A, B = _mats(10 + list(faults.STAGES).index(stage))  # defeat executor LRU
    s = repro.session(
        p=1, model="rowwise", policy=FAST, store_dir=str(tmp_path / "store")
    )
    with faults.inject(stage, times=1) as script:
        _check(s, A, B)
    assert script.fired == 1, f"fault at {stage!r} never fired"
    retried = [e for e in s.events if e.kind == "retry" and e.detail["stage"] == stage]
    assert len(retried) == 1
    assert "saved" in _kinds(s)


def test_permanent_execute_failure_downgrades_model():
    A, B = _mats(20)
    s = repro.session(p=1, model="fine", policy=FAST)
    with faults.inject("execute", exc=ValueError, times=1) as script:
        _check(s, A, B)
    assert script.fired == 1
    kinds = _kinds(s)
    assert "model_downgrade" in kinds
    down = next(e for e in s.events if e.kind == "model_downgrade")
    assert down.detail["from_model"] == "fine"
    assert down.model == "monoC"
    assert s.stats()["model"] == "monoC"
    # the downgraded entry is the warm one now: next call is a pure hit
    _check(s, A, B)
    assert _kinds(s)[-1] == "hit"


def test_permanent_store_failure_is_nonfatal(tmp_path):
    A, B = _mats(21)
    s = repro.session(
        p=1, model="rowwise", policy=FAST, store_dir=str(tmp_path / "store")
    )
    with faults.inject("store_save", exc=ValueError, times=1):
        _check(s, A, B)  # persistence lost, multiply unharmed
    ev = next(e for e in s.events if e.kind == "store_error")
    assert ev.detail["op"] == "save"
    assert "saved" not in _kinds(s)
    assert list_plans(str(tmp_path / "store")) == []


def test_mcl_style_loop_survives_scripted_faults(tmp_path):
    """The acceptance loop: expand-and-prune iterations (structure drifts
    every step) with failures scripted at several boundaries, every product
    still bit-checked against numpy."""
    rng = np.random.default_rng(7)
    n = 16
    M = (rng.random((n, n)) * (rng.random((n, n)) < 0.4)).astype(np.float32)
    M[np.arange(n), np.arange(n)] = 1.0  # self-loops keep rows nonempty
    s = repro.session(
        p=1, model="rowwise", policy=FAST, store_dir=str(tmp_path / "store")
    )
    schedule = {"partition": [1], "execute": [2], "store_save": [0], "compile": [1]}
    with faults.scripted(schedule) as scripts:
        for _ in range(4):
            C = np.asarray(s.multiply(M, M))
            np.testing.assert_allclose(C, M @ M, rtol=2e-4, atol=2e-4)
            # prune + renormalize: the structure drifts for the next round
            C[C < np.quantile(C[C > 0], 0.3)] = 0.0
            col = C.sum(axis=0)
            M = (C / np.where(col > 0, col, 1.0)).astype(np.float32)
            M[np.arange(n), np.arange(n)] += 0.5
    for stage, script in scripts.items():
        assert script.fired == len(schedule[stage]), f"{stage} fault never fired"
    kinds = _kinds(s)
    assert kinds.count("cold_replan") == 1
    assert kinds.count("warm_replan") == 3


# ---------------------------------------------------------------------------
# persistence: kill-and-restore, corruption quarantine
# ---------------------------------------------------------------------------
def test_killed_session_restores_from_store_without_recompiling(tmp_path):
    store = str(tmp_path / "store")
    A, B = _mats(30)
    s1 = repro.session(p=1, model="rowwise", policy=FAST, store_dir=store)
    want = _check(s1, A, B)
    assert "saved" in _kinds(s1)
    del s1  # the crash

    s2 = repro.session(p=1, model="rowwise", policy=FAST, store_dir=store)
    before = runtime.trace_count()
    got = _check(s2, A, B)
    assert runtime.trace_count() == before  # no retrace: the restored plan
    # is content-identical, so compilation hits the process-wide executor LRU
    assert _kinds(s2) == ["restored"]
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # restored labels seed warm starts exactly like home-grown ones
    _check(s2, _drift(A, seed=31), B)
    assert _kinds(s2)[-2:] == ["warm_replan", "saved"]


def test_corrupt_store_entry_is_quarantined_and_replanned(tmp_path):
    store = str(tmp_path / "store")
    A, B = _mats(32)
    s1 = repro.session(p=1, model="rowwise", policy=FAST, store_dir=store)
    _check(s1, A, B)
    (key,) = list_plans(store)
    blob = os.path.join(store, key, "arrays.npz")
    raw = bytearray(open(blob, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(blob, "wb").write(bytes(raw))

    s2 = repro.session(p=1, model="rowwise", policy=FAST, store_dir=store)
    with pytest.warns(RuntimeWarning, match="quarantin"):
        _check(s2, A, B)
    assert _kinds(s2)[:1] == ["cold_replan"]  # store gave nothing back
    assert list_plans(store) == [key]  # fresh plan re-saved under the key
    assert any(d.startswith(key + ".quarantined") for d in os.listdir(store))


# ---------------------------------------------------------------------------
# multi-device: the full acceptance loop at p=4 (subprocess: forced host
# devices must not leak into this pytest process' jax)
# ---------------------------------------------------------------------------
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def test_multidev_session_drift_faults_and_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = "4"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, "session"],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK session p=4" in out.stdout
