"""MoE dispatch planner tests: the hypergraph placement must beat naive
contiguous placement on correlated routing, and the permutation must be
valid + integrate with the MoE layer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe_planner import (
    dispatch_instance,
    plan_expert_placement,
    routing_counts,
)


def _correlated_routing(T=4096, E=16, K=2, n_blocks=4, seed=0):
    """Token span i prefers the expert block i mod n_blocks, but the expert
    ids within a 'semantic' block are scattered across the naive layout."""
    rng = np.random.default_rng(seed)
    scattered = rng.permutation(E).reshape(n_blocks, E // n_blocks)
    gate = np.empty((T, K), dtype=np.int64)
    for t in range(T):
        blk = (t * n_blocks) // T
        gate[t] = rng.choice(scattered[blk], size=K, replace=False)
    return gate


def test_routing_counts_shape_and_total():
    gate = _correlated_routing()
    counts = routing_counts(gate, 16, 32)
    assert counts.shape == (32, 16)
    assert counts.sum() == gate.size


def test_dispatch_instance_is_spgemm():
    gate = _correlated_routing()
    counts = routing_counts(gate, 16, 32)
    inst = dispatch_instance(counts)
    E, G, one = inst.shape
    assert (E, G, one) == (16, 32, 1)
    assert inst.n_mult == (counts > 0).sum()


def test_planner_beats_contiguous_on_correlated_routing():
    gate = _correlated_routing()
    counts = routing_counts(gate, 16, 64)
    plan = plan_expert_placement(counts, n_columns=4, seed=0)
    # the planner must recover (most of) the scattered block structure
    assert plan.comm_planned < plan.comm_contiguous
    # permutation validity
    assert sorted(plan.placement.tolist()) == list(range(16))
    # column sizes exactly E/cols
    assert (np.bincount(plan.column_of, minlength=4) == 4).all()


def test_placement_integrates_with_moe_layer():
    """moe_layer with a planner placement still computes a valid output."""
    from repro.configs import get_smoke_config
    from repro.models import init_params, train_loss
    import dataclasses

    cfg = get_smoke_config("dbrx-132b")
    gate = _correlated_routing(
        T=512, E=cfg.moe.n_experts, K=cfg.moe.top_k, n_blocks=2
    )
    counts = routing_counts(gate, cfg.moe.n_experts, 16)
    plan = plan_expert_placement(counts, n_columns=2)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, expert_placement=tuple(plan.placement))
    )
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32),
    }
    loss, _ = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss)
