"""Device-resident coarsening invariants (``core/coarsen_device.py`` and the
``engine="device", coarsen="auto"`` driver path).

The resident V-cycle replaces the host scipy descend with jitted cluster +
contract kernels; these tests pin the contracts that keep it honest:

- the cluster map is a valid contraction (every vertex lands in a real
  cluster, weights are conserved exactly, no cluster outgrows the cap the
  kernel was given),
- the end-to-end resident partition stays within a bounded connectivity
  ratio of the host-coarsening path it replaced,
- fixed seeds reproduce bit-identical partitions,
- repeated same-shape partitions never retrace a kernel (compile-once
  bucketing, the PR's perf contract), and
- a blocked ``coarsen_device`` import degrades to host coarsening with one
  warning and the identical host-coarsening result.

Like ``test_partition_device.py``, the device engine's size threshold is
monkeypatched to 0 so the small instances here exercise the kernels.
"""
import importlib
import sys
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.sparse.structure import random_structure

partition_mod = importlib.import_module("repro.core.partition")
refine_device = importlib.import_module("repro.core.refine_device")
coarsen_device = importlib.import_module("repro.core.coarsen_device")


def _instance(seed=0, rows=900, inner=700, cols=800, density=0.01):
    rng = np.random.default_rng(seed)
    a = random_structure(rows, inner, density, rng)
    b = random_structure(inner, cols, density, rng)
    return SpGEMMInstance(a, b)


@pytest.fixture(autouse=True)
def fresh_fallback_warnings(monkeypatch):
    """The device fallback warns once per process per reason; give each test
    its own warned-set so warning assertions stay order-independent."""
    monkeypatch.setattr(partition_mod, "_FALLBACK_WARNED", set())


@pytest.fixture
def device_everywhere(monkeypatch):
    """Route every size through the device engine."""
    monkeypatch.setattr(partition_mod, "DEVICE_MIN_VERTICES", 0)


# ---------------------------------------------------------------------------
# cluster-map validity
# ---------------------------------------------------------------------------
def test_cluster_map_is_valid_capped_contraction():
    """One ``coarsen_level`` call yields a genuine contraction: every real
    vertex maps into [0, n_coarse), coarse weights are the exact per-cluster
    sums of fine weights, and no cluster exceeds the weight cap handed to
    the kernel."""
    hg = build_model(_instance(0), "rowwise")
    level = coarsen_device.finest_level(hg)
    w = hg.w_comp.astype(np.float64)
    cap = max(float(w.sum()) / 12.0, float(w.max()))
    out = coarsen_device.coarsen_level(level, cap, seed=0, index=0)
    assert out is not None, "clustering stalled on a healthy instance"
    coarse, cmap, n_coarse = out
    assert coarse.n_vertices == n_coarse
    assert 0 < n_coarse < hg.n_vertices
    cm = np.asarray(cmap)[: hg.n_vertices]
    assert cm.min() >= 0 and cm.max() < n_coarse
    coarse_w = np.asarray(coarse.args[3])[:n_coarse].astype(np.float64)
    summed = np.bincount(cm, weights=w, minlength=n_coarse)
    np.testing.assert_allclose(coarse_w, summed, rtol=1e-5)
    assert (coarse_w <= cap * (1 + 1e-6)).all()


def test_coarsen_level_preserves_total_weight_down_the_hierarchy():
    hg = build_model(_instance(1), "rowwise")
    total = float(hg.w_comp.sum())
    cap = max(total / 10.0, float(hg.w_comp.max()))
    level = coarsen_device.finest_level(hg)
    for index in range(3):
        out = coarsen_device.coarsen_level(level, cap, seed=0, index=index)
        if out is None:
            break
        level = out[0]
        lw = np.asarray(level.args[3])[: level.n_vertices]
        assert np.isclose(float(lw.sum()), total, rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end quality, balance and determinism
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("inst_seed", [3, 4])
def test_resident_connectivity_ratio_bounded_vs_host_coarsening(
    device_everywhere, inst_seed
):
    """The device descend may not give back more than 5% connectivity vs
    the host-coarsening device path it replaces (the bench gates the same
    bound at scale on er10k/p16)."""
    hg = build_model(_instance(inst_seed), "rowwise")
    dev = partition(hg, 4, eps=0.10, seed=0, engine="device")
    host = partition(hg, 4, eps=0.10, seed=0, engine="device", coarsen="host")
    assert dev.connectivity <= 1.05 * host.connectivity


def test_resident_balance_cap_respected(device_everywhere):
    p, eps = 4, 0.10
    hg = build_model(_instance(3), "rowwise")
    res = partition(hg, p, eps=eps, seed=0, engine="device")
    w = hg.w_comp.astype(np.float64)
    part_w = np.bincount(res.parts, weights=w, minlength=p)
    cap = max((1 + eps) * w.sum() / p, float(w.max()))
    assert (part_w <= cap + 1e-9).all()


def test_resident_deterministic_for_fixed_seed(device_everywhere):
    hg = build_model(_instance(4), "rowwise")
    a = partition(hg, 4, eps=0.10, seed=5, engine="device")
    b = partition(hg, 4, eps=0.10, seed=5, engine="device")
    assert np.array_equal(a.parts, b.parts)
    assert a.connectivity == b.connectivity
    assert a.connectivity == evaluate(hg, a.parts, 4).connectivity


# ---------------------------------------------------------------------------
# compile-once shape bucketing
# ---------------------------------------------------------------------------
def test_coarsen_kernels_retrace_once_per_shape_bucket(device_everywhere):
    """Repeated resident partitions of the same instance reuse every jitted
    cluster/contract kernel (and every refiner): the retrace counters move
    only while warming."""
    hg = build_model(_instance(5), "rowwise")
    partition(hg, 4, eps=0.10, seed=0, engine="device")  # warm the caches
    before_cd = coarsen_device.trace_count()
    before_rd = refine_device.trace_count()
    partition(hg, 4, eps=0.10, seed=0, engine="device")
    partition(hg, 4, eps=0.10, seed=0, engine="device")
    assert coarsen_device.trace_count() == before_cd
    assert refine_device.trace_count() == before_rd


def test_cluster_kernel_shared_across_p(device_everywhere):
    """The clusterer is partition-count-independent: changing ``p`` compiles
    fresh refiners but reuses the descend kernels for the finest level."""
    hg = build_model(_instance(6), "rowwise")
    partition(hg, 4, eps=0.10, seed=0, engine="device")  # warm p=4
    n_clusterers = len(coarsen_device._CLUSTERERS)
    partition(hg, 5, eps=0.10, seed=0, engine="device")
    # p=5 may descend to a different depth (the stop target scales with p)
    # but the finest-level clusterer key is identical — no new entry for it
    keys = list(coarsen_device._CLUSTERERS)
    finest = coarsen_device.finest_level(hg)
    assert sum(
        1
        for k in keys
        if k[:3] == (finest.nb, finest.mb, finest.pb)
    ) == 1
    assert len(coarsen_device._CLUSTERERS) >= n_clusterers


# ---------------------------------------------------------------------------
# degradation: blocked import falls back to host coarsening
# ---------------------------------------------------------------------------
def test_blocked_coarsen_import_falls_back_to_host_coarsening(
    device_everywhere, monkeypatch
):
    """With ``coarsen_device`` unimportable the driver warns ONCE and
    produces exactly the host-coarsening result — and an explicit
    ``coarsen="host"`` request never warns at all."""
    hg = build_model(_instance(7), "rowwise")
    want = partition(hg, 4, eps=0.10, seed=0, engine="device", coarsen="host")
    monkeypatch.setitem(sys.modules, "repro.core.coarsen_device", None)
    with pytest.warns(RuntimeWarning, match="host coarsening"):
        got = partition(hg, 4, eps=0.10, seed=0, engine="device")
    assert np.array_equal(got.parts, want.parts)
    assert got.connectivity == want.connectivity
    # second call: same fallback, no second warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        again = partition(hg, 4, eps=0.10, seed=0, engine="device")
    assert np.array_equal(again.parts, want.parts)


def test_runtime_coarsen_failure_falls_back_to_host_coarsening(
    device_everywhere, monkeypatch
):
    """A descend that dies at runtime degrades to host coarsening with one
    warning and the identical host-coarsening result."""

    def boom(level, cap, seed, index):
        raise RuntimeError("RESOURCE_EXHAUSTED: injected device OOM")

    hg = build_model(_instance(8), "rowwise")
    want = partition(hg, 4, eps=0.10, seed=0, engine="device", coarsen="host")
    monkeypatch.setattr(coarsen_device, "coarsen_level", boom)
    with pytest.warns(RuntimeWarning, match="host coarsening"):
        got = partition(hg, 4, eps=0.10, seed=0, engine="device")
    assert np.array_equal(got.parts, want.parts)
    assert got.connectivity == want.connectivity


def test_bad_coarsen_value_rejected():
    hg = build_model(_instance(0, rows=60, inner=50, cols=55, density=0.08),
                     "rowwise")
    with pytest.raises(ValueError):
        partition(hg, 2, engine="device", coarsen="gpu")
