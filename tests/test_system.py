"""End-to-end behaviour tests: the hypergraph MODEL's predicted
communication equals the EXECUTOR plan's scheduled communication (Lemma 4.2
made executable), across random instances and partitions."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.distributed import build_outer_plan, build_rowwise_plan
from repro.sparse.structure import random_structure


def _inst(seed, shape=(40, 28, 33), density=0.15):
    rng = np.random.default_rng(seed)
    a = random_structure(shape[0], shape[1], density, rng)
    b = random_structure(shape[1], shape[2], density, rng)
    return SpGEMMInstance(a, b)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("p", [2, 4])
def test_rowwise_model_matches_executor_plan(seed, p):
    """The row-wise hypergraph (with B nonzero-vertices pinned to their
    owners) predicts, via the connectivity metric with unit net costs,
    exactly the number of B-row transfers the executor schedules."""
    inst = _inst(seed)
    I, K, J = inst.shape
    hg = build_model(inst, "rowwise", include_nz=True)
    res = partition(build_model(inst, "rowwise"), p, eps=0.3, seed=seed)
    row_part = res.parts[:I]
    b_part = np.arange(K) % p  # executor default distribution

    plan = build_rowwise_plan(inst, row_part, p, b_part=b_part)

    # hypergraph prediction: vertices = rows + B-row vertices
    parts = np.concatenate([row_part, b_part])
    hg.net_cost = np.ones(hg.n_nets, dtype=np.int64)  # count B-row transfers
    costs = evaluate(hg, parts, p)
    assert costs.connectivity == plan.comm_words_ideal


@pytest.mark.parametrize("seed", [3, 4])
def test_outer_model_matches_fold_plan(seed):
    """Outer-product fold volume: (distinct contributing k-parts - 1) summed
    over C nonzeros — model and plan must agree."""
    inst = _inst(seed)
    p = 4
    hg = build_model(inst, "outer")
    res = partition(hg, p, eps=0.3, seed=seed)
    plan = build_outer_plan(inst, res.parts[: inst.shape[1]], p)
    costs = evaluate(hg, res.parts, p)
    # outer model nets are C nonzeros with unit cost; connectivity = fold
    assert costs.connectivity == plan.comm_words_ideal


def test_partition_quality_transfers_to_executor(tmp_path):
    """A better partition (lower hypergraph cut) yields a plan with less
    scheduled traffic than a random partition — the paper's premise."""
    inst = _inst(7, shape=(60, 40, 50), density=0.12)
    I, K, J = inst.shape
    p = 4
    hg = build_model(inst, "rowwise")
    good = partition(hg, p, eps=0.3, seed=0).parts
    rng = np.random.default_rng(0)
    bad = rng.integers(0, p, size=I)
    b_part = np.arange(K) % p
    plan_good = build_rowwise_plan(inst, good, p, b_part=b_part)
    plan_bad = build_rowwise_plan(inst, bad, p, b_part=b_part)
    assert plan_good.comm_words_ideal < plan_bad.comm_words_ideal
