"""Plan IR invariants: vectorized construction == loop-based reference,
routing-table consistency, and a host-side (numpy) simulation of the monoC
routes — no multi-device jax needed."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance
from repro.core.spgemm_models import _lin_lookup
from repro.distributed import (
    build_monoC_plan,
    build_outer_plan,
    build_rowwise_plan,
)
from repro.distributed.plan import build_rowwise_plan_loop
from repro.distributed.plan_ir import padded_id_lists, plan_monoC_from_dense
from repro.kernels.bsr_spgemm import build_pair_lists, build_pair_lists_loop
from repro.sparse.structure import random_structure


def _instance(seed, i=40, k=32, j=36, density=0.15):
    rng = np.random.default_rng(seed)
    return SpGEMMInstance(
        random_structure(i, k, density, rng), random_structure(k, j, density, rng)
    )


# ---------------------------------------------------------------------------
# vectorized == loop (byte-identical routing tables)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(6))
def test_vectorized_rowwise_plan_matches_loop(seed):
    rng = np.random.default_rng(seed)
    inst = _instance(seed)
    p = int(rng.integers(2, 7))
    row_part = rng.integers(0, p, inst.shape[0])
    b_part = rng.integers(0, p, inst.shape[1]) if seed % 2 else None
    vec = build_rowwise_plan(inst, row_part, p, b_part)
    loop = build_rowwise_plan_loop(inst, row_part, p, b_part)
    assert np.array_equal(vec.send_idx, loop.send_idx)
    assert np.array_equal(vec.recv_key, loop.recv_key)
    assert np.array_equal(vec.local_rows, loop.local_rows)
    assert np.array_equal(vec.local_b_rows, loop.local_b_rows)
    assert vec.comm_words_ideal == loop.comm_words_ideal
    assert vec.comm_words_padded == loop.comm_words_padded
    assert vec.send_idx.dtype == np.int64


@pytest.mark.parametrize("seed", range(6))
def test_vectorized_pair_lists_match_loop(seed):
    rng = np.random.default_rng(seed)
    na, nb = int(rng.integers(0, 40)), int(rng.integers(0, 40))
    K, GR, GC = (int(rng.integers(1, 9)) for _ in range(3))
    args = (
        rng.integers(0, GR, na),
        rng.integers(0, K, na),
        rng.integers(0, K, nb),
        rng.integers(0, GC, nb),
    )
    for got, want in zip(build_pair_lists(*args), build_pair_lists_loop(*args)):
        assert np.array_equal(got, want)
        assert got.dtype == np.int64


# ---------------------------------------------------------------------------
# IR invariants
# ---------------------------------------------------------------------------
def test_padded_id_lists_roundtrip():
    rng = np.random.default_rng(0)
    p = 5
    part = rng.integers(0, p, 37)
    local_ids, local_of = padded_id_lists(part, p)
    for d in range(p):
        owned = local_ids[d][local_ids[d] >= 0]
        assert np.array_equal(owned, np.flatnonzero(part == d))
        assert np.array_equal(local_of[owned], np.arange(len(owned)))


def test_route_accounting_and_membership():
    inst = _instance(3)
    rng = np.random.default_rng(3)
    p = 4
    plan = build_rowwise_plan(inst, rng.integers(0, p, inst.shape[0]), p)
    route = plan.routes["expand"]
    assert route.items_padded >= route.items_ideal
    assert int((route.recv_key >= 0).sum()) == route.items_ideal
    # a device never ships to itself; padding is aligned between the tables
    for s in range(p):
        assert (route.recv_key[s, s] == -1).all()
    assert np.array_equal(route.send_idx >= 0, route.recv_key >= 0)
    # shipped local slots resolve to the advertised global row
    s_ids, d_ids, t_ids = np.nonzero(route.send_idx >= 0)
    local = route.send_idx[s_ids, d_ids, t_ids]
    assert np.array_equal(
        plan.local_ids["b_row"][s_ids, local], route.recv_key[s_ids, d_ids, t_ids]
    )


def test_outer_plan_fold_volume_via_stats():
    inst = _instance(4)
    rng = np.random.default_rng(4)
    p = 4
    plan = build_outer_plan(inst, rng.integers(0, p, inst.shape[1]), p)
    assert plan.routes == {}
    assert plan.comm_words_ideal == plan.stats["fold_words_ideal"] >= 0
    # the dense psum_scatter fold dominates the connectivity metric, so the
    # model-agnostic padding invariant holds for route-less plans too
    assert plan.comm_words_padded >= plan.comm_words_ideal
    assert 0.0 <= plan.padding_fraction <= 1.0


def test_monoC_plan_host_simulation():
    """Simulate the two expand routes with numpy gathers and run the pair
    lists over the resulting slot tables: must reproduce dense A @ B."""
    rng = np.random.default_rng(5)
    I, K, J, block, p = 36, 28, 32, 4, 4
    a = rng.standard_normal((I, K)).astype(np.float32) * (rng.random((I, K)) < 0.2)
    b = rng.standard_normal((K, J)).astype(np.float32) * (rng.random((K, J)) < 0.2)
    plan, inst = plan_monoC_from_dense(a, b, block, p)
    from repro.sparse.bsr import to_bsr

    ab, bb = to_bsr(a, block, block), to_bsr(b, block, block)

    def tables(blocks, local_ids, route):
        N_max, T = local_ids.shape[1], route.T
        tabs = np.zeros((p, N_max + p * T + 1, block, block), np.float32)
        dev, slot = np.nonzero(local_ids >= 0)
        tabs[dev, slot] = blocks[local_ids[dev, slot]]
        s_ids, d_ids, t_ids = np.nonzero(route.recv_key >= 0)
        tabs[d_ids, N_max + s_ids * T + t_ids] = blocks[
            route.recv_key[s_ids, d_ids, t_ids]
        ]
        return tabs

    a_tabs = tables(ab.blocks, plan.local_ids["a_nz"], plan.routes["expand_a"])
    b_tabs = tables(bb.blocks, plan.local_ids["b_nz"], plan.routes["expand_b"])
    pa, pb, pc = (plan.compute[k] for k in ("pair_a", "pair_b", "pair_c"))
    c_slots = np.zeros((p, plan.n_c_slots, block, block), np.float32)
    for d in range(p):
        np.add.at(
            c_slots[d], pc[d], np.einsum("nij,njk->nik", a_tabs[d][pa[d]], b_tabs[d][pb[d]])
        )
    # scatter back to dense
    gr, gc = inst.c.shape
    crow, ccol = inst.c.coo()
    out = np.zeros((gr, gc, block, block), np.float32)
    dev, slot = np.nonzero(plan.local_ids["c_nz"] >= 0)
    gids = plan.local_ids["c_nz"][dev, slot]
    out[crow[gids], ccol[gids]] = c_slots[dev, slot]
    dense = out.transpose(0, 2, 1, 3).reshape(gr * block, gc * block)[:I, :J]
    np.testing.assert_allclose(dense, a @ b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# satellite regression: _lin_lookup out-of-range queries
# ---------------------------------------------------------------------------
def test_lin_lookup_out_of_range_raises_keyerror():
    from repro.sparse.structure import from_coo

    s = from_coo([0, 1], [0, 1], (2, 2))
    # absent but within range: plain membership failure
    with pytest.raises(KeyError):
        _lin_lookup(s, np.array([1]), np.array([0]))
    # past the last stored linear index: searchsorted returns len(lin_sorted)
    # and used to IndexError on the gather before the intended KeyError
    s2 = from_coo([0], [0], (2, 2))
    with pytest.raises(KeyError):
        _lin_lookup(s2, np.array([1]), np.array([1]))
    # in-range queries still resolve
    assert np.array_equal(
        _lin_lookup(s, np.array([0, 1]), np.array([0, 1])), np.array([0, 1])
    )
