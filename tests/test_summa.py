"""Sparse SUMMA baseline: the oblivious competitor's plan-level invariants
(pure numpy, in-process) plus the executor oracle through the subprocess
runner (forced host devices must not leak into this pytest process' jax).

The load-bearing identity mirrors the hypergraph models' measured ==
predicted check with the connectivity metric replaced by the closed form:
the per-stage broadcast routes must ship exactly
``nnz(A) * (pc - 1) + nnz(B) * (pr - 1)`` words for EVERY factorization of
p — obliviousness means the volume never depends on the other operand's
sparsity.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.spgemm_models import SpGEMMInstance
from repro.distributed.plan_ir import measured_route_words, route_messages
from repro.distributed.summa import (
    SummaPlan,
    build_summa_plan,
    summa_mesh_shape,
    summa_words_ideal,
)
from repro.sparse.structure import random_structure

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def _run(case: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = str(devices)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, case],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def _inst(seed: int, shape=(30, 24, 27), density=(0.2, 0.2)) -> SpGEMMInstance:
    rng = np.random.default_rng(seed)
    I, K, J = shape
    return SpGEMMInstance(
        random_structure(I, K, density[0], rng),
        random_structure(K, J, density[1], rng),
        name=f"summa_case_{seed}",
    )


def _factorizations(p: int):
    return [(pr, p // pr) for pr in range(1, p + 1) if p % pr == 0]


# ---------------------------------------------------------------------------
# plan-level invariants (pure numpy)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [2, 4, 6, 8, 12])
def test_measured_words_equal_closed_form_for_every_factorization(p):
    inst = _inst(0)
    for pr, pc in _factorizations(p):
        plan = build_summa_plan(inst, p, pr=pr, pc=pc)
        assert isinstance(plan, SummaPlan)
        want = summa_words_ideal(inst, pr, pc)
        assert plan.stats["words_analytic"] == want, (pr, pc)
        assert measured_route_words(plan) == want, (pr, pc)
        assert plan.comm_words_ideal == want, (pr, pc)
        assert plan.comm_words_padded >= want, (pr, pc)
        assert plan.stats["n_pairs"] == inst.n_mult, (pr, pc)
        assert route_messages(plan) >= 0


def test_stage_count_is_lcm_and_routes_cover_every_stage():
    inst = _inst(1)
    for p, pr, pc, want_s in ((6, 2, 3, 6), (8, 2, 4, 4), (4, 2, 2, 2), (1, 1, 1, 1)):
        plan = build_summa_plan(inst, p, pr=pr, pc=pc)
        assert (plan.pr, plan.pc, plan.n_stages) == (pr, pc, want_s)
        assert len(plan.routes) == 2 * want_s
        # every A/B nonzero is broadcast in exactly one stage
        sent_a = sum(plan.routes[f"bcast_a_s{t}"].items_ideal for t in range(want_s))
        sent_b = sum(plan.routes[f"bcast_b_s{t}"].items_ideal for t in range(want_s))
        assert sent_a == inst.a.nnz * (pc - 1)
        assert sent_b == inst.b.nnz * (pr - 1)


def test_single_device_plan_is_communication_free():
    plan = build_summa_plan(_inst(2), 1)
    assert plan.stats["words_analytic"] == 0
    assert measured_route_words(plan) == 0


def test_bad_factorization_raises():
    with pytest.raises(ValueError, match="does not factor"):
        build_summa_plan(_inst(3), 4, pr=3, pc=2)


def test_mesh_shape_minimizes_analytic_volume():
    # no instance: nearest-square, ties toward more rows
    assert summa_mesh_shape(4) == (2, 2)
    assert summa_mesh_shape(8) == (4, 2)
    assert summa_mesh_shape(16) == (4, 4)
    # the aspect follows the operand imbalance: broadcasting A costs
    # (pc - 1) copies, so an A-heavy instance wants few columns, and a
    # B-heavy one few rows
    rng = np.random.default_rng(4)
    a_heavy = SpGEMMInstance(
        random_structure(40, 30, 0.5, rng), random_structure(30, 8, 0.05, rng)
    )
    b_heavy = SpGEMMInstance(
        random_structure(8, 30, 0.05, rng), random_structure(30, 40, 0.5, rng)
    )
    assert summa_mesh_shape(8, a_heavy) == (8, 1)
    assert summa_mesh_shape(8, b_heavy) == (1, 8)
    # and in general it is the argmin of the closed form over factorizations
    for inst in (a_heavy, b_heavy, _inst(5)):
        for p in (4, 6, 8):
            pr, pc = summa_mesh_shape(p, inst)
            assert pr * pc == p
            best = min(summa_words_ideal(inst, r, c) for r, c in _factorizations(p))
            assert summa_words_ideal(inst, pr, pc) == best


def test_plan_store_round_trip(tmp_path):
    """The crash-safe plan store must rebuild a SummaPlan byte-for-byte —
    sessions persist whatever model they planned, baseline included."""
    from repro.checkpoint.store import restore_plan, save_plan

    plan = build_summa_plan(_inst(6), 4)
    save_plan(str(tmp_path), "summa_rt", plan, meta={"model": "summa2d"})
    restored = restore_plan(str(tmp_path), "summa_rt").plan
    assert type(restored) is SummaPlan
    assert restored.stats == plan.stats
    assert measured_route_words(restored) == measured_route_words(plan)
    for name, route in plan.routes.items():
        np.testing.assert_array_equal(restored.routes[name].send_idx, route.send_idx)
        np.testing.assert_array_equal(restored.routes[name].recv_key, route.recv_key)
    for name, tab in plan.compute.items():
        np.testing.assert_array_equal(restored.compute[name], tab)


# ---------------------------------------------------------------------------
# executor oracle
# ---------------------------------------------------------------------------
def test_front_door_oracle_p1_and_zero_retrace():
    """p=1 runs in-process (a 1-device mesh exercises the full packed
    program): dense-oracle match plus zero retraces across 10 value-only
    calls on the one AOT executable."""
    import jax

    import repro
    from repro.distributed import runtime

    inst = _inst(7, shape=(22, 18, 20), density=(0.25, 0.25))
    rng = np.random.default_rng(7)
    a = np.zeros(inst.a.shape, np.float32)
    b = np.zeros(inst.b.shape, np.float32)
    a[inst.a.coo()] = rng.standard_normal(inst.a.nnz).astype(np.float32)
    b[inst.b.coo()] = rng.standard_normal(inst.b.nnz).astype(np.float32)
    handle = repro.plan(inst, p=1, model="summa2d")
    exe = handle.compile()
    av, bv = a[inst.a.coo()], b[inst.b.coo()]
    got = exe(av, bv)
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)
    packed = exe.pack(av, bv)
    n0 = runtime.trace_count()
    for _ in range(10):
        out = exe.runtime(*packed)
    jax.block_until_ready(out)
    assert runtime.trace_count() == n0, "summa executor retraced on value-only calls"


@pytest.mark.parametrize("devices", [4, 8])
def test_summa_executes_multidev(devices):
    """Oracle + measured == closed-form + every (pr, pc) factorization of p
    on forced host devices (the flattened all_to_all is mesh-shape
    independent — see case_summa)."""
    assert f"OK summa p={devices}" in _run("summa", devices=devices)
