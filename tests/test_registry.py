"""The declarative ModelSpec registry: completeness, consistency with the
core model list, and the repro.api round-trip oracle.

Completeness is the load-bearing property: every name in ``MODELS`` must
either carry a *full* executable spec (lowerer + runner + unpacker + mesh)
or be *explicitly* marked volume-only — a half-wired entry (e.g. a lowerer
without an executor) is exactly the kind of drift the old three-site
dispatch allowed, and is an error here.

The p=1 oracle runs in-process (a 1-device mesh exercises the full packed
program); p in {4, 8} goes through the subprocess runner so forced host
devices never leak into this pytest process' jax.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.spgemm_models import MODELS, SpGEMMInstance
from repro.distributed.registry import (
    MODEL_SPECS,
    VOLUME_ONLY,
    executable_models,
    get_spec,
)
from repro.sparse.structure import random_structure

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUNNER = os.path.join(ROOT, "tests", "multidev_runner.py")


def _run(case: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DEVICES"] = str(devices)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, RUNNER, case],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# completeness / consistency
# ---------------------------------------------------------------------------
def test_registry_covers_every_model_and_the_summa_baseline():
    assert set(MODELS) <= set(MODEL_SPECS)
    assert set(MODEL_SPECS) - set(MODELS) == {"summa2d"}
    # the oblivious baseline is executable but never enters model="auto"
    summa = MODEL_SPECS["summa2d"]
    assert summa.executable and not summa.in_auto and summa.build is None
    assert all(MODEL_SPECS[m].in_auto for m in MODELS)


@pytest.mark.parametrize("model", MODELS)
def test_every_model_fully_executable(model):
    """No half-wired and no volume-only entries remain: every paper model
    carries lowerer, runner, unpacker and mesh geometry as a package."""
    spec = get_spec(model)
    assert spec.name == model
    assert spec.family in ("1D", "2D", "3D")
    assert callable(spec.build)
    assert spec.executable, f"{model}: silently volume-only"
    parts = (spec.lower, spec.make_runner, spec.unpack)
    assert all(callable(f) for f in parts), f"{model}: partial spec"
    assert callable(spec.mesh_shape) and spec.axis_names
    assert spec.measured in ("exact", "useful")
    assert model not in VOLUME_ONLY
    assert VOLUME_ONLY == ()


def test_executable_models_matches_select_surface():
    from repro.distributed.select import EXECUTABLE

    assert executable_models() == EXECUTABLE
    assert executable_models() == MODELS  # all seven, in MODELS order


def test_mesh_shapes_multiply_to_p():
    for p in (1, 2, 3, 4, 8):
        for model in (*MODELS, "summa2d"):
            spec = get_spec(model)
            if not spec.executable:
                continue
            shape = spec.mesh_shape(p)
            assert len(shape) == len(spec.axis_names), (model, p)
            assert int(np.prod(shape)) == p, (model, p, shape)


def test_get_spec_rejects_unknown_model():
    with pytest.raises(ValueError, match="unknown model"):
        get_spec("colwise")


# ---------------------------------------------------------------------------
# api round-trip oracle
# ---------------------------------------------------------------------------
def _valued(struct, rng):
    dense = np.zeros(struct.shape, dtype=np.float32)
    r, c = struct.coo()
    dense[r, c] = rng.standard_normal(len(r)).astype(np.float32)
    return dense


@pytest.mark.parametrize("model", executable_models())
def test_api_round_trip_matches_oracle_p1(model):
    """repro.plan(...).compile()(a_vals, b_vals) == dense oracle, with 1-D
    canonical value vectors for EVERY model (no block/mesh special-casing)."""
    import repro

    rng = np.random.default_rng(3)
    a_s = random_structure(18, 15, 0.25, rng)
    b_s = random_structure(15, 17, 0.25, rng)
    a = _valued(a_s, rng)
    b = _valued(b_s, rng)
    handle = repro.plan(a_s, b_s, p=1, model=model)
    got = handle.compile()(a[a_s.coo()], b[b_s.coo()])
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("devices", [4, 8])
def test_api_round_trip_matches_oracle_multidev(devices):
    """All executable models + model="auto" through the front door at
    p in {4, 8} (subprocess: forced host devices)."""
    assert f"OK api p={devices}" in _run("api", devices=devices)


def test_api_monoC_executes_at_odd_p():
    """The registry's (1, p) monoC mesh fallback replaces the old
    caller-side odd-p skip."""
    assert "OK api_odd_p p=3" in _run("api_odd_p", devices=4)


def test_plan_auto_selects_min_predicted_words():
    import repro

    rng = np.random.default_rng(5)
    a_s = random_structure(26, 22, 0.15, rng)
    b_s = random_structure(22, 24, 0.15, rng)
    handle = repro.plan(a_s, b_s, p=4, model="auto")
    assert handle.model in executable_models()
    assert handle.selection is not None
    assert {r["model"] for r in handle.selection} == set(executable_models())
    best = min(handle.selection, key=lambda r: r["predicted_words"])
    assert best["model"] == handle.model and best["selected"]


def test_cost_report_planned_equals_predicted_for_every_model():
    """The front door exposes the paper's predicted == planned identity:
    exact for replicated-free plans, via item weighting for rowwise, via
    fold accounting for outer, and through the volume plan for the
    volume-only models."""
    import repro

    rng = np.random.default_rng(6)
    a_s = random_structure(24, 20, 0.18, rng)
    b_s = random_structure(20, 22, 0.18, rng)
    for model in MODELS:
        report = repro.plan(a_s, b_s, p=4, model=model).cost_report()
        assert report["planned_words"] == report["predicted_words"], report


def test_plan_accepts_instance_for_reuse():
    """One symbolic inspection, many plans: repro.plan(inst, ...) reuses the
    instance instead of re-deriving S_C and the multiplication space."""
    import repro

    rng = np.random.default_rng(8)
    inst = SpGEMMInstance(
        random_structure(16, 14, 0.25, rng), random_structure(14, 15, 0.25, rng)
    )
    handle = repro.plan(inst, p=2, model="fine")
    assert handle.instance is inst
    with pytest.raises(ValueError, match="B must be omitted"):
        repro.plan(inst, inst.b, p=2, model="fine")
    with pytest.raises(ValueError, match="B is required"):
        repro.plan(inst.a, p=2, model="fine")


def test_plan_include_nz_places_nonzero_vertices():
    """include_nz keeps V^nz: fine lowers such partitions (placements become
    ownership, words still == connectivity); models whose lowerers don't
    understand them stay cost/analysis-only instead of lowering garbage."""
    import repro
    from repro.core import evaluate

    rng = np.random.default_rng(9)
    inst = SpGEMMInstance(
        random_structure(18, 15, 0.2, rng), random_structure(15, 16, 0.2, rng)
    )
    fine = repro.plan(inst, p=3, model="fine", include_nz=True)
    n_nz = inst.a.nnz + inst.b.nnz + inst.c.nnz
    assert fine.hypergraph.n_vertices == inst.n_mult + n_nz
    assert fine.executable
    assert fine.execution_plan.comm_words_ideal == int(
        evaluate(fine.hypergraph, fine.partition.parts, 3).connectivity
    )
    rw = repro.plan(inst, p=3, model="rowwise", include_nz=True)
    assert not rw.executable  # lowerer does not accept include_nz partitions
    assert rw.cost_report()["planned_words"] == rw.cost_report()["predicted_words"]
    with pytest.raises(ValueError, match="include_nz"):
        rw.compile()
    # auto must pick something that can run: fine is the only include_nz
    # lowerer, so it wins regardless of predicted words
    auto = repro.plan(inst, p=3, model="auto", include_nz=True)
    assert auto.model == "fine" and auto.executable


def test_planned_handle_has_identity_semantics():
    """ndarray-bearing fields: the handle must neither define value
    equality (ambiguous-truth ValueError territory) nor lose hashability —
    it is meant to key plan caches."""
    import dataclasses

    import repro

    rng = np.random.default_rng(10)
    inst = SpGEMMInstance(
        random_structure(12, 10, 0.3, rng), random_structure(10, 11, 0.3, rng)
    )
    h1 = repro.plan(inst, p=2, model="fine")
    h2 = dataclasses.replace(h1)
    assert h1 == h1 and h1 != h2  # identity, not field comparison
    assert len({h1, h2}) == 2  # hashable


def test_summa_baseline_is_planned_but_never_auto_selected():
    """The oblivious competitor is always available by name, carries an
    analytic (hypergraph-free) cost report whose planned == predicted, and
    never appears in the model="auto" contest."""
    import repro

    rng = np.random.default_rng(7)
    a_s = random_structure(14, 12, 0.25, rng)
    b_s = random_structure(12, 13, 0.25, rng)
    handle = repro.plan(a_s, b_s, p=2, model="summa2d")
    assert handle.hypergraph is None and handle.partition is None
    assert handle.p == 2  # falls back to the execution plan's p
    report = handle.cost_report()
    assert report["planned_words"] == report["predicted_words"]
    assert report["planned_messages"] >= 0 and "padded_words" in report
    with pytest.raises(ValueError, match="partition-free"):
        handle.costs()
    auto = repro.plan(a_s, b_s, p=2, model="auto")
    assert "summa2d" not in {r["model"] for r in auto.selection}
