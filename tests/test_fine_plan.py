"""Predicted-vs-planned communication: the hypergraph connectivity metric
equals the plan IR's scheduled words, for every model (via the generic
volume plan) and at item granularity for the fine-grained executor plan —
plus a host-side numpy simulation of the full expand-expand-reduce schedule.
No multi-device jax needed (the executor itself is oracle-tested in
``test_distributed_exec.py``)."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.core.spgemm_models import MODELS
from repro.distributed import (
    build_fine_plan,
    build_volume_plan,
    derive_owner_from_pins,
)
from repro.distributed.select import (
    build_executable_plan,
    measured_route_words,
    sweep_instance,
)
from repro.sparse.structure import random_structure


def _instance(seed, i=36, k=30, j=33, density=0.15):
    rng = np.random.default_rng(seed)
    return SpGEMMInstance(
        random_structure(i, k, density, rng), random_structure(k, j, density, rng)
    )


# ---------------------------------------------------------------------------
# every model: volume plan == connectivity metric (independent code paths)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("seed", [0, 1])
def test_volume_plan_matches_connectivity_every_model(model, seed):
    """For any model hypergraph and a random partition, lowering the cut to
    routing tables (transfer enumeration) counts exactly the words the
    connectivity metric predicts (lambda counting)."""
    inst = _instance(seed)
    rng = np.random.default_rng(seed + 100)
    hg = build_model(inst, model)
    p = int(rng.integers(2, 6))
    parts = rng.integers(0, p, hg.n_vertices)
    plan = build_volume_plan(hg, parts, p)
    assert plan.comm_words_ideal == evaluate(hg, parts, p).connectivity


@pytest.mark.parametrize("model", MODELS)
def test_volume_plan_matches_connectivity_partitioned(model):
    """Same identity on an optimized (non-random) partition."""
    inst = _instance(7)
    hg = build_model(inst, model)
    res = partition(hg, 4, eps=0.2, seed=0)
    plan = build_volume_plan(hg, res.parts, 4)
    assert plan.comm_words_ideal == evaluate(hg, res.parts, 4).connectivity


# ---------------------------------------------------------------------------
# fine plan: item-granularity routes realize exactly the connectivity cost
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_fine_plan_words_equal_connectivity(seed):
    inst = _instance(seed)
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 6))
    hg = build_model(inst, "fine")
    parts = rng.integers(0, p, hg.n_vertices)
    plan = build_fine_plan(inst, parts, p)
    assert plan.comm_words_ideal == evaluate(hg, parts, p).connectivity
    for route in plan.routes.values():
        assert route.items_padded >= route.items_ideal
        assert np.array_equal(route.send_idx >= 0, route.recv_key >= 0)


def test_fine_plan_include_nz_partition_becomes_ownership():
    """Partitioning the include_nz fine hypergraph places the nonzero
    vertices too; the plan adopts those placements and its words still equal
    the (include_nz) connectivity cost."""
    inst = _instance(5)
    p = 4
    hg = build_model(inst, "fine", include_nz=True)
    res = partition(hg, p, eps=0.2, seed=0)
    plan = build_fine_plan(inst, res.parts, p)
    M, nA = inst.n_mult, inst.a.nnz
    assert np.array_equal(plan.a_part, res.parts[M : M + nA])
    assert plan.comm_words_ideal == evaluate(hg, res.parts, p).connectivity


def test_derive_owner_from_pins_places_owner_on_a_pin():
    rng = np.random.default_rng(0)
    p, n_items = 5, 30
    item = rng.integers(0, n_items, 120)
    part = rng.integers(0, p, 120)
    owner = derive_owner_from_pins(item, part, n_items, p)
    touched = {i: set(part[item == i]) for i in range(n_items)}
    for i in range(n_items):
        if touched[i]:
            assert owner[i] in touched[i]
        else:
            assert owner[i] == i % p  # round-robin fallback, no traffic


def test_fine_plan_every_produced_slot_owned_or_shipped():
    """Conservation: each partial-C slot a device produces either folds
    locally (the device owns that C nonzero) or ships on the reduce route
    exactly once — nothing is dropped, nothing is double-counted."""
    inst = _instance(6)
    rng = np.random.default_rng(6)
    p = 4
    plan = build_fine_plan(inst, rng.integers(0, p, inst.n_mult), p)
    prod_ids = plan.local_ids["c_prod"]
    prod_owned = plan.compute["prod_to_owned"]
    route = plan.routes["reduce_c"]
    shipped = np.zeros_like(prod_ids)
    s_ids, d_ids, t_ids = np.nonzero(route.send_idx >= 0)
    np.add.at(shipped, (s_ids, route.send_idx[s_ids, d_ids, t_ids]), 1)
    valid = prod_ids >= 0
    assert ((prod_owned >= 0).astype(int) + shipped)[valid].min() == 1
    assert ((prod_owned >= 0).astype(int) + shipped)[valid].max() == 1
    assert (shipped[~valid] == 0).all() and (prod_owned[~valid] == -1).all()
    # arriving items resolve to the destination's owned slot of that C id
    recv_slot = plan.compute["reduce_recv_slot"]
    keys = route.recv_key[s_ids, d_ids, t_ids]
    assert np.array_equal(
        plan.local_ids["c_nz"][d_ids, recv_slot[s_ids, d_ids, t_ids]], keys
    )


def test_fine_plan_host_simulation_reproduces_dense():
    """Simulate expand-expand-reduce with numpy gathers/segment-adds over
    the plan's tables: must reproduce dense A @ B."""
    rng = np.random.default_rng(8)
    inst = _instance(8, i=32, k=26, j=28, density=0.18)
    p = 4
    parts = rng.integers(0, p, inst.n_mult)
    plan = build_fine_plan(inst, parts, p)
    import scipy.sparse as sp

    I, K, J = inst.shape
    a = np.zeros((I, K))
    r, c = inst.a.coo()
    a[r, c] = rng.standard_normal(len(r))
    b = np.zeros((K, J))
    r, c = inst.b.coo()
    b[r, c] = rng.standard_normal(len(r))
    a_vals, b_vals = sp.csr_matrix(a).data, sp.csr_matrix(b).data

    def tables(vals, local_ids, route):
        N_max, T = local_ids.shape[1], route.T
        tabs = np.zeros((p, N_max + p * T + 1))
        dev, slot = np.nonzero(local_ids >= 0)
        tabs[dev, slot] = vals[local_ids[dev, slot]]
        s_ids, d_ids, t_ids = np.nonzero(route.recv_key >= 0)
        tabs[d_ids, N_max + s_ids * T + t_ids] = vals[route.recv_key[s_ids, d_ids, t_ids]]
        return tabs

    a_tabs = tables(a_vals, plan.local_ids["a_nz"], plan.routes["expand_a"])
    b_tabs = tables(b_vals, plan.local_ids["b_nz"], plan.routes["expand_b"])
    pa, pb, pc = (plan.compute[k] for k in ("pair_a", "pair_b", "pair_c"))
    R_max = plan.local_ids["c_prod"].shape[1]
    partial = np.zeros((p, R_max + 1))
    for d in range(p):
        np.add.at(partial[d], pc[d], a_tabs[d][pa[d]] * b_tabs[d][pb[d]])
    c_out = np.zeros((p, plan.n_c_slots))
    route = plan.routes["reduce_c"]
    recv_slot = plan.compute["reduce_recv_slot"]
    s_ids, d_ids, t_ids = np.nonzero(route.send_idx >= 0)
    np.add.at(
        c_out,
        (d_ids, recv_slot[s_ids, d_ids, t_ids]),
        partial[s_ids, route.send_idx[s_ids, d_ids, t_ids]],
    )
    prod_owned = plan.compute["prod_to_owned"]
    dev, slot = np.nonzero(prod_owned >= 0)
    np.add.at(c_out, (dev, prod_owned[dev, slot]), partial[dev, slot])
    out = np.zeros((I, J))
    crow, ccol = inst.c.coo()
    dev, slot = np.nonzero(plan.local_ids["c_nz"] >= 0)
    gids = plan.local_ids["c_nz"][dev, slot]
    out[crow[gids], ccol[gids]] = c_out[dev, slot]
    np.testing.assert_allclose(out, a @ b, rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------------------
# executable plans through the selection pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", ["rowwise", "outer", "monoC", "fine"])
def test_executable_plan_measures_its_models_prediction(model):
    """Pin-derived ownership makes each executable plan's table-counted
    words equal the model's connectivity prediction."""
    inst = _instance(9)
    p = 4
    hg = build_model(inst, model)
    res = partition(hg, p, eps=0.2, seed=1)
    predicted = evaluate(hg, res.parts, p).connectivity
    plan = build_executable_plan(inst, model, res.parts, p)
    if model == "rowwise":
        measured = measured_route_words(plan, {"expand": inst.b.row_counts()})
    else:
        measured = measured_route_words(plan)
    assert measured == predicted


def test_sweep_instance_selects_min_predicted():
    inst = _instance(10)
    recs = sweep_instance(inst, p=4)
    ok = [r for r in recs if r["status"] == "ok"]
    assert {r["model"] for r in ok} == set(MODELS)
    best = min(ok, key=lambda r: r["predicted_words"])
    assert best["selected"] and sum(r["selected"] for r in recs) == 1
    for r in ok:
        assert r["volume_plan_words"] == r["predicted_words"]
