"""Partitioner + communication-cost evaluation tests (Sec. 4, Sec. 6)."""
import numpy as np
import pytest

from repro.core import (
    SpGEMMInstance,
    build_model,
    evaluate,
    partition,
    partition_block,
    partition_random,
    memory_dependent_bound,
    memory_independent_bound,
    sequential_io_estimate,
)
from repro.core.matrices import (
    amg_instances,
    geometric_row_partition,
    lp_instance,
    mcl_instance,
    stencil27,
)
from repro.sparse.structure import random_structure


def _small_instance(seed=0, shape=(40, 30, 35), density=0.1):
    rng = np.random.default_rng(seed)
    a = random_structure(shape[0], shape[1], density, rng)
    b = random_structure(shape[1], shape[2], density, rng)
    return SpGEMMInstance(a, b)


# ---------------------------------------------------------------------------
# comm evaluation invariants
# ---------------------------------------------------------------------------
def test_single_part_no_communication():
    inst = _small_instance()
    hg = build_model(inst, "fine")
    costs = evaluate(hg, np.zeros(hg.n_vertices, dtype=np.int64), p=1)
    assert costs.max_part_cost == 0
    assert costs.connectivity == 0
    assert costs.total_volume == 0


def test_connectivity_le_volume_le_p_times_connectivity():
    inst = _small_instance(1)
    hg = build_model(inst, "fine")
    rng = np.random.default_rng(0)
    parts = rng.integers(0, 4, size=hg.n_vertices)
    c = evaluate(hg, parts, p=4)
    assert c.connectivity <= c.total_volume <= 2 * c.connectivity + c.connectivity * 3
    # per-part costs: max over parts <= total cut cost
    assert c.max_part_cost <= c.per_part.sum()
    assert c.per_part.max() == c.max_part_cost


def test_lemma_4_2_exactness_two_parts():
    """For p=2, each cut net contributes its cost to BOTH parts' |Q_i|."""
    inst = _small_instance(2)
    hg = build_model(inst, "fine")
    rng = np.random.default_rng(1)
    parts = rng.integers(0, 2, size=hg.n_vertices)
    c = evaluate(hg, parts, p=2)
    # with p=2, per_part[0] == per_part[1] == connectivity (all cut nets touch both)
    assert c.per_part[0] == c.per_part[1] == c.connectivity
    assert c.total_volume == 2 * c.connectivity


def test_expand_fold_split_partitions_connectivity():
    inst = _small_instance(3)
    hg = build_model(inst, "fine")
    rng = np.random.default_rng(2)
    parts = rng.integers(0, 3, size=hg.n_vertices)
    c = evaluate(hg, parts, p=3)
    assert c.expand + c.fold == c.connectivity


# ---------------------------------------------------------------------------
# partitioner quality
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", ["fine", "rowwise", "outer", "monoC"])
def test_partitioner_beats_random(model):
    inst = _small_instance(4, shape=(60, 50, 55), density=0.08)
    hg = build_model(inst, model)
    p = 4
    ours = partition(hg, p, eps=0.10, seed=0)
    rand = partition_random(hg, p, seed=0)
    assert ours.connectivity < rand.connectivity
    c = evaluate(hg, ours.parts, p)
    assert c.comp_imbalance < 0.35  # recursive bisection slack


def test_partitioner_respects_balance_eps():
    inst = _small_instance(5, shape=(80, 60, 70), density=0.06)
    hg = build_model(inst, "rowwise")
    res = partition(hg, 2, eps=0.05, seed=1)
    c = evaluate(hg, res.parts, 2)
    assert c.comp_imbalance <= 0.08  # eps + rounding


def test_partition_structured_grid_cut_scales():
    """On a 27-pt stencil rowwise model, a good 2-way cut is O(n^2) nets,
    not O(n^3): the partitioner must find a planar-ish cut."""
    a = stencil27(9)  # 729 rows
    inst = SpGEMMInstance(a, a)
    hg = build_model(inst, "rowwise")
    res = partition(hg, 2, eps=0.05, seed=0)
    rand = partition_random(hg, 2, seed=0)
    assert res.connectivity < rand.connectivity / 2


def test_geometric_partition_matches_grid():
    parts = geometric_row_partition(6, 8)
    assert parts.shape == (216,)
    assert len(np.unique(parts)) == 8
    counts = np.bincount(parts)
    assert counts.max() == counts.min() == 27  # perfect 3^3 subcubes


# ---------------------------------------------------------------------------
# bounds
# ---------------------------------------------------------------------------
def test_classical_bounds_monotone_in_p():
    assert memory_dependent_bound(10**6, 4, 1000) > memory_dependent_bound(
        10**6, 16, 1000
    )
    assert memory_independent_bound(10**6, 10**4, 4) > memory_independent_bound(
        10**6, 10**4, 64
    )


def test_sequential_io_estimate_runs():
    inst = _small_instance(6)
    hg = build_model(inst, "fine", include_nz=True)
    est = sequential_io_estimate(hg, fast_mem=16)
    assert est["h"] >= 1
    assert est["upper_bound"] >= est["lower_bound_proxy"]


def test_diagonal_case_trivial_lower_bound():
    """Paper Sec. 4.2: diagonal x diagonal needs >= |V^nz| words; our greedy
    S-partition with big M should find h == 1 (no refetches)."""
    from repro.sparse import from_dense

    d = np.eye(8)
    inst = SpGEMMInstance(from_dense(d), from_dense(d))
    hg = build_model(inst, "fine", include_nz=True)
    est = sequential_io_estimate(hg, fast_mem=64)
    assert est["h"] == 1
    assert est["lower_bound_proxy"] == 0  # the M(h-1) term vanishes...
    # ...leaving the trivial |V^nz| bound, which is 3*8 here
    assert hg.w_mem.sum() == 24


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------
def test_stencil27_structure():
    a = stencil27(5)
    assert a.shape == (125, 125)
    # interior point has 27 neighbors (incl. itself)
    counts = a.row_counts()
    assert counts.max() == 27
    assert counts.min() == 8  # corner
    # symmetric
    assert a == a.transpose()


def test_amg_instances_shapes():
    ap, ptap = amg_instances(6)
    assert ap.shape == (216, 216, 8)
    assert ptap.shape == (8, 216, 8)
    # Tab. II: PTAP has higher mult-to-output ratio than AP
    assert ptap.stats()["mult_per_C_nnz"] > ap.stats()["mult_per_C_nnz"]


def test_lp_instance_symmetric_output():
    inst = lp_instance("fome21", scale=0.05, seed=0)
    I, K, J = inst.shape
    assert I == J and K > I
    # C = A A^T is structurally symmetric
    assert inst.c == inst.c.transpose()


def test_mcl_instance_square_symmetric():
    inst = mcl_instance("facebook", scale=0.25, seed=0)
    I, K, J = inst.shape
    assert I == K == J
    assert inst.a == inst.a.transpose()
    # scale-free: max degree far above average
    counts = inst.a.row_counts()
    assert counts.max() > 5 * counts.mean()
