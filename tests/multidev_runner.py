"""Subprocess runner for multi-device tests.

Run as:  python tests/multidev_runner.py <case>
Sets XLA host-device-count BEFORE importing jax (must not leak into the main
pytest process, which owns a 1-device jax).  ``REPRO_DEVICES`` overrides the
device count (default 4; the monoC cases run at 4 and 8).
"""
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={N_DEV}"
)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro import compat  # noqa: E402
from repro.compat import shard_map  # noqa: E402
from repro.core import SpGEMMInstance, build_model, partition  # noqa: E402
from repro.distributed import (  # noqa: E402
    build_outer_plan,
    build_rowwise_plan,
    fine_spgemm,
    monoC_spgemm,
    outer_product_spgemm,
    rowwise_spgemm,
    spsumma,
)
from repro.distributed.plan_ir import (  # noqa: E402
    plan_fine_from_dense,
    plan_monoC_from_dense,
)
from repro.distributed.spgemm_exec import (  # noqa: E402
    unpack_fine_result,
    unpack_monoC_result,
    unpack_rowwise_result,
)
from repro.sparse.structure import random_structure  # noqa: E402


def _random_valued(struct, rng):
    dense = np.zeros(struct.shape, dtype=np.float32)
    r, c = struct.coo()
    dense[r, c] = rng.standard_normal(len(r)).astype(np.float32)
    return dense


def case_rowwise():
    rng = np.random.default_rng(0)
    a_s = random_structure(37, 23, 0.15, rng)
    b_s = random_structure(23, 29, 0.2, rng)
    inst = SpGEMMInstance(a_s, b_s)
    hg = build_model(inst, "rowwise")
    res = partition(hg, 4, eps=0.2, seed=0)
    plan = build_rowwise_plan(inst, res.parts, 4)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    mesh = Mesh(np.array(jax.devices()[:4]), ("x",))
    c_local = rowwise_spgemm(a, b, plan, mesh)
    c = unpack_rowwise_result(c_local, plan, 37)
    np.testing.assert_allclose(c, a @ b, rtol=1e-5, atol=1e-5)
    # padded comm never below the combinatorial ideal
    assert plan.comm_words_padded >= plan.comm_words_ideal
    print("OK rowwise ideal=%d padded=%d" % (plan.comm_words_ideal, plan.comm_words_padded))


def case_outer():
    rng = np.random.default_rng(1)
    a_s = random_structure(31, 26, 0.15, rng)
    b_s = random_structure(26, 33, 0.2, rng)
    inst = SpGEMMInstance(a_s, b_s)
    hg = build_model(inst, "outer")
    res = partition(hg, 4, eps=0.2, seed=0)
    plan = build_outer_plan(inst, res.parts, 4)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    mesh = Mesh(np.array(jax.devices()[:4]), ("x",))
    c_shards = np.asarray(outer_product_spgemm(a, b, plan, mesh))
    c = c_shards.reshape(-1, 33)[:31]
    np.testing.assert_allclose(c, a @ b, rtol=1e-5, atol=1e-5)
    print("OK outer ideal_fold=%d" % plan.comm_words_ideal)


def case_spsumma():
    rng = np.random.default_rng(2)
    a_s = random_structure(19, 22, 0.3, rng)
    b_s = random_structure(22, 17, 0.3, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("x", "y"))
    c = np.asarray(spsumma(a, b, mesh))
    np.testing.assert_allclose(c, a @ b, rtol=1e-5, atol=1e-5)
    print("OK spsumma")


def case_rowwise_identity_partition():
    """All rows on one device: zero expand traffic to that device's rows."""
    rng = np.random.default_rng(3)
    a_s = random_structure(16, 12, 0.25, rng)
    b_s = random_structure(12, 14, 0.25, rng)
    inst = SpGEMMInstance(a_s, b_s)
    parts = np.zeros(16, dtype=np.int64)
    plan = build_rowwise_plan(inst, parts, 4, b_part=np.zeros(12, dtype=np.int64))
    assert plan.comm_words_ideal == 0
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    mesh = Mesh(np.array(jax.devices()[:4]), ("x",))
    c_local = rowwise_spgemm(a, b, plan, mesh)
    c = unpack_rowwise_result(c_local, plan, 16)
    np.testing.assert_allclose(c, a @ b, rtol=1e-5, atol=1e-5)
    print("OK rowwise_identity")


def _monoC_oracle(seed: int, shape: tuple[int, int, int], block: int, density: float):
    """Build a monoC plan on the block structure, execute on a 2D mesh over
    all devices, check vs dense A @ B, and check the IR's route accounting."""
    p = N_DEV
    rng = np.random.default_rng(seed)
    I, K, J = shape
    a_s = random_structure(I, K, density, rng)
    b_s = random_structure(K, J, density, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    plan, inst = plan_monoC_from_dense(a, b, block, p, seed=seed)
    pr = 2
    pc = p // pr
    mesh = Mesh(np.array(jax.devices()).reshape(pr, pc), ("x", "y"))
    c_local = monoC_spgemm(a, b, plan, mesh, block=block)
    gr, gc = inst.c.shape
    c = unpack_monoC_result(c_local, plan, inst.c, (gr * block, gc * block))[:I, :J]
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    assert plan.comm_words_padded >= plan.comm_words_ideal
    for route in plan.routes.values():
        assert route.items_padded >= route.items_ideal
    return plan


def case_monoC():
    plan = _monoC_oracle(0, (36, 28, 32), block=4, density=0.18)
    print(
        "OK monoC p=%d ideal=%d padded=%d"
        % (N_DEV, plan.comm_words_ideal, plan.comm_words_padded)
    )


def case_monoC_blocked():
    plan = _monoC_oracle(1, (48, 40, 32), block=8, density=0.22)
    print(
        "OK monoC_blocked p=%d ideal=%d padded=%d"
        % (N_DEV, plan.comm_words_ideal, plan.comm_words_padded)
    )


def case_monoC_identity_partition():
    """All C blocks (and A/B nonzeros) on device 0: zero expand traffic."""
    rng = np.random.default_rng(2)
    a_s = random_structure(16, 12, 0.3, rng)
    b_s = random_structure(12, 16, 0.3, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    from repro.distributed import build_monoC_plan
    from repro.sparse.bsr import to_bsr

    block = 4
    ab = to_bsr(a, block, block)
    bb = to_bsr(b, block, block)
    inst = SpGEMMInstance(ab.block_structure(), bb.block_structure())
    plan = build_monoC_plan(
        inst,
        np.zeros(inst.c.nnz, dtype=np.int64),
        N_DEV,
        a_part=np.zeros(inst.a.nnz, dtype=np.int64),
        b_part=np.zeros(inst.b.nnz, dtype=np.int64),
        word_size=block * block,
    )
    assert plan.comm_words_ideal == 0
    pr = 2
    mesh = Mesh(np.array(jax.devices()).reshape(pr, N_DEV // pr), ("x", "y"))
    c_local = monoC_spgemm(a, b, plan, mesh, block=block)
    gr, gc = inst.c.shape
    c = unpack_monoC_result(c_local, plan, inst.c, (gr * block, gc * block))[:16, :16]
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    print("OK monoC_identity")


def _fine_oracle(seed: int, shape: tuple[int, int, int], density: float, include_nz=False):
    """Build a fine-grained plan, execute expand-expand-reduce on a 1D mesh
    over all devices, check vs dense A @ B, and check that the planned words
    equal the fine hypergraph's connectivity cost (predicted == planned)."""
    p = N_DEV
    rng = np.random.default_rng(seed)
    I, K, J = shape
    a_s = random_structure(I, K, density, rng)
    b_s = random_structure(K, J, density, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    plan, inst = plan_fine_from_dense(a, b, p, seed=seed, include_nz=include_nz)
    from repro.core import evaluate

    hg = build_model(inst, "fine", include_nz=include_nz)
    res = partition(hg, p, eps=0.10, seed=seed)
    # same partitioner invocation as the pipeline: predictions must line up
    predicted = evaluate(hg, res.parts, p).connectivity
    assert plan.comm_words_ideal == predicted, (plan.comm_words_ideal, predicted)
    mesh = Mesh(np.array(jax.devices()), ("x",))
    c_local = fine_spgemm(a, b, plan, mesh)
    c = unpack_fine_result(c_local, plan, inst.c, (I, J))
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    assert plan.comm_words_padded >= plan.comm_words_ideal
    for route in plan.routes.values():
        assert route.items_padded >= route.items_ideal
    return plan


def case_fine():
    plan = _fine_oracle(0, (36, 28, 32), density=0.15)
    print(
        "OK fine p=%d ideal=%d padded=%d"
        % (N_DEV, plan.comm_words_ideal, plan.comm_words_padded)
    )


def case_fine_nz():
    plan = _fine_oracle(1, (30, 26, 24), density=0.18, include_nz=True)
    print(
        "OK fine_nz p=%d ideal=%d padded=%d"
        % (N_DEV, plan.comm_words_ideal, plan.comm_words_padded)
    )


def case_fine_identity_partition():
    """All multiplications and nonzeros on device 0: zero traffic on all
    three routes, result still correct."""
    rng = np.random.default_rng(2)
    a_s = random_structure(16, 12, 0.3, rng)
    b_s = random_structure(12, 16, 0.3, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    from repro.distributed import build_fine_plan

    inst = SpGEMMInstance(a_s, b_s)
    zeros = np.zeros(inst.n_mult, dtype=np.int64)
    plan = build_fine_plan(
        inst,
        zeros,
        N_DEV,
        a_part=np.zeros(inst.a.nnz, dtype=np.int64),
        b_part=np.zeros(inst.b.nnz, dtype=np.int64),
        c_part=np.zeros(inst.c.nnz, dtype=np.int64),
    )
    assert plan.comm_words_ideal == 0
    mesh = Mesh(np.array(jax.devices()), ("x",))
    c_local = fine_spgemm(a, b, plan, mesh)
    c = unpack_fine_result(c_local, plan, inst.c, (16, 16))
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    print("OK fine_identity")


def case_select():
    """End-to-end model selection: sweep every model on a small instance,
    execute the plans that have executors, measured == predicted for the
    replicated-free (fine, monoC) plans."""
    from repro.distributed.select import sweep_instance

    rng = np.random.default_rng(4)
    a_s = random_structure(32, 24, 0.15, rng)
    b_s = random_structure(24, 28, 0.18, rng)
    inst = SpGEMMInstance(a_s, b_s, name="select_case")
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    recs = sweep_instance(inst, p=N_DEV, a_dense=a, b_dense=b, execute=True)
    by_model = {r["model"]: r for r in recs}
    for model in ("fine", "monoC"):
        r = by_model[model]
        assert r["measured_words"] == r["predicted_words"], (model, r)
        assert r.get("exec_max_err", 1.0) < 1e-4, (model, r)
    assert by_model["rowwise"].get("exec_max_err", 1.0) < 1e-4
    best = min(by_model.values(), key=lambda r: r["predicted_words"])
    print("OK select best=%s predicted=%d" % (best["model"], best["predicted_words"]))


def case_runtime():
    """Compile-once runtime: every registry executor AOT-compiled once, value-only
    updates match the dense oracle, zero retraces across >= 10 same-structure
    calls, donation never corrupts caller-held numpy buffers, and the LRU
    returns the identical executable on a same-key lookup."""
    from repro.distributed import runtime
    from repro.distributed.runtime import compile_spgemm
    from repro.distributed.select import build_executable_plan

    p = N_DEV
    rng = np.random.default_rng(7)
    a_s = random_structure(36, 30, 0.15, rng)
    b_s = random_structure(30, 32, 0.18, rng)
    inst = SpGEMMInstance(a_s, b_s, name="runtime_case")
    a1, b1 = _random_valued(a_s, rng), _random_valued(b_s, rng)
    a2, b2 = _random_valued(a_s, rng), _random_valued(b_s, rng)
    ar, ac = a_s.coo()
    br, bc = b_s.coo()

    def vals(a_dense, b_dense, model):
        av, bv = a_dense[ar, ac], b_dense[br, bc]
        if model == "monoC":  # scalar instance == 1x1 blocks
            av, bv = av.reshape(-1, 1, 1), bv.reshape(-1, 1, 1)
        return av, bv

    fine_exe = None
    for model in ("rowwise", "columnwise", "outer", "monoA", "monoB", "monoC", "fine"):
        hg = build_model(inst, model)
        res = partition(hg, p, eps=0.2, seed=0)
        plan = build_executable_plan(inst, model, res.parts, p)
        if model == "monoC":
            mesh = Mesh(np.array(jax.devices()[:p]).reshape(2, p // 2), ("x", "y"))
            exe = compile_spgemm(
                plan, inst.a, inst.b, mesh, block=1, backend="xla", c_structure=inst.c
            )
        else:
            mesh = Mesh(np.array(jax.devices()[:p]), ("x",))
            exe = compile_spgemm(plan, inst.a, inst.b, mesh, c_structure=inst.c)
        # value-only updates: two value sets on the one compiled structure
        for a_d, b_d in ((a1, b1), (a2, b2)):
            got = exe.unpack(exe(*vals(a_d, b_d, model)))[:36, :32]
            np.testing.assert_allclose(got, a_d @ b_d, rtol=1e-4, atol=1e-4)
        # cache hit returns the identical executable object
        assert (
            compile_spgemm(
                plan, inst.a, inst.b, mesh,
                **(dict(block=1, backend="xla") if model == "monoC" else {}),
            )
            is exe
        ), model
        if model == "fine":
            fine_exe = exe

    # zero retraces across >= 10 same-structure calls
    av, bv = vals(a1, b1, "fine")
    n0 = runtime.trace_count()
    for _ in range(10):
        out = fine_exe(av, bv)
    jax.block_until_ready(out)
    assert runtime.trace_count() == n0, (runtime.trace_count(), n0)

    # donation doesn't corrupt reuse: numpy inputs survive repeated calls
    av_copy, bv_copy = av.copy(), bv.copy()
    r1 = np.asarray(fine_exe(av, bv))
    r2 = np.asarray(fine_exe(av, bv))
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(av, av_copy)
    np.testing.assert_array_equal(bv, bv_copy)

    # mismatched-structure values raise
    try:
        fine_exe(av[:-1], bv)
    except ValueError:
        pass
    else:
        raise AssertionError("short A values did not raise")

    info = runtime.cache_info()
    assert info["hits"] >= 4, info
    print("OK runtime p=%d traces=%d" % (p, runtime.trace_count()))


def case_api():
    """The repro.api front door: one call from structures to dense C for
    every executable model — no caller-visible mesh/dtype/model
    special-casing — plus model="auto" selection and the cost report's
    predicted == planned identity for the replicated-free models."""
    import repro

    p = N_DEV
    rng = np.random.default_rng(11)
    a_s = random_structure(34, 27, 0.15, rng)
    b_s = random_structure(27, 31, 0.18, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    want = a @ b
    a_vals = a[a_s.coo()]
    b_vals = b[b_s.coo()]
    for model in repro.executable_models():
        handle = repro.plan(a_s, b_s, p=p, model=model)
        got = handle(a_vals, b_vals)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4, err_msg=model)
        report = handle.cost_report()
        if handle.spec.measured == "exact":
            assert report["planned_words"] == report["predicted_words"], report
    auto = repro.plan(a_s, b_s, p=p, model="auto")
    assert auto.model in repro.executable_models()
    assert sum(r["selected"] for r in auto.selection) == 1
    assert min(r["predicted_words"] for r in auto.selection) == (
        auto.cost_report()["predicted_words"]
    )
    np.testing.assert_allclose(auto(a_vals, b_vals), want, rtol=1e-4, atol=1e-4)
    print("OK api p=%d auto=%s" % (p, auto.model))


def case_summa():
    """Sparse SUMMA baseline at p=N_DEV: the oblivious executor matches the
    dense oracle through the front door, its route tables ship exactly the
    closed-form nnz(A)(pc-1) + nnz(B)(pr-1) words, and the SAME plan executes
    correctly when the caller forces non-square (pr, pc) factorizations —
    the flattened all_to_all is independent of the physical mesh shape."""
    import repro
    from repro.distributed.plan_ir import measured_route_words
    from repro.distributed.summa import build_summa_plan, summa_words_ideal

    p = N_DEV
    rng = np.random.default_rng(13)
    a_s = random_structure(33, 26, 0.18, rng)
    b_s = random_structure(26, 29, 0.2, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    want = a @ b
    handle = repro.plan(a_s, b_s, p=p, model="summa2d")
    plan = handle.execution_plan
    assert measured_route_words(plan) == plan.stats["words_analytic"]
    assert plan.stats["words_analytic"] == summa_words_ideal(
        handle.instance, plan.pr, plan.pc
    )
    got = handle(a[a_s.coo()], b[b_s.coo()])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    # every factorization of p, including the degenerate 1D ones
    inst = handle.instance
    for pr in range(1, p + 1):
        if p % pr:
            continue
        pc = p // pr
        forced = build_summa_plan(inst, p, pr=pr, pc=pc)
        assert forced.stats["words_analytic"] == summa_words_ideal(inst, pr, pc)
        h2 = repro.PlannedSpGEMM(
            instance=inst, model="summa2d", hypergraph=None, partition=None,
            execution_plan=forced,
        )
        got2 = h2(a[a_s.coo()], b[b_s.coo()])
        np.testing.assert_allclose(
            got2, want, rtol=1e-4, atol=1e-4, err_msg=f"pr={pr} pc={pc}"
        )
    print(
        "OK summa p=%d mesh=(%d,%d) words=%d"
        % (p, plan.pr, plan.pc, plan.stats["words_analytic"])
    )


def case_api_odd_p():
    """monoC through the front door at an ODD p: the registry's (1, p) mesh
    fallback replaces the old caller-side 'odd p skipped' quirk."""
    import repro

    p = 3
    assert N_DEV >= p
    rng = np.random.default_rng(12)
    a_s = random_structure(20, 16, 0.2, rng)
    b_s = random_structure(16, 18, 0.2, rng)
    a = _random_valued(a_s, rng)
    b = _random_valued(b_s, rng)
    handle = repro.plan(a_s, b_s, p=p, model="monoC")
    devices = jax.devices()[:p]
    got = handle.compile(devices=devices)(a[a_s.coo()], b[b_s.coo()])
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)
    print("OK api_odd_p p=%d" % p)


def case_compressed_psum():
    """EF-int8 compressed all-reduce: approximates the exact mean within the
    quantization scale, and error feedback drives the running average of the
    compressed stream toward the exact mean."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.training.compression import compressed_psum_mean

    mesh = Mesh(np.array(jax.devices()[:4]), ("x",))
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((4, 64, 32)).astype(np.float32)
    exact = xs.mean(axis=0)

    def body(x, err):
        return compressed_psum_mean(x[0], err[0], "x")

    fn = jax.jit(
        shard_map(
            lambda x, e: tuple(o[None] for o in body(x, e)),
            mesh=mesh,
            in_specs=(P("x"), P("x")),
            out_specs=(P("x"), P("x")),
        )
    )
    err = np.zeros_like(xs)
    means = []
    for _ in range(8):
        mean, err = fn(jnp.asarray(xs), jnp.asarray(err))
        means.append(np.asarray(mean[0]))
        err = np.asarray(err)
    # single-shot error bounded by the max quantization scale
    scale = np.abs(xs).max() / 127.0
    assert np.abs(means[0] - exact).max() <= 4 * scale
    # error feedback: the running average converges below one-shot error
    avg = np.mean(means, axis=0)
    assert np.abs(avg - exact).max() < np.abs(means[0] - exact).max() + 1e-7
    # wire format really is int8-sized: compression ratio 2x vs bf16
    from repro.training.compression import compression_ratio
    assert compression_ratio() == 2.0
    print("OK compressed_psum")


def case_moe_ep():
    """Expert-parallel shard_map MoE must match the single-device fallback
    numerically (same routing, same capacity semantics)."""
    import dataclasses
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_smoke_config
    from repro.models import init_params, train_loss

    cfg = get_smoke_config("dbrx-132b")
    # capacity factor high enough that no token is ever dropped: the two
    # dispatch paths then compute identical math (drop ORDER differs between
    # global-capacity fallback and per-shard-capacity EP, by design)
    cfg = dataclasses.replace(
        cfg,
        dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
    )
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    # fallback: no mesh context
    loss_ref, _ = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)

    # EP path: mesh with model axis 2 (4 experts / 2 columns), data axis 2
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    compat.set_mesh(mesh)
    try:
        from repro.models.sharding import param_shardings, batch_sharding
        psh = param_shardings(cfg, mesh)
        bsh = {k: batch_sharding(mesh, v.shape[0], v.ndim) for k, v in batch.items()}
        loss_ep, _ = jax.jit(
            lambda p, b: train_loss(p, cfg, b),
            in_shardings=(psh, bsh),
        )(jax.device_put(params, psh), {k: jax.device_put(v, bsh[k]) for k, v in batch.items()})
    finally:
        pass
    assert abs(float(loss_ref) - float(loss_ep)) < 2e-4, (loss_ref, loss_ep)
    print("OK moe_ep", float(loss_ref), float(loss_ep))


def case_session():
    """Resilient session at p=N_DEV: an MCL-style drift loop with faults
    scripted at four stage boundaries (every product checked against numpy),
    then kill-and-restore — a fresh session rebuilds its pool from the plan
    store with zero retraces."""
    import shutil
    import tempfile

    import repro
    from repro.distributed import runtime
    from repro.resilience import FaultPolicy
    from repro.testing import faults

    p = N_DEV
    policy = FaultPolicy(backoff_s=0.0)
    store = tempfile.mkdtemp(prefix="repro_session_store_")
    try:
        rng = np.random.default_rng(5)
        n = 48
        M = (rng.random((n, n)) * (rng.random((n, n)) < 0.2)).astype(np.float32)
        M[np.arange(n), np.arange(n)] = 1.0
        s = repro.session(p=p, model="rowwise", policy=policy, store_dir=store)
        hist = []
        schedule = {"partition": [1], "compile": [1], "execute": [2], "store_save": [0]}
        with faults.scripted(schedule) as scripts:
            for _ in range(4):
                C = np.asarray(s.multiply(M, M))
                np.testing.assert_allclose(C, M @ M, rtol=2e-4, atol=2e-4)
                hist.append(M)
                # prune + renormalize: the structure drifts for the next round
                C[C < np.quantile(C[C > 0], 0.3)] = 0.0
                col = C.sum(axis=0)
                M = (C / np.where(col > 0, col, 1.0)).astype(np.float32)
                M[np.arange(n), np.arange(n)] += 0.5
        for stage, script in scripts.items():
            assert script.fired == len(schedule[stage]), (stage, script.seen)
        kinds = [e.kind for e in s.events]
        assert kinds.count("cold_replan") + kinds.count("warm_replan") == 4, kinds
        assert kinds.count("warm_replan") >= 1, kinds

        # the crash: a fresh session restores every entry from the store
        del s
        s2 = repro.session(p=p, model="rowwise", policy=policy, store_dir=store)
        before = runtime.trace_count()
        for M_old in hist:
            C = np.asarray(s2.multiply(M_old, M_old))
            np.testing.assert_allclose(C, M_old @ M_old, rtol=2e-4, atol=2e-4)
        assert runtime.trace_count() == before, "restored plans must not retrace"
        kinds2 = [e.kind for e in s2.events]
        assert kinds2.count("restored") == len(hist), kinds2
        assert "cold_replan" not in kinds2 and "warm_replan" not in kinds2
        print(
            "OK session p=%d warm=%d restored=%d"
            % (p, kinds.count("warm_replan"), len(hist))
        )
    finally:
        shutil.rmtree(store, ignore_errors=True)


def case_serve():
    """Serving tier at p=N_DEV: batched executors for all four executable
    models match the per-call path and the dense oracle; ragged batch sizes
    inside one capacity bucket share a single AOT executable with zero
    retraces; repeated batched calls reusing the same numpy value buffers are
    donation-safe; and the serving loop drains a mixed window batched."""
    import repro
    from repro.distributed import runtime
    from repro.distributed.runtime import batch_bucket
    from repro.launch.serve import SpGEMMServer

    p = N_DEV
    rng = np.random.default_rng(9)
    a_s = random_structure(34, 28, 0.15, rng)
    b_s = random_structure(28, 30, 0.18, rng)
    a_stack = lambda m: rng.standard_normal((m, a_s.nnz)).astype(np.float32)  # noqa: E731
    b_stack = lambda m: rng.standard_normal((m, b_s.nnz)).astype(np.float32)  # noqa: E731

    def dense(s, vals):
        d = np.zeros(s.shape, np.float32)
        d[s.coo()] = vals
        return d

    for model in repro.executable_models():
        planned = repro.plan(a_s, b_s, p=p, model=model)
        exe_one = planned.compile()
        exe_batch = planned.compile(batch=4)
        av, bv = a_stack(4), b_stack(4)
        got = exe_batch(av, bv)
        assert got.shape == (4, 34, 30), (model, got.shape)
        for i in range(4):
            want = dense(a_s, av[i]) @ dense(b_s, bv[i])
            np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4, err_msg=model)
            np.testing.assert_allclose(
                exe_one(av[i], bv[i]), want, rtol=1e-4, atol=1e-4, err_msg=model
            )

    # ragged batches in one bucket: m in {3, 4} -> capacity-4 executable,
    # zero retraces after the first batched call compiled the bucket
    planned = repro.plan(a_s, b_s, p=p, model="fine")
    exe4 = planned.compile(batch=3)
    assert exe4.batch_capacity == batch_bucket(3) == 4
    exe4(a_stack(2), b_stack(2))  # bucket warm
    n0 = runtime.trace_count()
    for m in (1, 2, 3, 4):
        got = exe4(a_stack(m), b_stack(m))
        assert got.shape[0] == m, (m, got.shape)
    assert runtime.trace_count() == n0, "ragged batches inside one bucket retraced"
    # the handle wrapper is fresh per compile(); the AOT executable is shared
    assert planned.compile(batch=4).runtime is exe4.runtime, (
        "same bucket must hit the runtime LRU"
    )

    # donation safety: the same numpy buffers survive repeated batched calls
    av, bv = a_stack(4), b_stack(4)
    av_copy, bv_copy = av.copy(), bv.copy()
    r1 = np.asarray(exe4(av, bv))
    r2 = np.asarray(exe4(av, bv))
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(av, av_copy)
    np.testing.assert_array_equal(bv, bv_copy)

    # the loop end-to-end at this p: one window of same-structure traffic
    # rides batched dispatches and every result matches the oracle
    server = SpGEMMServer(p=p, model="fine", max_batch=4, batch_window=8)
    reqs = [
        server.submit((a_s, a_stack(1)[0]), (b_s, b_stack(1)[0])) for _ in range(6)
    ]
    server.drain()
    assert server.stats.completed == 6, server.stats
    assert server.stats.dispatches == 2, server.stats  # 6 reqs / max_batch 4
    for r in reqs:
        want = dense(a_s, r.a_vals) @ dense(b_s, r.b_vals)
        np.testing.assert_allclose(r.result, want, rtol=1e-4, atol=1e-4)
    print("OK serve p=%d traces=%d" % (p, runtime.trace_count()))


if __name__ == "__main__":
    assert len(jax.devices()) == N_DEV, jax.devices()
    for name in sys.argv[1:] or [
        "rowwise",
        "outer",
        "spsumma",
        "rowwise_identity_partition",
    ]:
        globals()[f"case_{name}"]()
    print("ALL OK")
