"""Public-surface snapshot: the documented front door cannot rot silently.

Pins ``repro.__all__``, the signature of ``repro.plan``, the demotion of the
loop-reference builder from ``repro.distributed.__all__`` (with its
deprecation shim), and the lazy-import property (``import repro`` must not
drag jax in — planning is a numpy/scipy affair).
"""
import inspect
import subprocess
import sys
import warnings

import pytest

import repro


def test_top_level_all_is_pinned():
    assert repro.__all__ == [
        "MODELS",
        "MODEL_SPECS",
        "CompiledSpGEMM",
        "FaultPolicy",
        "ModelSpec",
        "PlannedSpGEMM",
        "SpGEMMInstance",
        "SpGEMMSession",
        "device_count",
        "executable_models",
        "plan",
        "session",
    ]


def test_every_exported_name_resolves():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    assert set(repro.__all__) <= set(dir(repro))


def test_plan_signature_is_pinned():
    sig = inspect.signature(repro.plan)
    assert list(sig.parameters) == [
        "A", "B", "p", "model", "eps", "seed", "name", "include_nz", "engine",
        "coarsen",
    ]
    defaults = {
        k: v.default
        for k, v in sig.parameters.items()
        if v.default is not inspect.Parameter.empty
    }
    assert defaults == {
        "B": None,
        "p": 8,
        "model": "auto",
        "eps": 0.10,
        "seed": 0,
        "name": "",
        "include_nz": False,
        "engine": "flat",
        "coarsen": "auto",
    }


def test_session_signature_is_pinned():
    sig = inspect.signature(repro.session)
    assert list(sig.parameters) == [
        "p", "model", "eps", "seed", "engine", "store_dir", "policy", "kwargs",
    ]
    defaults = {
        k: v.default
        for k, v in sig.parameters.items()
        if v.default is not inspect.Parameter.empty
    }
    assert defaults == {
        "p": 8,
        "model": "auto",
        "eps": 0.10,
        "seed": 0,
        "engine": "flat",
        "store_dir": None,
        "policy": None,
    }
    for attr in ("multiply", "stats", "__call__"):
        assert callable(getattr(repro.SpGEMMSession, attr)), attr


def test_planned_handle_surface_is_pinned():
    for attr in ("cost_report", "compile", "execute", "costs"):
        assert callable(getattr(repro.PlannedSpGEMM, attr)), attr
    assert repro.PlannedSpGEMM.__call__ is repro.PlannedSpGEMM.execute
    for attr in ("pack", "__call__"):
        assert callable(getattr(repro.CompiledSpGEMM, attr)), attr


def test_registry_is_the_executable_source_of_truth():
    # the seven paper models plus the oblivious SUMMA baseline (by name only;
    # never part of model="auto")
    assert tuple(repro.MODEL_SPECS) == (*repro.MODELS, "summa2d")
    assert repro.executable_models() == repro.MODELS
    assert repro.executable_models() == (
        "fine", "rowwise", "columnwise", "outer", "monoA", "monoB", "monoC"
    )


def test_planning_side_imports_do_not_import_jax():
    """The front door resolves lazily: planning (model build, partitioning,
    plan lowering, selection, cost reports) is a pure numpy/scipy affair —
    only compiling/executing touches jax."""
    code = (
        "import sys; import repro, repro.api, repro.core, repro.sparse; "
        "import repro.distributed.registry, repro.distributed.select, "
        "repro.distributed.plan_ir, repro.distributed.session; "
        "import repro.resilience, repro.testing, repro.checkpoint; "
        "import repro.launch.serve; "
        "sys.exit(1 if 'jax' in sys.modules else 0)"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True)
    assert out.returncode == 0, out.stderr.decode()


def test_loop_reference_demoted_but_shimmed():
    import repro.distributed as dist
    from repro.distributed import plan as plan_mod

    assert "build_rowwise_plan_loop" not in dist.__all__
    # the shim returns the real function (and warns at least once per process)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        assert dist.build_rowwise_plan_loop is plan_mod.build_rowwise_plan_loop


def test_distributed_all_lists_only_supported_entry_points():
    import repro.distributed as dist

    for name in dist.__all__:
        assert not name.endswith("_loop"), name
        assert getattr(dist, name) is not None, name


def test_unknown_model_raises():
    import numpy as np

    with pytest.raises(ValueError, match="unknown model"):
        repro.plan(np.eye(4), np.eye(4), p=2, model="rowwize")
