"""Sec. 5.1/5.5/5.6 tests: generic vertex coarsening, the SpMV model family,
masked SpGEMM, and symmetric-input coarsening."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.core.coarsen import (
    coarsen_vertices,
    masked_fine_grained,
    spmv_column_net,
    spmv_fine_grain,
    spmv_row_net,
    symmetric_input_coarse_map,
)
from repro.sparse import from_dense
from repro.sparse.structure import random_structure


def _inst(seed=0, shape=(20, 15, 18), density=0.2):
    rng = np.random.default_rng(seed)
    a = random_structure(shape[0], shape[1], density, rng)
    b = random_structure(shape[1], shape[2], density, rng)
    return SpGEMMInstance(a, b)


# ---------------------------------------------------------------------------
# Sec. 5.1: generic coarsening preserves cost accounting
# ---------------------------------------------------------------------------
def test_coarsening_preserves_total_weights():
    inst = _inst()
    hg = build_model(inst, "fine", include_nz=True)
    rng = np.random.default_rng(1)
    cmap = rng.integers(0, hg.n_vertices // 3, size=hg.n_vertices)
    _, cmap = np.unique(cmap, return_inverse=True)
    coarse = coarsen_vertices(hg, cmap)
    assert coarse.total_comp() == hg.total_comp()
    assert coarse.total_mem() == hg.total_mem()


def test_coarsening_matches_slicewise_model():
    """Coarsening V^m of the fine model by i-slices == the row-wise model's
    cut structure: any partition must yield identical connectivity cost."""
    inst = _inst(2)
    fine = build_model(inst, "fine", include_nz=False)
    rowwise = build_model(inst, "rowwise", include_nz=False)
    I = inst.shape[0]
    # coarse map: v_ikj -> i
    cmap = inst.mult_i.copy()
    coarse = coarsen_vertices(fine, cmap)
    rng = np.random.default_rng(3)
    for p in (2, 4):
        parts = rng.integers(0, p, size=I)
        # rowwise model has exactly I vertices; coarse has <= I (empty rows)
        c1 = evaluate(coarse, parts[: coarse.n_vertices], p)
        c2 = evaluate(rowwise, parts, p)
        # B-net cut cost must agree (C/A nets of coarse are uncut singletons
        # or row-internal); compare expand phases
        assert c1.connectivity == c2.connectivity


# ---------------------------------------------------------------------------
# Sec. 5.5: SpMV models
# ---------------------------------------------------------------------------
def test_spmv_column_net_counts():
    rng = np.random.default_rng(4)
    a = random_structure(12, 9, 0.3, rng)
    hg = spmv_column_net(a)
    assert hg.n_vertices == 12  # one per row
    assert hg.n_nets == 9  # one per column
    assert hg.total_comp() == a.nnz


def test_spmv_row_net_counts():
    rng = np.random.default_rng(5)
    a = random_structure(12, 9, 0.3, rng)
    hg = spmv_row_net(a)
    assert hg.n_vertices == 9
    assert hg.n_nets == 12
    assert hg.total_comp() == a.nnz


def test_spmv_fine_grain_catalyurek_aykanat():
    """Square A: vertex per nonzero (+ dummies for zero diagonal), a net per
    row and per column, weights per Sec. 5.5."""
    a = from_dense(
        np.array(
            [
                [1, 1, 0, 0],
                [0, 0, 1, 0],  # zero diagonal at (1,1) -> dummy vertex
                [1, 0, 1, 0],
                [0, 1, 0, 1],
            ]
        )
    )
    hg = spmv_fine_grain(a)
    n_dummy = 1
    assert hg.n_vertices == a.nnz + n_dummy
    assert hg.n_nets == 2 * 4
    # w_mem: diag nz vertices 3, dummy 2, plain nz 1
    assert sorted(hg.w_mem.tolist()) == sorted([3, 1, 1, 3, 1, 3, 1, 2])
    assert hg.total_comp() == a.nnz


# ---------------------------------------------------------------------------
# Sec. 5.6.2: masked SpGEMM
# ---------------------------------------------------------------------------
def test_masked_spgemm_removes_masked_outputs():
    inst = _inst(6)
    rng = np.random.default_rng(7)
    mask_dense = rng.random(inst.c.shape) < 0.5
    mask = from_dense(mask_dense)
    hg = masked_fine_grained(inst, mask)
    full = build_model(inst, "fine", include_nz=True)
    assert hg.n_vertices < full.n_vertices
    assert hg.n_nets < full.n_nets
    # surviving mult count == mults whose (i, j) is unmasked
    kept = mask_dense[inst.mult_i, inst.mult_j].sum()
    assert hg.total_comp() == kept


def test_masked_spgemm_full_mask_is_identity():
    inst = _inst(8)
    mask = from_dense(np.ones(inst.c.shape, dtype=bool))
    hg = masked_fine_grained(inst, mask)
    full = build_model(inst, "fine", include_nz=True)
    assert hg.total_comp() == full.total_comp()


# ---------------------------------------------------------------------------
# Sec. 5.6.1: symmetric input coarsening
# ---------------------------------------------------------------------------
def test_symmetric_coarse_map_pairs_transposed_entries():
    rng = np.random.default_rng(9)
    base = random_structure(10, 10, 0.25, rng)
    import scipy.sparse as sp
    from repro.sparse.structure import SparseStructure

    sym = SparseStructure.wrap(base.csr + base.csr.T)
    inst = SpGEMMInstance(sym, sym)
    cmap = symmetric_input_coarse_map(inst)
    hg = build_model(inst, "fine", include_nz=True)
    coarse = coarsen_vertices(hg, cmap, unit_mem=True)
    off_diag_pairs = (sym.nnz - np.sum(np.array(sym.coo()[0]) == np.array(sym.coo()[1]))) // 2
    assert coarse.n_vertices == hg.n_vertices - off_diag_pairs
    # dedup semantics: coarse memory = one copy per stored entry
    assert coarse.total_mem() == hg.total_mem() - off_diag_pairs
