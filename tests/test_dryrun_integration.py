"""Dry-run integration test (deliverable e): one real cell lowered+compiled
for the production meshes in a subprocess with 512 placeholder devices."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("multi_pod", [False, True])
def test_dryrun_cell_compiles(tmp_path, multi_pod):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    args = [
        sys.executable,
        "-m",
        "repro.launch.dryrun",
        "--arch",
        "internlm2-1.8b",
        "--shape",
        "decode_32k",
        "--out",
        str(tmp_path),
    ]
    if multi_pod:
        args.append("--multi-pod")
    out = subprocess.run(
        args, capture_output=True, text=True, env=env, cwd=ROOT, timeout=900
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    mesh = "2x16x16" if multi_pod else "16x16"
    rec = json.load(open(tmp_path / f"internlm2-1.8b_decode_32k_{mesh}.json"))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == (512 if multi_pod else 256)
    assert rec["flops"] > 0
    assert rec["wire_bytes"] >= 0
    assert "temp_size_in_bytes" in rec["memory"]
    # the collective census found at least one collective kind
    assert len(rec["collectives"]) >= 1
