"""Unit tests for the SpGEMM hypergraph models against the paper's own
worked example (Fig. 1 / Fig. 3 / Fig. 4) and structural invariants."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance, build_model, MODELS
from repro.core.spgemm_models import _lin_lookup
from repro.sparse import from_dense, spgemm_symbolic
from repro.sparse.structure import nontrivial_multiplications, random_structure


# The Fig. 1 instance: reconstructed from the incidence submatrix of Fig. 4.
# A-nets present: (0,0) (0,2) (1,0) (1,3) (2,1)  -> S_A
# B-nets present: (0,1) (1,0) (2,0) (2,1) (3,1)  -> S_B
# C-nets: (0,0) (0,1) (1,1) (2,0); mults: v020 v001 v021 v101 v131 v210
A_FIG1 = np.array(
    [
        [1, 0, 1, 0],
        [1, 0, 0, 1],
        [0, 1, 0, 0],
    ]
)
B_FIG1 = np.array(
    [
        [0, 1],
        [1, 0],
        [1, 1],
        [0, 1],
    ]
)


@pytest.fixture
def fig1():
    return SpGEMMInstance(from_dense(A_FIG1), from_dense(B_FIG1), name="fig1")


def test_fig1_multiplications(fig1):
    triples = set(zip(fig1.mult_i.tolist(), fig1.mult_k.tolist(), fig1.mult_j.tolist()))
    assert triples == {
        (0, 2, 0),
        (0, 0, 1),
        (0, 2, 1),
        (1, 0, 1),
        (1, 3, 1),
        (2, 1, 0),
    }
    assert fig1.n_mult == 6


def test_fig1_output_structure(fig1):
    c = np.zeros((3, 2), dtype=bool)
    r, col = fig1.c.coo()
    c[r, col] = True
    expected = np.array([[1, 1], [0, 1], [1, 0]], dtype=bool)
    assert np.array_equal(c, expected)


def test_fig1_fine_grained_counts(fig1):
    hg = build_model(fig1, "fine", include_nz=True)
    nA, nB, nC = 5, 5, 4
    assert hg.n_vertices == 6 + nA + nB + nC
    assert hg.n_nets == nA + nB + nC
    # every mult vertex has exactly 3 pins; every nz vertex exactly 1
    ptr, _ = hg.vertex_to_nets()
    deg = np.diff(ptr)
    assert (deg[:6] == 3).all()
    assert (deg[6:] == 1).all()
    # each net contains its nz vertex: sizes = 1 + #associated mults
    assert hg.net_sizes().sum() == 6 * 3 + (nA + nB + nC)
    assert (hg.net_cost == 1).all()
    assert hg.total_comp() == 6


def test_fig1_fine_no_nz(fig1):
    hg = build_model(fig1, "fine", include_nz=False)
    assert hg.n_vertices == 6
    assert hg.n_nets == 14
    assert hg.total_comp() == 6
    assert hg.total_mem() == 0


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("include_nz", [False, True])
def test_models_build_and_validate(model, include_nz):
    rng = np.random.default_rng(42)
    a = random_structure(17, 13, 0.2, rng)
    b = random_structure(13, 19, 0.2, rng)
    inst = SpGEMMInstance(a, b)
    hg = build_model(inst, model, include_nz=include_nz)
    hg.validate()
    assert hg.n_vertices > 0


@pytest.mark.parametrize("model", MODELS)
def test_total_comp_equals_flops(model):
    """All parallelization models must account for the same |V^m| flops."""
    rng = np.random.default_rng(7)
    a = random_structure(23, 17, 0.15, rng)
    b = random_structure(17, 29, 0.15, rng)
    inst = SpGEMMInstance(a, b)
    hg = build_model(inst, model, include_nz=False)
    assert hg.total_comp() == inst.n_mult


def test_rowwise_weights_match_ex51():
    rng = np.random.default_rng(3)
    a = random_structure(11, 7, 0.3, rng)
    b = random_structure(7, 9, 0.3, rng)
    inst = SpGEMMInstance(a, b)
    hg = build_model(inst, "rowwise", include_nz=True)
    I, K = 11, 7
    assert hg.n_vertices == I + K
    assert hg.n_nets == K
    # net cost = nnz of B row k
    assert np.array_equal(hg.net_cost, b.row_counts())
    # w_mem(v_i) = nnz(A row i) + nnz(C row i)
    assert np.array_equal(hg.w_mem[:I], a.row_counts() + inst.c.row_counts())
    # pins: between 1 (no v_i) + 1 and I + 1
    assert (hg.net_sizes() <= I + 1).all()


def test_outer_weights_match_ex52():
    rng = np.random.default_rng(4)
    a = random_structure(11, 7, 0.3, rng)
    b = random_structure(7, 9, 0.3, rng)
    inst = SpGEMMInstance(a, b)
    hg = build_model(inst, "outer", include_nz=True)
    K = 7
    assert hg.n_vertices == K + inst.c.nnz
    assert hg.n_nets == inst.c.nnz
    assert np.array_equal(hg.w_comp[:K], a.col_counts() * b.row_counts())
    assert np.array_equal(hg.w_mem[:K], a.col_counts() + b.row_counts())
    assert (hg.net_cost == 1).all()


def test_monoC_weights_match_ex54():
    rng = np.random.default_rng(5)
    a = random_structure(11, 7, 0.3, rng)
    b = random_structure(7, 9, 0.3, rng)
    inst = SpGEMMInstance(a, b)
    hg = build_model(inst, "monoC", include_nz=True)
    assert hg.n_vertices == inst.c.nnz + a.nnz + b.nnz
    assert hg.n_nets == a.nnz + b.nnz
    # w_comp(v_ij) = number of k contributing to (i,j); sums to |V^m|
    assert hg.w_comp.sum() == inst.n_mult


def test_columnwise_transpose_duality():
    """column-wise on (A,B) == row-wise on (B^T, A^T) (C^T = B^T A^T)."""
    rng = np.random.default_rng(6)
    a = random_structure(12, 8, 0.25, rng)
    b = random_structure(8, 10, 0.25, rng)
    inst = SpGEMMInstance(a, b)
    inst_t = SpGEMMInstance(b.transpose(), a.transpose())
    col = build_model(inst, "columnwise", include_nz=False)
    row_t = build_model(inst_t, "rowwise", include_nz=False)
    assert col.n_vertices == row_t.n_vertices
    assert col.n_nets == row_t.n_nets
    assert np.array_equal(np.sort(col.net_cost), np.sort(row_t.net_cost))
    assert np.array_equal(np.sort(col.w_comp), np.sort(row_t.w_comp))


def test_lin_lookup_roundtrip():
    rng = np.random.default_rng(8)
    s = random_structure(20, 30, 0.1, rng)
    r, c = s.coo()
    pos = _lin_lookup(s, r, c)
    assert np.array_equal(pos, np.arange(s.nnz))


def test_spgemm_symbolic_matches_numpy():
    rng = np.random.default_rng(9)
    a = random_structure(15, 12, 0.2, rng)
    b = random_structure(12, 18, 0.2, rng)
    c = spgemm_symbolic(a, b)
    ad = np.zeros((15, 12), bool)
    bd = np.zeros((12, 18), bool)
    ar, ac = a.coo()
    ad[ar, ac] = True
    br, bc = b.coo()
    bd[br, bc] = True
    cd = ad.astype(int) @ bd.astype(int) > 0
    got = np.zeros((15, 18), bool)
    cr, cc = c.coo()
    got[cr, cc] = True
    assert np.array_equal(got, cd)


def test_mult_count_equals_flops_formula():
    rng = np.random.default_rng(10)
    a = random_structure(9, 14, 0.3, rng)
    b = random_structure(14, 11, 0.3, rng)
    i, k, j = nontrivial_multiplications(a, b)
    assert len(i) == int((a.col_counts() * b.row_counts()).sum())
