"""Partitioner-engine invariants: the flat-CSR engine (core/refine.py) vs
the retained loop-FM executable specification (partition engine="loop").

The two engines are not move-for-move identical (gain buckets visit
candidates in a different order than the per-move argmax), so the gate is
on the *outcomes*: balance-cap respect, self-consistent reported
connectivity, determinism, and equal-or-better connectivity than the loop
reference in aggregate over small random instances (with a small per-case
tolerance — multilevel heuristics are noisy per instance)."""
import numpy as np
import pytest

from repro.core import SpGEMMInstance, build_model, evaluate, partition
from repro.core.refine import compute_counts, fm_refine, initial_bisect, kway_refine
from repro.sparse.structure import random_structure


def _instance(seed=0, shape=(60, 50, 55), density=0.08):
    rng = np.random.default_rng(seed)
    a = random_structure(shape[0], shape[1], density, rng)
    b = random_structure(shape[1], shape[2], density, rng)
    return SpGEMMInstance(a, b)


# ---------------------------------------------------------------------------
# balance + self-consistency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p,eps", [(2, 0.05), (4, 0.10), (8, 0.10)])
def test_balance_cap_respected_or_heavy_forced(p, eps):
    hg = build_model(_instance(1, shape=(90, 70, 80)), "rowwise")
    res = partition(hg, p, eps=eps, seed=0)
    w = hg.w_comp.astype(np.float64)
    part_w = np.bincount(res.parts, weights=w, minlength=p)
    cap = max((1 + eps) * w.sum() / p, float(w.max()))
    # the cap is the driver's own invariant: every part fits, except where a
    # single heavy vertex forces a violation (then the part is that cap)
    assert (part_w <= cap + 1e-9).all()


@pytest.mark.parametrize("model", ["rowwise", "fine", "monoC"])
def test_reported_connectivity_matches_fresh_evaluation(model):
    hg = build_model(_instance(2), model)
    for p in (2, 5):
        res = partition(hg, p, eps=0.10, seed=3)
        assert res.connectivity == evaluate(hg, res.parts, p).connectivity


def test_determinism_for_fixed_seed():
    hg = build_model(_instance(3, shape=(80, 60, 70)), "rowwise")
    a = partition(hg, 4, eps=0.10, seed=7)
    b = partition(hg, 4, eps=0.10, seed=7)
    assert np.array_equal(a.parts, b.parts)
    assert a.connectivity == b.connectivity
    c = partition(hg, 4, eps=0.10, seed=8)
    # different seed is allowed to (and generally does) differ
    assert c.parts.shape == a.parts.shape


# ---------------------------------------------------------------------------
# flat engine vs loop reference
# ---------------------------------------------------------------------------
def test_flat_connectivity_not_worse_than_loop_reference():
    """Aggregate equal-or-better over a grid of small random instances, and
    never more than 15% worse on any single cell."""
    tot_flat = tot_loop = 0
    for seed in (0, 4, 5):
        inst = _instance(seed, shape=(60 + 10 * seed, 50 + 5 * seed, 55))
        for model in ("rowwise", "fine"):
            hg = build_model(inst, model)
            for p in (2, 4):
                cf = partition(hg, p, eps=0.10, seed=seed).connectivity
                cl = partition(hg, p, eps=0.10, seed=seed, engine="loop").connectivity
                assert cf <= 1.15 * cl, f"{model}/p{p}/seed{seed}: {cf} vs {cl}"
                tot_flat += cf
                tot_loop += cl
    assert tot_flat <= tot_loop


def test_unknown_engine_rejected():
    hg = build_model(_instance(0), "rowwise")
    with pytest.raises(ValueError):
        partition(hg, 2, engine="vectorized")


# ---------------------------------------------------------------------------
# refinement-engine unit invariants
# ---------------------------------------------------------------------------
def test_fm_refine_never_worsens_the_cut():
    hg = build_model(_instance(6, shape=(70, 60, 65)), "rowwise")
    rng = np.random.default_rng(0)
    side = rng.integers(0, 2, hg.n_vertices).astype(np.int8)
    w = hg.w_comp.astype(np.float64)
    cap = 0.6 * w.sum()
    before = evaluate(hg, side.astype(np.int64), 2).connectivity
    after_side = fm_refine(hg, side, (cap, cap))
    after = evaluate(hg, after_side.astype(np.int64), 2).connectivity
    assert after <= before


def test_kway_refine_monotone_and_balance_preserving():
    hg = build_model(_instance(7, shape=(80, 70, 75)), "fine")
    p = 5
    rng = np.random.default_rng(1)
    parts = rng.integers(0, p, hg.n_vertices)
    w = hg.w_comp.astype(np.float64)
    cap = max(1.25 * w.sum() / p, float(w.max()))
    before = evaluate(hg, parts, p).connectivity
    bw = np.bincount(parts, weights=w, minlength=p)
    refined = kway_refine(hg, parts, p, cap)
    after = evaluate(hg, refined, p).connectivity
    assert after <= before
    aw = np.bincount(refined, weights=w, minlength=p)
    # no part exceeds the cap unless it already did before the pass
    for q in range(p):
        assert aw[q] <= cap + 1e-9 or aw[q] <= bw[q] + 1e-9


def test_kway_refine_restricted_mode_monotone():
    """Forcing the cut-net-restricted fallback (dense_cell_cap=1) must still
    improve monotonically and respect the cap — it is the only refiner the
    speed path has at paper scale."""
    hg = build_model(_instance(11, shape=(120, 90, 100)), "fine")
    p = 6
    rng = np.random.default_rng(3)
    parts = rng.integers(0, p, hg.n_vertices)
    w = hg.w_comp.astype(np.float64)
    cap = max(1.25 * w.sum() / p, float(w.max()))
    before = evaluate(hg, parts, p).connectivity
    refined = kway_refine(hg, parts, p, cap, dense_cell_cap=1)
    after = evaluate(hg, refined, p).connectivity
    assert after <= before
    assert (np.bincount(refined, weights=w, minlength=p) <= cap + 1e-9).all()


def test_initial_bisect_hits_weight_target():
    hg = build_model(_instance(8, shape=(90, 80, 85)), "rowwise")
    w = hg.w_comp.astype(np.float64)
    target = 0.5 * w.sum()
    side = initial_bisect(hg, target, np.random.default_rng(0))
    got = w[side == 0].sum()
    assert 0.8 * target <= got <= 1.1 * target


def test_compute_counts_matches_bruteforce():
    hg = build_model(_instance(9), "fine")
    rng = np.random.default_rng(2)
    side = rng.integers(0, 2, hg.n_vertices).astype(np.int8)
    cnt = compute_counts(hg, side)
    for n in range(0, hg.n_nets, max(hg.n_nets // 40, 1)):
        pins = hg.pins_of(n)
        assert cnt[n, 0] == int((side[pins] == 0).sum())
        assert cnt[n, 1] == int((side[pins] == 1).sum())


# ---------------------------------------------------------------------------
# warm-start partitioning (drift-aware replanning, session satellite)
# ---------------------------------------------------------------------------
def test_warm_start_from_own_labels_is_feasible_and_no_worse():
    hg = build_model(_instance(6, shape=(80, 60, 70)), "rowwise")
    p, eps = 4, 0.10
    cold = partition(hg, p, eps=eps, seed=0)
    assert not cold.warm
    warm = partition(hg, p, eps=eps, seed=0, warm_start=cold.parts)
    assert warm.warm
    # kway_refine polish is monotone: reusing the labels can only help
    assert warm.connectivity <= cold.connectivity
    w = hg.w_comp.astype(np.float64)
    part_w = np.bincount(warm.parts, weights=w, minlength=p)
    cap = max((1 + eps) * w.sum() / p, float(w.max()))
    assert (part_w <= cap + 1e-9).all()


def test_warm_start_fills_drift_holes_under_balance_cap():
    hg = build_model(_instance(7, shape=(80, 60, 70)), "rowwise")
    p, eps = 4, 0.10
    cold = partition(hg, p, eps=eps, seed=1)
    labels = cold.parts.copy()
    rng = np.random.default_rng(3)
    labels[rng.choice(hg.n_vertices, hg.n_vertices // 5, replace=False)] = -1
    warm = partition(hg, p, eps=eps, seed=1, warm_start=labels)
    assert warm.warm
    assert ((warm.parts >= 0) & (warm.parts < p)).all()
    w = hg.w_comp.astype(np.float64)
    part_w = np.bincount(warm.parts, weights=w, minlength=p)
    cap = max((1 + eps) * w.sum() / p, float(w.max()))
    assert (part_w <= cap + 1e-9).all()


def test_warm_start_beyond_drift_limit_goes_cold():
    hg = build_model(_instance(8, shape=(80, 60, 70)), "rowwise")
    p = 4
    labels = np.full(hg.n_vertices, -1, dtype=np.int64)
    labels[: hg.n_vertices // 4] = 0  # 75% drift > 50% limit
    warm = partition(hg, p, eps=0.10, seed=2, warm_start=labels)
    cold = partition(hg, p, eps=0.10, seed=2)
    assert not warm.warm
    assert np.array_equal(warm.parts, cold.parts)  # bit-identical cold path


def test_warm_start_infeasible_polish_goes_cold(monkeypatch):
    """If the polished warm result cannot satisfy the balance cap, reuse is
    rejected and cold partitioning runs (polish neutered to force the case)."""
    import importlib

    partition_mod = importlib.import_module("repro.core.partition")
    hg = build_model(_instance(6, shape=(80, 60, 70)), "rowwise")
    p = 4
    monkeypatch.setattr(
        partition_mod, "kway_refine", lambda hg, parts, p, cap, **kw: parts
    )
    labels = np.zeros(hg.n_vertices, dtype=np.int64)  # everything on part 0
    warm = partition(hg, p, eps=0.10, seed=0, warm_start=labels)
    assert not warm.warm


def test_warm_start_wrong_shape_goes_cold():
    hg = build_model(_instance(6), "rowwise")
    warm = partition(hg, 4, eps=0.10, seed=0, warm_start=np.zeros(3, np.int64))
    cold = partition(hg, 4, eps=0.10, seed=0)
    assert not warm.warm
    assert np.array_equal(warm.parts, cold.parts)


def test_warm_start_p1_short_circuits_warm():
    hg = build_model(_instance(6), "rowwise")
    res = partition(hg, 1, warm_start=np.zeros(hg.n_vertices, np.int64))
    assert res.warm
    assert (res.parts == 0).all()
